"""Check that intra-repo markdown links resolve to real files.

Walks every ``*.md`` under the repository root, extracts inline links
``[text](target)``, and verifies that each relative target exists on disk
(after stripping any ``#fragment``). External schemes (http/https/mailto)
and pure-fragment anchors are skipped. Exit code 1 and one line per broken
link otherwise — run by the CI ``docs`` job and runnable locally:

    python tools/check_links.py
"""

from __future__ import annotations

import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "runs", "node_modules"}
# [text](target) — target ends at the first unescaped ')' or whitespace
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def iter_markdown_files() -> "list[str]":
    """Every tracked-ish markdown file under the repo root."""
    found = []
    for dirpath, dirnames, filenames in os.walk(REPO_ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for name in filenames:
            if name.endswith(".md"):
                found.append(os.path.join(dirpath, name))
    return sorted(found)


def check_file(md_path: str) -> "list[str]":
    """Return one problem string per unresolvable relative link in ``md_path``."""
    with open(md_path, encoding="utf-8") as fh:
        text = fh.read()
    problems = []
    for lineno, line in enumerate(text.splitlines(), start=1):
        for match in LINK_RE.finditer(line):
            target = match.group(1)
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = os.path.normpath(os.path.join(os.path.dirname(md_path), path))
            if not os.path.exists(resolved):
                rel_md = os.path.relpath(md_path, REPO_ROOT)
                problems.append(f"{rel_md}:{lineno}: broken link -> {target}")
    return problems


def main() -> int:
    """Check every markdown file; print problems; 0 iff all links resolve."""
    files = iter_markdown_files()
    problems = []
    for md in files:
        problems.extend(check_file(md))
    for p in problems:
        print(p)
    status = "OK" if not problems else f"{len(problems)} broken links"
    print(f"# checked {len(files)} markdown files: {status}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
