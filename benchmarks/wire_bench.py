"""Wire-codec benchmark: canonical bytes, digests, and the context-union
hot path under each available backend, plus the per-entry digest cache win.

What it measures (median µs per call, CSV like benchmarks/run.py):

  canonical_bytes/<codec>    encode a mixed fact payload to canonical form
  canonical_digest/<codec>   ...plus sha256
  entry_make/<codec>         ContextEntry.make (canonical encode at insert)
  union_digest/<codec>       union two 64-fact contexts + digest the result
                             (the journal-commit hot path: with memoized
                             per-entry digests this re-hashes only 16-hex
                             strings, never re-serializes values)
  union_digest_cold          same, but entry digest caches deliberately
                             dropped — the speedup shows what the cache buys
  payload_encode/decode      compressed msgpack pytree codec (journal body)

Run:  PYTHONPATH=src python -m benchmarks.wire_bench [--repeat N]
"""
from __future__ import annotations

import argparse
import statistics
import time
from typing import Callable

import numpy as np

from repro import wire
from repro.core.context import Context, ContextEntry


def timeit(fn: Callable[[], None], repeat: int, inner: int = 1) -> float:
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        ts.append((time.perf_counter() - t0) * 1e6 / inner)
    return statistics.median(ts)


RESULTS: list = []  # (name, median_us, derived) — dumped by --json


def record(name: str, us: float, derived: str = "") -> None:
    RESULTS.append({"name": name, "median_us": round(us, 2), "derived": derived})
    print(f"{name},{us:.2f},{derived}", flush=True)


def fact_payload(i: int) -> dict:
    return {"step": i, "loss": 2.75 / (i + 1), "shard": [i, i + 1],
            "meta": {"host": f"h{i % 4}", "ok": True},
            "arr": np.arange(8, dtype=np.int32)}


def build_context(n: int, origin: str) -> Context:
    return Context(ContextEntry.make(f"k{i}", fact_payload(i), origin, i % 7)
                   for i in range(n))


def drop_entry_caches(ctx: Context) -> None:
    for e in ctx._entries:
        object.__setattr__(e, "_digest", None)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat", type=int, default=7)
    ap.add_argument("--entries", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, assert-no-crash (the CI gate)")
    ap.add_argument("--json", type=str, default="",
                    help="write results to this path")
    args = ap.parse_args()
    if args.smoke:
        args.repeat, args.entries = 1, 8

    payload = fact_payload(3)
    codecs = wire.available_codecs()
    print(f"# codecs available: {codecs}; zstd={wire.zstd_available()}")

    for name in codecs:
        codec = wire.get_codec(name)
        record(f"canonical_bytes/{name}",
               timeit(lambda: codec.canonical_bytes(payload), args.repeat, 200))
        record(f"canonical_digest/{name}",
               timeit(lambda: codec.canonical_digest(payload), args.repeat, 200))

    for name in codecs:
        wire.set_default_codec(name)
        record(f"entry_make/{name}",
               timeit(lambda: ContextEntry.make("k", payload, "bench"),
                      args.repeat, 200))

        a = build_context(args.entries, "A")
        b = build_context(args.entries, "B")
        a.digest(), b.digest()  # warm entry caches

        def union_digest():
            (a | b).digest()

        record(f"union_digest/{name}", timeit(union_digest, args.repeat, 50),
               f"{2 * args.entries}_facts")
    wire.set_default_codec(None)

    # what the per-entry cache buys: same union+digest with caches dropped
    a = build_context(args.entries, "A")
    b = build_context(args.entries, "B")

    def union_digest_cold():
        drop_entry_caches(a)
        drop_entry_caches(b)
        (a | b).digest()

    warm = timeit(lambda: (a | b).digest(), args.repeat, 50)
    cold = timeit(union_digest_cold, args.repeat, 50)
    record("union_digest_cold", cold, f"cache_speedup={cold / max(warm, 1e-9):.1f}x")

    tree = {"w": np.ones((64, 64), np.float32), "step": 7,
            "opt": {"m": np.zeros((64, 64), np.float32)}}
    blob = wire.encode_payload(tree)
    record("payload_encode", timeit(lambda: wire.encode_payload(tree),
                                    args.repeat, 20), f"{len(blob)}B")
    record("payload_decode", timeit(lambda: wire.decode_payload(blob),
                                    args.repeat, 20))

    if args.json:
        import json as _json

        with open(args.json, "w") as fh:
            _json.dump({"codecs": codecs, "zstd": wire.zstd_available(),
                        "results": RESULTS}, fh, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
