"""Streaming pipeline benchmark: batch-barrier vs chunk-pipelined execution.

Workload: a staged producer → map → map → map → reduce pipeline over K
chunks
where every stage costs per-chunk wall time and the producer is FASTER
than its consumers — the skewed regime where batch barriers hurt most
(the consumer could have started K-1 chunks ago) and where backpressure
matters (an unbounded producer would buffer the whole stream).

Two runners over the same stage functions:

  - ``batch``: each stage materializes its full output before the next
    starts — the barrier semantics every node had before repro.stream.
  - ``pipelined``: the same graph declared with ``stream=`` kinds, run by
    ``LocalExecutor`` — consumers start on the first chunk, chunks flow
    through bounded channels, every chunk is journaled (CHUNK_COMMIT).

Wall-clock under batch is the SUM of per-stage costs; pipelined is the
cost of the slowest stage plus fill/drain — the benchmark asserts ≥2x and
audits the journal (chunk counts, EOS markers) of the pipelined run.

Run:   PYTHONPATH=src python -m benchmarks.stream_bench
       PYTHONPATH=src python -m benchmarks.stream_bench --smoke --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import ContextGraph, Journal, LocalExecutor

FLOOR_SPEEDUP = 2.0  # asserted: pipelined must beat batch-barrier by ≥2x


def stage_fns(chunks: int, dt: float):
    """The five stage functions; the producer runs 2x faster than consumers."""

    def produce(ctx, start=0):
        for i in range(start, chunks):
            time.sleep(dt / 2)
            yield i

    def stage_a(ctx, chunk):
        time.sleep(dt)
        return chunk * 2

    def stage_b(ctx, chunk):
        time.sleep(dt)
        return chunk + 1

    def stage_c(ctx, chunk):
        time.sleep(dt)
        return chunk + 3

    def reduce(ctx, stream):
        total = 0
        for v in stream:
            time.sleep(dt)
            total += v
        return total

    return produce, stage_a, stage_b, stage_c, reduce


def run_batch(chunks: int, dt: float) -> int:
    """Barrier baseline: each stage fully materializes before the next."""
    produce, stage_a, stage_b, stage_c, reduce = stage_fns(chunks, dt)
    src = list(produce(None))
    a = [stage_a(None, chunk=c) for c in src]
    b = [stage_b(None, chunk=c) for c in a]
    c = [stage_c(None, chunk=v) for v in b]
    return reduce(None, iter(c))


def build_graph(chunks: int, dt: float) -> ContextGraph:
    produce, stage_a, stage_b, stage_c, reduce = stage_fns(chunks, dt)
    g = ContextGraph(name="stream-bench")
    g.add_stream("src", produce)
    g.add("a", stage_a, deps=["src"], stream="map", aliases={"src": "chunk"})
    g.add("b", stage_b, deps=["a"], stream="map", aliases={"a": "chunk"})
    g.add("c", stage_c, deps=["b"], stream="map", aliases={"b": "chunk"})
    g.add("total", reduce, deps=["c"], stream="reduce", aliases={"c": "stream"})
    return g


def bench(args: argparse.Namespace) -> dict:
    chunks = 12 if args.smoke else args.chunks
    dt = 0.01 if args.smoke else args.dt

    from repro.wire import payload_digest

    payload_digest({"warmup": 0})  # pull in numpy etc. outside the timed region

    t0 = time.perf_counter()
    batch_total = run_batch(chunks, dt)
    batch_s = time.perf_counter() - t0

    journal_path = os.path.join(args.out, "stream_bench.wal")
    if os.path.exists(journal_path):
        os.remove(journal_path)  # a stale journal would replay, not execute
    with Journal(journal_path, sync="batch") as j:
        ex = LocalExecutor(journal=j, channel_capacity=args.capacity)
        t0 = time.perf_counter()
        rep = ex.run(build_graph(chunks, dt))
        pipelined_s = time.perf_counter() - t0
        kinds = j.kinds()

    want = sum(i * 2 + 4 for i in range(chunks))
    assert batch_total == want, f"batch result {batch_total} != {want}"
    assert rep.outputs["total"] == want, f"pipelined {rep.outputs['total']} != {want}"
    # journal audit: every chunk of every emitting stage is durable
    assert kinds["CHUNK_COMMIT"] == 4 * chunks, kinds
    assert kinds["STREAM_EOS"] == 4, kinds
    assert kinds["NODE_COMMIT"] == 5, kinds

    speedup = batch_s / pipelined_s if pipelined_s else float("inf")
    result = {
        "chunks": chunks,
        "stage_dt_s": dt,
        "channel_capacity": args.capacity,
        "batch_wall_s": round(batch_s, 4),
        "pipelined_wall_s": round(pipelined_s, 4),
        "speedup": round(speedup, 2),
        "outputs_ok": True,
        "journal_kinds": kinds,
        "journal": journal_path,
    }
    print(f"batch_wall_s,{batch_s * 1e3:.1f}ms")
    print(f"pipelined_wall_s,{pipelined_s * 1e3:.1f}ms")
    print(f"speedup,{speedup:.2f}x")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--chunks", type=int, default=24)
    ap.add_argument("--dt", type=float, default=0.012,
                    help="per-chunk stage cost (the producer runs at dt/2)")
    ap.add_argument("--capacity", type=int, default=4,
                    help="bounded channel capacity (backpressure window)")
    ap.add_argument("--repeat", type=int, default=3,
                    help="take the best-of-N of each mode's wall clock")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; still asserts the ≥2x floor")
    ap.add_argument("--json", type=str, default="",
                    help="write the result blob to this path")
    ap.add_argument("--out", type=str, default=".",
                    help="directory for the run journal")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    runs = [bench(args) for _ in range(2 if args.smoke else args.repeat)]
    best = dict(runs[0])
    # best-of-N per MODE (not per run): each mode's floor is its honest cost
    best["batch_wall_s"] = min(r["batch_wall_s"] for r in runs)
    best["pipelined_wall_s"] = min(r["pipelined_wall_s"] for r in runs)
    best["speedup"] = round(best["batch_wall_s"] / best["pipelined_wall_s"], 2)
    if len(runs) > 1:
        best["runs"] = runs
    assert best["speedup"] >= FLOOR_SPEEDUP, (
        f"pipelined speedup {best['speedup']}x under the {FLOOR_SPEEDUP}x floor"
    )
    print(f"best_speedup,{best['speedup']:.2f}x (floor {FLOOR_SPEEDUP}x)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(best, fh, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
