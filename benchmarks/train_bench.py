"""Distributed training benchmark: data-parallel fan-out vs a single worker.

Workload: the real ``DistributedTrainer`` round graph (sync → grad shards →
reduce → apply → checkpoint) on the smoke model, with REAL gradient math on
every shard. Each in-proc worker additionally carries ``--latency`` seconds
of injected per-task latency simulating the remote-accelerator regime
(device step + transfer time on a worker host) — the same honest-injection
idiom as ``cluster_bench``'s slow worker. The single-worker baseline pays
the per-shard latency serially; the 4-worker leg overlaps it.

Three legs over identical configs (same seed, same shard count):

  - ``baseline``: 1 worker — every shard task of a step serializes;
  - ``dataflow``: N workers — shard tasks fan out through the gateway;
  - ``kill``: N workers, one of which dies mid-round — the run must finish
    and its final checkpoint digest must equal the ``dataflow`` leg's
    (bit-identical elastic re-shard, the docs/training.md §4 contract).

Run:   PYTHONPATH=src python -m benchmarks.train_bench
       PYTHONPATH=src python -m benchmarks.train_bench --smoke --json out.json

Prints CSV-ish lines like the other benches; ``--json`` writes the result
blob the CI bench-smoke artifact step uploads.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import time

import jax

from repro.configs import get_config, smoke_variant
from repro.core import FlakyWorker, InProcWorker, Journal
from repro.core.context import Context
from repro.optim.adamw import AdamWConfig
from repro.train import DistTrainConfig, DistributedTrainer
from repro.wire import unwrap_digested


def make_config(args: argparse.Namespace):
    cfg = smoke_variant(get_config("serpytor-demo-100m"))
    steps = 2 if args.smoke else args.steps
    return cfg, dict(
        num_steps=steps,
        checkpoint_every=max(2, steps // 2),
        log_every=10_000,
        global_batch=args.shards,  # one row per shard: the latency-bound regime
        seq_len=16 if args.smoke else args.seq,
        heartbeat=False,
        journal_sync="batch",
        num_shards=args.shards,
        opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps),
    )


def make_trainer(cfg, tc_kw, run_dir: str, num_workers: int) -> DistributedTrainer:
    shutil.rmtree(run_dir, ignore_errors=True)
    tc = DistTrainConfig(run_dir=run_dir, num_workers=num_workers, **tc_kw)
    return DistributedTrainer(cfg, tc)


def warmup(trainer: DistributedTrainer) -> None:
    """Compile the grad/apply jits outside the timed region (both legs pay
    compilation identically, so it would only add noise to the ratio)."""
    start, params, opt = trainer.recover()
    ctx = Context.origin(
        {"shard": 0, "num_shards": trainer.tc.num_shards}, origin="warmup"
    )
    out = trainer.registry.get("grad_shard")(
        ctx, sync={"step": start, "params": params}
    )
    grads = unwrap_digested(out["grads"])
    jax.block_until_ready(trainer._japply(params, opt, grads))


def inject_latency(trainer: DistributedTrainer, latency_s: float) -> None:
    for w in trainer.workers:
        w.latency_s = latency_s


def run_leg(cfg, tc_kw, run_dir, num_workers, latency_s, flaky_kill_at=None):
    tr = make_trainer(cfg, tc_kw, run_dir, num_workers)
    if flaky_kill_at is not None:
        tr.workers = [
            FlakyWorker(
                "w0",
                tr.registry,
                kill_after_starts=flaky_kill_at,
                max_concurrency=1,
            )
        ] + [
            InProcWorker(f"w{i}", tr.registry, max_concurrency=1)
            for i in range(1, num_workers)
        ]
    inject_latency(tr, latency_s)
    warmup(tr)
    t0 = time.perf_counter()
    out = tr.train()
    wall = time.perf_counter() - t0
    digest = tr.store.manifest(tr.store.latest())["digest"]
    return {
        "steps": out["steps"],
        "wall_s": round(wall, 4),
        "steps_per_s": round(out["steps"] / max(wall, 1e-9), 3),
        "final_loss": out["final_loss"],
        "params_digest": digest,
        "journal": os.path.join(run_dir, "journal.wal"),
    }


def bench(args: argparse.Namespace) -> dict:
    cfg, tc_kw = make_config(args)
    latency = 0.01 if args.smoke else args.latency
    repeat = 1 if args.smoke else args.repeat

    # best-of-N per MODE (the cluster_bench convention): each leg's floor is
    # its honest cost — this container's CPU allotment is noisy enough that a
    # single rep can be throttled mid-leg
    def best_of(run_dir, num_workers):
        legs = [
            run_leg(cfg, tc_kw, run_dir, num_workers, latency)
            for _ in range(repeat)
        ]
        return max(legs, key=lambda r: r["steps_per_s"])

    base = best_of(os.path.join(args.out, "train_1w"), 1)
    data = best_of(os.path.join(args.out, "train_4w"), args.workers)
    # one worker dies on its 2nd task start — mid-round, shards in flight.
    # One rep: this leg asserts digest equality, not timing
    kill = run_leg(
        cfg,
        tc_kw,
        os.path.join(args.out, "train_kill"),
        args.workers,
        latency,
        flaky_kill_at=2,
    )
    speedup = data["steps_per_s"] / max(base["steps_per_s"], 1e-9)
    requeues = Journal(kill["journal"], sync="never").kinds().get("NODE_REQUEUE", 0)

    assert data["params_digest"] == base["params_digest"], (
        "shard fan-out changed the math: 1-worker and N-worker runs must "
        "produce bit-identical params"
    )
    assert kill["params_digest"] == data["params_digest"], (
        "kill-mid-round run diverged from the uninterrupted run"
    )
    if not args.smoke:
        assert speedup >= 1.5, f"speedup floor breached: {speedup:.2f}x < 1.5x"

    result = {
        "model": cfg.name,
        "steps": tc_kw["num_steps"],
        "shards": args.shards,
        "workers": args.workers,
        "simulated_worker_latency_s": latency,
        "baseline_1w": base,
        "dataflow": data,
        "kill_mid_round": kill,
        "kill_requeues": requeues,
        "speedup": round(speedup, 2),
        "digests_identical": True,
    }
    print(f"baseline_1w_steps_per_s,{base['steps_per_s']}")
    print(f"dataflow_{args.workers}w_steps_per_s,{data['steps_per_s']}")
    print(f"speedup,{speedup:.2f}x")
    print(f"kill_mid_round_digest_match,{kill['params_digest'] == data['params_digest']}")
    print(f"kill_requeues,{requeues}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument(
        "--latency",
        type=float,
        default=0.15,
        help="injected per-task worker latency (simulated accelerator regime)",
    )
    ap.add_argument(
        "--repeat",
        type=int,
        default=3,
        help="take the best-of-N of each mode's wall clock",
    )
    ap.add_argument("--smoke", action="store_true", help="tiny sizes, assert-no-crash")
    ap.add_argument("--json", type=str, default="", help="write the result blob here")
    ap.add_argument("--out", type=str, default=".", help="directory for run dirs")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    result = bench(args)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result, fh, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
