"""Result-cache benchmark: cold vs warm re-run of the skewed-diamond graph.

Workload: the same K-diamond graph as ``benchmarks.cluster_bench`` (one
deliberately slow worker), run twice against one ``repro.cache.ResultCache``
root:

  - ``cold``: empty cache — every node executes on the cluster and is
    committed into the cache (``CACHE_STORE`` journal records);
  - ``warm``: a fresh journal and a fresh gateway, same cache root — every
    node is answered from the cache before dispatch (``CACHE_HIT`` records),
    so no task ever reaches a worker.

The warm journal is then audited (CACHE_HIT/NODE_COMMIT counts in
``Journal.kinds()``) and replayed without the cache to prove that a
cache-accelerated run remains a complete, standalone durable record —
the contract specified in docs/result-cache.md §5.

``--tiered`` benches the fleet scenario instead (docs/journal-lifecycle.md
§4): host A runs cold through a :class:`~repro.cache.TieredCacheBackend`
(local tier + shared remote path), then host B — a *fresh* local tier, same
shared remote — runs the same graph. Every node must be answered by
read-through from the shared tier (and promoted into B's local tier), making
B's "cold" run ≥2x faster than a genuinely cold one: cross-host dedup, not
just cross-run.

Run:   PYTHONPATH=src python -m benchmarks.cache_bench
       PYTHONPATH=src python -m benchmarks.cache_bench --smoke --json out.json
       PYTHONPATH=src python -m benchmarks.cache_bench --smoke --tiered
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import time

from benchmarks.cluster_bench import build_diamonds, build_registry, make_workers
from repro.cache import ResultCache
from repro.core import ClusterExecutor, Gateway, Journal


def _timed_run(
    args: argparse.Namespace,
    k: int,
    task_s: float,
    slow_s: float,
    journal_path: str,
    cache: "ResultCache | None",
) -> tuple:
    """One cluster run of the K-diamond graph; returns (report, wall_s)."""
    reg = build_registry(task_s)
    with Gateway(make_workers(reg, args.workers, slow_s)) as gw:
        with Journal(journal_path, sync="batch") as j:
            ex = ClusterExecutor(gw, journal=j, cache=cache, speculation_tick_s=0.01)
            t0 = time.perf_counter()
            rep = ex.run(build_diamonds(k))
            wall = time.perf_counter() - t0
    return rep, wall


def bench(args: argparse.Namespace) -> dict:
    """Cold + warm + replay-audit cycle; returns the result blob."""
    k = 3 if args.smoke else args.diamonds
    task_s = 0.002 if args.smoke else args.task_s
    slow_s = 0.01 if args.smoke else args.slow_s
    n_nodes = 4 * k
    expected = {f"join{i}": 5 for i in range(k)}

    from repro.wire import payload_digest

    payload_digest({"warmup": 0})  # pull in numpy etc. outside the timed region

    cache_root = os.path.join(args.out, "cache_bench_cache")
    cold_wal = os.path.join(args.out, "cache_bench_cold.wal")
    warm_wal = os.path.join(args.out, "cache_bench_warm.wal")
    for path in (cold_wal, warm_wal):
        if os.path.exists(path):
            os.remove(path)  # a stale journal would replay, not execute
    shutil.rmtree(cache_root, ignore_errors=True)  # cold must be genuinely cold

    rep_cold, cold_s = _timed_run(args, k, task_s, slow_s, cold_wal, ResultCache(cache_root))
    assert len(rep_cold.executed) == n_nodes, rep_cold

    floor = 2.0 if args.smoke else 3.0
    warm_s = float("inf")
    for _attempt in range(3):  # best-of-3: one scheduler hiccup must not fail CI
        if os.path.exists(warm_wal):
            os.remove(warm_wal)  # each attempt must cache-hit, not replay
        # fresh ResultCache instance: warm hits come from disk, not process memory
        warm_cache = ResultCache(cache_root)
        rep_warm, attempt_s = _timed_run(args, k, task_s, slow_s, warm_wal, warm_cache)
        assert len(rep_warm.cached) == n_nodes, rep_warm
        assert rep_warm.executed == (), rep_warm
        warm_s = min(warm_s, attempt_s)
        if cold_s / warm_s >= floor:
            break

    for nid, want in expected.items():
        assert rep_cold.outputs[nid] == want, f"cold {nid}: {rep_cold.outputs[nid]}"
        assert rep_warm.outputs[nid] == want, f"warm {nid}: {rep_warm.outputs[nid]}"

    # audit: the warm journal accounts for every hit and still fully replays
    with Journal(warm_wal, sync="never") as j:
        kinds = j.kinds()
    assert kinds.get("CACHE_HIT") == n_nodes, kinds
    assert kinds.get("NODE_COMMIT") == n_nodes, kinds
    rep_replay, _ = _timed_run(args, k, task_s, slow_s, warm_wal, None)
    assert rep_replay.executed == () and rep_replay.cached == (), rep_replay
    assert len(rep_replay.replayed) == n_nodes, rep_replay

    speedup = cold_s / warm_s if warm_s else float("inf")
    assert speedup >= floor, f"warm rerun only {speedup:.2f}x faster than cold (floor {floor}x)"
    result = {
        "diamonds": k,
        "nodes": n_nodes,
        "workers": args.workers,
        "task_s": task_s,
        "slow_extra_s": slow_s,
        "cold_wall_s": round(cold_s, 4),
        "warm_wall_s": round(warm_s, 4),
        "speedup": round(speedup, 2),
        "cache_hits": warm_cache.stats["hits"],
        "cache_disk_bytes": warm_cache.backend.size_bytes(),
        "warm_journal_kinds": kinds,
        "replay_ok": True,
        "outputs_ok": True,
    }
    print(f"cold_wall_s,{cold_s * 1e3:.1f}ms")
    print(f"warm_wall_s,{warm_s * 1e3:.1f}ms")
    print(f"speedup,{speedup:.2f}x")
    return result


def bench_tiered(args: argparse.Namespace) -> dict:
    """Two-host tiered-cache cycle: host A cold, host B served by the shared tier."""
    k = 3 if args.smoke else args.diamonds
    task_s = 0.002 if args.smoke else args.task_s
    slow_s = 0.01 if args.smoke else args.slow_s
    n_nodes = 4 * k
    expected = {f"join{i}": 5 for i in range(k)}

    from repro.wire import payload_digest

    payload_digest({"warmup": 0})  # pull in numpy etc. outside the timed region

    remote_root = os.path.join(args.out, "cache_bench_remote")
    host_a = os.path.join(args.out, "cache_bench_hostA")
    host_b = os.path.join(args.out, "cache_bench_hostB")
    cold_wal = os.path.join(args.out, "cache_bench_tiered_cold.wal")
    b_wal = os.path.join(args.out, "cache_bench_tiered_b.wal")
    for path in (cold_wal, b_wal):
        if os.path.exists(path):
            os.remove(path)
    for root in (remote_root, host_a, host_b):
        shutil.rmtree(root, ignore_errors=True)

    cache_a = ResultCache(host_a, remote_root=remote_root)
    rep_cold, cold_s = _timed_run(args, k, task_s, slow_s, cold_wal, cache_a)
    assert len(rep_cold.executed) == n_nodes, rep_cold
    assert cache_a.backend.remote_errors == 0, cache_a.backend.remote_errors
    remote_bytes = cache_a.backend.remote_size_bytes()
    assert remote_bytes > 0, "cold run published nothing to the shared tier"

    floor = 2.0
    b_s = float("inf")
    for _attempt in range(3):  # best-of-3: one scheduler hiccup must not fail CI
        if os.path.exists(b_wal):
            os.remove(b_wal)
        shutil.rmtree(host_b, ignore_errors=True)  # host B starts locally cold
        cache_b = ResultCache(host_b, remote_root=remote_root)
        rep_b, attempt_s = _timed_run(args, k, task_s, slow_s, b_wal, cache_b)
        assert len(rep_b.cached) == n_nodes, rep_b
        assert rep_b.executed == (), rep_b
        # every *unique* key came through the shared tier and was promoted
        # (duplicate-key nodes are then answered by memory/local tiers)
        assert cache_b.backend.remote_hits > 0, cache_b.backend.remote_hits
        assert cache_b.backend.promotions == cache_b.backend.remote_hits, (
            cache_b.backend.promotions,
            cache_b.backend.remote_hits,
        )
        b_s = min(b_s, attempt_s)
        if cold_s / b_s >= floor:
            break

    for nid, want in expected.items():
        assert rep_b.outputs[nid] == want, f"hostB {nid}: {rep_b.outputs[nid]}"

    speedup = cold_s / b_s if b_s else float("inf")
    assert speedup >= floor, (
        f"second-host cold run only {speedup:.2f}x faster via the shared "
        f"tier (floor {floor}x)"
    )
    result = {
        "mode": "tiered",
        "diamonds": k,
        "nodes": n_nodes,
        "workers": args.workers,
        "cold_wall_s": round(cold_s, 4),
        "warm_wall_s": round(b_s, 4),  # host B; named for best-of-N aggregation
        "speedup": round(speedup, 2),
        "remote_hits": cache_b.backend.remote_hits,
        "promotions": cache_b.backend.promotions,
        "remote_bytes": remote_bytes,
        "local_b_bytes": cache_b.backend.size_bytes(),
        "outputs_ok": True,
    }
    print(f"cold_wall_s,{cold_s * 1e3:.1f}ms")
    print(f"second_host_wall_s,{b_s * 1e3:.1f}ms")
    print(f"tiered_speedup,{speedup:.2f}x")
    return result


def main() -> None:
    """CLI entry point (CSV-ish lines; ``--json`` writes the result blob)."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--diamonds", type=int, default=12)
    ap.add_argument("--task-s", type=float, default=0.01)
    ap.add_argument(
        "--slow-s",
        type=float,
        default=0.12,
        help="extra per-task latency injected on one worker",
    )
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument(
        "--repeat",
        type=int,
        default=3,
        help="take the best-of-N of each mode's wall clock",
    )
    ap.add_argument("--smoke", action="store_true", help="tiny sizes, assert-no-crash")
    ap.add_argument(
        "--tiered",
        action="store_true",
        help="bench the two-host shared-remote-tier scenario instead",
    )
    ap.add_argument("--json", type=str, default="", help="write the result blob to this path")
    ap.add_argument("--out", type=str, default=".", help="directory for journals and the cache")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    bench_fn = bench_tiered if args.tiered else bench
    runs = [bench_fn(args) for _ in range(1 if args.smoke else args.repeat)]
    best = dict(runs[0])
    # best-of-N per MODE (not per run): each mode's floor is its honest cost
    best["cold_wall_s"] = min(r["cold_wall_s"] for r in runs)
    best["warm_wall_s"] = min(r["warm_wall_s"] for r in runs)
    best["speedup"] = round(best["cold_wall_s"] / best["warm_wall_s"], 2)
    if len(runs) > 1:
        best["runs"] = runs
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(best, fh, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
