"""Observability overhead benchmark: tracing must be (near) free when off.

Two legs over one fixed workload (K diamond graphs of named registry tasks
on an in-proc cluster, journaled — the cluster_bench dataflow shape):

  - ``disabled``: the tracer stays off. The per-call-site cost is a single
    ``tracer.enabled`` attribute read; a micro-leg times that guard
    directly and asserts it stays in the nanosecond-noise regime.
  - ``enabled``: tracing on with a RingSink. Every committed node emits
    its node/rpc/task spans; the run wall-clock must stay within the
    overhead budget of the disabled leg (<5 % at full size; the tiny
    ``--smoke`` workload is dominated by scheduling noise, so the ratio
    assert is relaxed there to a crash-and-sanity check).

Both legs take best-of-``--repeat`` wall clocks on fresh journals (a stale
journal would replay, not execute, and measure nothing).

Run:   PYTHONPATH=src python -m benchmarks.obs_bench
       PYTHONPATH=src python -m benchmarks.obs_bench --smoke --json out.json

Prints CSV-ish lines like benchmarks/run.py; ``--json`` additionally
writes a machine-readable result blob (consumed by the CI bench-smoke
artifact step).
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import (
    ClusterExecutor,
    ContextGraph,
    Gateway,
    InProcWorker,
    Journal,
    TaskRegistry,
)
from repro.obs.sinks import RingSink
from repro.obs.trace import get_tracer

#: Enabled-tracing overhead budget vs the disabled leg, full-size workload.
OVERHEAD_BUDGET = 0.05

#: The disabled guard must stay under this many seconds per call site —
#: generous (hundreds of ns of slack) so CI-host jitter never flakes it,
#: while still catching any accidental work on the disabled path.
GUARD_BUDGET_S = 2e-6


def build_registry(task_s: float) -> TaskRegistry:
    """The bench task: a tiny sleep plus integer fold (cluster_bench's shape)."""
    reg = TaskRegistry()

    @reg.task("work")
    def work(ctx, **kw):
        time.sleep(task_s)
        return sum(v for v in kw.values() if isinstance(v, int)) + 1

    return reg


def build_diamonds(k: int) -> ContextGraph:
    """K independent src -> (left, right) -> join diamonds."""
    g = ContextGraph(name="obs-diamonds")
    for i in range(k):
        g.add(f"src{i}", "work")
        g.add(f"left{i}", "work", deps=[f"src{i}"])
        g.add(f"right{i}", "work", deps=[f"src{i}"])
        g.add(f"join{i}", "work", deps=[f"left{i}", f"right{i}"])
    return g


def run_once(args: argparse.Namespace, k: int, task_s: float, journal_path: str) -> float:
    """One full cluster run on a fresh journal; returns the wall seconds."""
    if os.path.exists(journal_path):
        os.remove(journal_path)
    reg = build_registry(task_s)
    workers = [InProcWorker(f"w{i}", reg) for i in range(args.workers)]
    with Gateway(workers) as gw:
        with Journal(journal_path, sync="batch") as j:
            ex = ClusterExecutor(gw, journal=j, speculative=False)
            t0 = time.perf_counter()
            rep = ex.run(build_diamonds(k))
            wall = time.perf_counter() - t0
    for i in range(k):
        assert rep.outputs[f"join{i}"] == 5, f"join{i}: {rep.outputs[f'join{i}']}"
    return wall


def bench_guard(iters: int) -> float:
    """Seconds per disabled-tracer guard (attribute read + branch)."""
    tracer = get_tracer()
    assert not tracer.enabled
    t0 = time.perf_counter()
    hits = 0
    for _ in range(iters):
        if tracer.enabled:  # the entire disabled-mode call-site cost
            hits += 1
    per_call = (time.perf_counter() - t0) / iters
    assert hits == 0
    return per_call


def bench(args: argparse.Namespace) -> dict:
    """Run both legs and return the result blob (asserting the budgets)."""
    k = 3 if args.smoke else args.diamonds
    task_s = 0.002 if args.smoke else args.task_s
    n_nodes = 4 * k
    tracer = get_tracer()

    from repro.wire import payload_digest

    payload_digest({"warmup": 0})  # pull in numpy etc. outside the timed region

    journal_path = os.path.join(args.out, "obs_bench.wal")
    disabled_walls, enabled_walls = [], []
    span_count = 0
    for _ in range(args.repeat):
        disabled_walls.append(run_once(args, k, task_s, journal_path))
    for _ in range(args.repeat):
        ring = RingSink()
        with tracer.attached(ring):
            enabled_walls.append(run_once(args, k, task_s, journal_path))
        node_spans = [sp for sp in ring.spans() if sp["kind"] == "node"]
        span_count = len(node_spans)
        assert span_count == n_nodes, f"{span_count} node spans for {n_nodes} nodes"
        assert len({sp["trace"] for sp in ring.spans()}) == 1, "trace not coherent"
    os.remove(journal_path)

    disabled_s, enabled_s = min(disabled_walls), min(enabled_walls)
    overhead = enabled_s / disabled_s - 1.0 if disabled_s else 0.0
    guard_s = bench_guard(10_000 if args.smoke else 1_000_000)
    assert guard_s < GUARD_BUDGET_S, (
        f"disabled guard {guard_s * 1e9:.0f}ns/call exceeds budget "
        f"{GUARD_BUDGET_S * 1e9:.0f}ns — the off path is doing work"
    )
    if not args.smoke:
        assert overhead < OVERHEAD_BUDGET, (
            f"enabled tracing costs {overhead:.1%} (> {OVERHEAD_BUDGET:.0%}) "
            f"over the disabled leg"
        )

    result = {
        "diamonds": k,
        "nodes": n_nodes,
        "workers": args.workers,
        "task_s": task_s,
        "repeat": args.repeat,
        "disabled_wall_s": round(disabled_s, 4),
        "enabled_wall_s": round(enabled_s, 4),
        "enabled_overhead_frac": round(overhead, 4),
        "overhead_budget_frac": OVERHEAD_BUDGET,
        "guard_ns_per_call": round(guard_s * 1e9, 2),
        "node_spans": span_count,
        "spans_ok": True,
        "smoke": bool(args.smoke),
    }
    print(f"disabled_wall_s,{disabled_s * 1e3:.1f}ms")
    print(f"enabled_wall_s,{enabled_s * 1e3:.1f}ms")
    print(f"enabled_overhead,{overhead:+.1%}")
    print(f"guard_ns_per_call,{guard_s * 1e9:.1f}ns")
    return result


def main() -> None:
    """CLI entry point."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--diamonds", type=int, default=12)
    ap.add_argument("--task-s", type=float, default=0.01)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument(
        "--repeat",
        type=int,
        default=3,
        help="take the best-of-N of each leg's wall clock",
    )
    ap.add_argument("--smoke", action="store_true", help="tiny sizes, assert-no-crash")
    ap.add_argument("--json", type=str, default="", help="write the result blob to this path")
    ap.add_argument("--out", type=str, default=".", help="directory for the run journal")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    result = bench(args)
    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(result, fh, indent=2, sort_keys=True)
        print(f"json,{args.json}")


if __name__ == "__main__":
    main()
