"""Cluster scheduling benchmark: level-barrier vs barrier-free dataflow.

Workload: K independent diamond graphs

    src_i -> (left_i, right_i) -> join_i

of named registry tasks on an in-proc cluster where ONE worker has injected
latency — the skewed-straggler regime that stage barriers are worst at
(SparkNet's observation, and the motivation for PR 2's scheduler rework).

Two runners over the *same* gateway/worker setup:

  - ``barrier``: dispatches toposort level by level and waits out each level
    before dispatching the next — the pre-dataflow ClusterExecutor semantics,
    reimplemented here as the baseline.
  - ``dataflow``: ``ClusterExecutor`` — a node dispatches the moment its deps
    commit, completions are event-driven, speculation is global.

Under the barrier, every level's wall-clock is the slow worker's wall-clock;
under dataflow only the diamonds whose tasks actually landed on the slow
worker are delayed (and speculation covers even those).

A second leg exercises the asyncio control plane's inflight ceiling: with
``--inflight N`` the bench submits N trivial tasks through an
:class:`~repro.core.AsyncGateway` at once and reports sustained completion
throughput — the threaded runtime's thread-per-dispatch pump tops out at a
few hundred inflight; the event-loop runtime is expected to take 10k+.

Run:   PYTHONPATH=src python -m benchmarks.cluster_bench
       PYTHONPATH=src python -m benchmarks.cluster_bench --smoke --json out.json
       PYTHONPATH=src python -m benchmarks.cluster_bench --inflight 10000

Prints CSV-ish lines like benchmarks/run.py; ``--json`` additionally writes a
machine-readable result blob (consumed by the CI bench-smoke artifact step).
"""

from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import (
    EMPTY_CONTEXT,
    AsyncGateway,
    ClusterExecutor,
    ContextGraph,
    Gateway,
    InProcWorker,
    Journal,
    TaskRegistry,
)


def build_registry(task_s: float) -> TaskRegistry:
    reg = TaskRegistry()

    @reg.task("work")
    def work(ctx, **kw):
        time.sleep(task_s)
        return sum(v for v in kw.values() if isinstance(v, int)) + 1

    return reg


def make_workers(reg: TaskRegistry, n: int, slow_extra_s: float) -> list:
    workers = [InProcWorker(f"w{i}", reg) for i in range(n)]
    workers[-1].latency_s = slow_extra_s  # the skewed straggler
    return workers


def build_diamonds(k: int) -> ContextGraph:
    g = ContextGraph(name="skewed-diamonds")
    for i in range(k):
        g.add(f"src{i}", "work")
        g.add(f"left{i}", "work", deps=[f"src{i}"])
        g.add(f"right{i}", "work", deps=[f"src{i}"])
        g.add(f"join{i}", "work", deps=[f"left{i}", f"right{i}"])
    return g


def run_barrier(gateway: Gateway, graph: ContextGraph) -> dict:
    """Level-synchronous baseline: no level-N+1 dispatch until level N drains."""
    levels, exec_nodes, member_to_group = graph.schedule()
    outputs: dict = {}
    for level in levels:
        futs = {}
        for nid in level:
            node = exec_nodes[nid]
            inputs = {node.kwarg_for(d): outputs[member_to_group.get(d, d)] for d in node.deps}
            if callable(node.fn):
                outputs[nid] = node.fn(EMPTY_CONTEXT, **inputs)
            else:
                futs[nid] = gateway.submit(str(node.fn), inputs=inputs)
        for nid, fut in futs.items():  # <- the stage barrier
            outputs[nid] = fut.result(timeout=120)
    return outputs


def bench(args: argparse.Namespace) -> dict:
    k = 3 if args.smoke else args.diamonds
    task_s = 0.002 if args.smoke else args.task_s
    slow_s = 0.01 if args.smoke else args.slow_s
    expected = {f"join{i}": 5 for i in range(k)}  # src=1, arms=2 each, join=2+2+1

    from repro.wire import payload_digest

    payload_digest({"warmup": 0})  # pull in numpy etc. outside the timed region

    reg = build_registry(task_s)
    with Gateway(make_workers(reg, args.workers, slow_s)) as gw:
        t0 = time.perf_counter()
        barrier_out = run_barrier(gw, build_diamonds(k))
        barrier_s = time.perf_counter() - t0

    journal_path = os.path.join(args.out, "cluster_bench.wal")
    if os.path.exists(journal_path):
        os.remove(journal_path)  # a stale journal would replay, not execute
    reg = build_registry(task_s)
    with Gateway(make_workers(reg, args.workers, slow_s)) as gw:
        with Journal(journal_path, sync="batch") as j:
            ex = ClusterExecutor(gw, journal=j, speculation_tick_s=0.01)
            t0 = time.perf_counter()
            rep = ex.run(build_diamonds(k))
            dataflow_s = time.perf_counter() - t0

    for nid, want in expected.items():
        assert barrier_out[nid] == want, f"barrier {nid}: {barrier_out[nid]}"
        assert rep.outputs[nid] == want, f"dataflow {nid}: {rep.outputs[nid]}"

    speedup = barrier_s / dataflow_s if dataflow_s else float("inf")
    result = {
        "diamonds": k,
        "workers": args.workers,
        "task_s": task_s,
        "slow_extra_s": slow_s,
        "barrier_wall_s": round(barrier_s, 4),
        "dataflow_wall_s": round(dataflow_s, 4),
        "speedup": round(speedup, 2),
        "outputs_ok": True,
        "journal": journal_path,
    }
    print(f"barrier_wall_s,{barrier_s * 1e3:.1f}ms")
    print(f"dataflow_wall_s,{dataflow_s * 1e3:.1f}ms")
    print(f"speedup,{speedup:.2f}x")
    return result


def bench_inflight(args: argparse.Namespace) -> dict:
    """Async-runtime inflight ceiling: N concurrent trivial tasks, one host.

    Every task is submitted before the first result is collected, so the
    gateway genuinely holds ``--inflight`` outstanding requests; the leg
    fails loudly if any future is lost, times out, or returns the wrong
    value — completion correctness at scale is the point, not just speed.
    """
    n = args.inflight
    reg = TaskRegistry()

    @reg.task("noop")
    def noop(ctx, i=0):
        return i + 1

    workers = [
        InProcWorker(f"w{i}", reg, max_concurrency=256) for i in range(args.workers)
    ]
    with AsyncGateway(workers, max_inflight_rpc=1024) as gw:
        t0 = time.perf_counter()
        futs = gw.map("noop", [{"i": i} for i in range(n)])
        submit_s = time.perf_counter() - t0
        results = [f.result(timeout=300) for f in futs]
        wall_s = time.perf_counter() - t0
    assert results == [i + 1 for i in range(n)], "lost or corrupted completions"
    throughput = n / wall_s if wall_s else float("inf")
    result = {
        "inflight": n,
        "workers": args.workers,
        "runtime": "async",
        "submit_wall_s": round(submit_s, 4),
        "wall_s": round(wall_s, 4),
        "tasks_per_s": round(throughput, 1),
        "outputs_ok": True,
    }
    print(f"inflight,{n}")
    print(f"inflight_wall_s,{wall_s * 1e3:.1f}ms")
    print(f"inflight_tasks_per_s,{throughput:.0f}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--diamonds", type=int, default=12)
    ap.add_argument("--task-s", type=float, default=0.01)
    ap.add_argument(
        "--slow-s",
        type=float,
        default=0.12,
        help="extra per-task latency injected on one worker",
    )
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument(
        "--repeat",
        type=int,
        default=3,
        help="take the best-of-N of each mode's wall clock",
    )
    ap.add_argument("--smoke", action="store_true", help="tiny sizes, assert-no-crash")
    ap.add_argument("--json", type=str, default="", help="write the result blob to this path")
    ap.add_argument("--out", type=str, default=".", help="directory for the run journal")
    ap.add_argument(
        "--inflight",
        type=int,
        default=0,
        help="run ONLY the async-runtime inflight leg with N concurrent tasks",
    )
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.inflight:
        result = bench_inflight(args)
        if args.json:
            with open(args.json, "w") as fh:
                json.dump(result, fh, indent=2)
            print(f"# wrote {args.json}")
        return

    runs = [bench(args) for _ in range(1 if args.smoke else args.repeat)]
    best = dict(runs[0])
    # best-of-N per MODE (not per run): each mode's floor is its honest cost
    best["barrier_wall_s"] = min(r["barrier_wall_s"] for r in runs)
    best["dataflow_wall_s"] = min(r["dataflow_wall_s"] for r in runs)
    best["speedup"] = round(best["barrier_wall_s"] / best["dataflow_wall_s"], 2)
    if len(runs) > 1:
        best["runs"] = runs
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(best, fh, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
