"""Benchmark harness — one function per paper claim (the paper has no numeric
tables; Figures 1-2 are architectural, so the claims in the abstract/§1/§5
define the benchmark set). Prints ``name,us_per_call,derived`` CSV.

  bench_setup_overhead      claim: "little setup" vs a Spark-style bring-up
  bench_gateway_scheduling  claim: gateway allocation must stay fast (§5)
  bench_graph_execution     claim: "fast speeds" — framework overhead per node
  bench_journal_overhead    durable-execution tax (sync vs batch vs off)
  bench_context_overhead    ξ-union + digest cost per node
  bench_heavy_stage_vs_gateway  end-to-end task throughput vs the baseline
  bench_train_step          end-to-end jitted train step (demo model)

Run:  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import statistics
import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def record(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def timeit(fn: Callable[[], None], repeat: int = 5) -> float:
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


# ---------------------------------------------------------------------------
def bench_setup_overhead(quick: bool) -> None:
    """SerPyTor cluster bring-up vs Spark-style heavyweight bring-up."""
    from benchmarks.baseline_heavy import HeavyCluster
    from repro.core import Gateway, InProcWorker, TaskRegistry

    reg = TaskRegistry()
    reg.register("noop", lambda ctx: None)

    def serpytor_setup():
        workers = [InProcWorker(f"w{i}", reg) for i in range(4)]
        gw = Gateway(workers, heartbeat_interval_s=10).start()
        gw.stop()

    def heavy_setup():
        hc = HeavyCluster(num_workers=4)
        hc.setup()
        hc.teardown()

    us_s = timeit(serpytor_setup, 3 if quick else 7)
    us_h = timeit(heavy_setup, 3 if quick else 7)
    record("setup_overhead_serpytor", us_s, "4 workers+gateway")
    record("setup_overhead_heavy_baseline", us_h,
           f"spark-style bring-up; ratio={us_h/us_s:.1f}x")


def bench_gateway_scheduling(quick: bool) -> None:
    from repro.core import Gateway, InProcWorker, TaskRegistry

    reg = TaskRegistry()
    reg.register("noop", lambda ctx: 0)
    n = 200 if quick else 1000
    for algo in ("round_robin", "least_loaded", "power_of_two",
                 "context_affinity"):
        workers = [InProcWorker(f"w{i}", reg) for i in range(8)]
        with Gateway(workers, allocation=(algo,),
                     heartbeat_interval_s=10) as gw:
            futs = gw.map("noop", [{} for _ in range(n)])
            [f.result(timeout=60) for f in futs]
            record(f"gateway_alloc_{algo}", gw.mean_alloc_us(),
                   f"{n} tasks, 8 workers")


def bench_graph_execution(quick: bool) -> None:
    """Per-node framework overhead: chain + fanout graphs of noop tasks."""
    from repro.core import Context, ContextGraph, LocalExecutor

    n = 50 if quick else 200

    def chain():
        g = ContextGraph(origin=Context.origin({"b": 1}))
        prev = None
        for i in range(n):
            g.add(f"n{i}", lambda ctx, **kw: 0,
                  deps=[prev] if prev else [])
            prev = f"n{i}"
        LocalExecutor(max_workers=4).run(g)

    def fanout():
        g = ContextGraph(origin=Context.origin({"b": 1}))
        g.add("src", lambda ctx: 0)
        for i in range(n):
            g.add(f"n{i}", lambda ctx, src: 0, deps=["src"])
        LocalExecutor(max_workers=8).run(g)

    us = timeit(chain, 3)
    record("graph_exec_chain_per_node", us / n, f"{n}-node chain")
    us = timeit(fanout, 3)
    record("graph_exec_fanout_per_node", us / n, f"{n}-wide fanout")


def bench_journal_overhead(quick: bool) -> None:
    import os
    import tempfile

    from repro.core import Context, ContextGraph, Journal, LocalExecutor

    n = 30 if quick else 100

    def run(sync):
        with tempfile.TemporaryDirectory() as d:
            g = ContextGraph(origin=Context.origin({"b": 1}))
            prev = None
            for i in range(n):
                g.add(f"n{i}", lambda ctx, **kw: {"x": 1},
                      deps=[prev] if prev else [])
                prev = f"n{i}"
            if sync == "off":
                LocalExecutor().run(g)
            else:
                with Journal(os.path.join(d, "j.wal"), sync=sync) as j:
                    LocalExecutor(journal=j).run(g)

    base = timeit(lambda: run("off"), 3)
    for sync in ("never", "batch", "always"):
        us = timeit(lambda s=sync: run(s), 3)
        record(f"journal_overhead_{sync}", (us - base) / n,
               f"per-node delta vs no-journal ({base/n:.1f}us baseline)")


def bench_context_overhead(quick: bool) -> None:
    from repro.core import Context

    big = Context.origin({f"k{i}": i for i in range(100)})
    small = Context.origin({"a": 1})
    us = timeit(lambda: [big | small for _ in range(100)], 5) / 100
    record("context_union_100fact", us, "union of 100-fact + 1-fact contexts")
    us = timeit(lambda: [Context.origin({"x": 1}).digest()
                         for _ in range(100)], 5) / 100
    record("context_digest", us, "fresh 1-fact context digest")


def bench_heavy_stage_vs_gateway(quick: bool) -> None:
    """End-to-end: many small tasks through both frameworks."""
    from benchmarks.baseline_heavy import HeavyCluster
    from repro.core import Gateway, InProcWorker, TaskRegistry

    n = 64 if quick else 256
    work = lambda x: sum(i * i for i in range(200))

    reg = TaskRegistry()
    reg.register("work", lambda ctx, x: work(x))

    def serpytor():
        workers = [InProcWorker(f"w{i}", reg) for i in range(4)]
        with Gateway(workers, allocation=("round_robin",),
                     heartbeat_interval_s=10) as gw:
            futs = gw.map("work", [{"x": i} for i in range(n)])
            [f.result(timeout=60) for f in futs]

    def heavy():
        hc = HeavyCluster(num_workers=4)
        hc.setup()
        hc.run_stage(work, list(range(n)))
        hc.teardown()

    us_s = timeit(serpytor, 3)
    us_h = timeit(heavy, 3)
    record("e2e_tasks_serpytor", us_s / n, f"{n} tasks incl. setup")
    record("e2e_tasks_heavy_baseline", us_h / n,
           f"{n} tasks incl. setup; ratio={us_h/us_s:.2f}x")


def bench_train_step(quick: bool) -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import build
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.train.steps import make_train_step

    cfg = dataclasses.replace(
        get_config("serpytor-demo-100m"), num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=8192)
    model = build(cfg)
    params, _ = model.init(jax.random.key(0))
    opt = adamw_init(params, AdamWConfig())
    step = jax.jit(make_train_step(model, AdamWConfig()),
                   donate_argnums=(0, 1))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 256)), jnp.int32)
    params, opt, _ = step(params, opt, {"tokens": toks})  # compile
    n_tokens = toks.size

    def one():
        nonlocal params, opt
        params, opt, m = step(params, opt, {"tokens": toks})
        jax.block_until_ready(m["loss"])

    us = timeit(one, 3 if quick else 5)
    record("train_step_10m_cpu", us,
           f"{n_tokens} tok/step; {n_tokens/(us/1e6):.0f} tok/s (1 CPU core)")


BENCHES = [bench_setup_overhead, bench_gateway_scheduling,
           bench_graph_execution, bench_journal_overhead,
           bench_context_overhead, bench_heavy_stage_vs_gateway,
           bench_train_step]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for bench in BENCHES:
        if args.only and args.only not in bench.__name__:
            continue
        try:
            bench(args.quick)
        except Exception as exc:  # pragma: no cover
            record(bench.__name__ + "_ERROR", -1, str(exc)[:100])
    import csv
    import os

    os.makedirs("results", exist_ok=True)
    with open("results/benchmarks.csv", "w", newline="") as fh:
        w = csv.writer(fh)
        w.writerow(["name", "us_per_call", "derived"])
        w.writerows(ROWS)


if __name__ == "__main__":
    main()
