"""The "heavyweight cluster" baseline SerPyTor is compared against.

Spark itself cannot be installed offline, so this is an in-repo stand-in
that faithfully reproduces the *setup cost structure* of a Spark-style
cluster bring-up (the paper's comparison axis, §1: "the prerequisite setup
for a Spark cluster often induces an additional overhead"):

  1. config validation + session negotiation (driver ↔ master handshake),
  2. per-worker environment sync (ship serialized closures/conf),
  3. executor registration barrier (all workers must check in),
  4. per-job stage planning with a synchronous barrier per stage.

Costs are modeled as real work (serialization, socket round trips on
localhost, barrier waits), NOT sleeps, so the comparison measures honest
protocol overhead rather than an arbitrary constant. It is clearly labeled
a stand-in in EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import socket
import threading
import time
from typing import Any, Callable, Dict, List, Sequence

from repro.core.durable import decode_payload, encode_payload

__all__ = ["HeavyCluster"]


class _EchoServer(threading.Thread):
    """Stand-in master: accepts registrations and echoes conf blobs."""

    def __init__(self):
        super().__init__(daemon=True)
        self.sock = socket.socket()
        self.sock.bind(("127.0.0.1", 0))
        self.sock.listen(64)
        self.port = self.sock.getsockname()[1]
        self._stop = False

    def run(self):
        self.sock.settimeout(0.2)
        while not self._stop:
            try:
                conn, _ = self.sock.accept()
            except socket.timeout:
                continue
            with conn:
                data = conn.recv(1 << 20)
                conn.sendall(data)  # echo = ack

    def stop(self):
        self._stop = True
        self.join(timeout=2)
        self.sock.close()


class HeavyCluster:
    """Spark-style bring-up + stage-barrier execution."""

    def __init__(self, num_workers: int = 4, conf: Dict[str, Any] = None):
        self.num_workers = num_workers
        self.conf = dict(conf or {})
        self.master: _EchoServer = None
        self.registered: List[int] = []

    # -- the expensive part the paper complains about -----------------------
    def setup(self) -> float:
        t0 = time.perf_counter()
        # 1. config validation + session negotiation
        conf_blob = json.dumps({**self.conf, "defaults": {
            f"spark.opt.{i}": str(i) for i in range(200)}}).encode()
        self.master = _EchoServer()
        self.master.start()
        for _ in range(3):  # handshake round trips
            s = socket.create_connection(("127.0.0.1", self.master.port))
            s.sendall(conf_blob[:4096])
            s.recv(1 << 20)
            s.close()
        # 2. per-worker env sync (ship conf + closure registry)
        env_blob = encode_payload({"conf": self.conf,
                                   "env": {f"var{i}": "x" * 64
                                           for i in range(100)}})
        for w in range(self.num_workers):
            s = socket.create_connection(("127.0.0.1", self.master.port))
            s.sendall(env_blob[:8192])
            s.recv(1 << 20)
            s.close()
            self.registered.append(w)
        # 3. registration barrier
        assert len(self.registered) == self.num_workers
        return time.perf_counter() - t0

    def run_stage(self, fn: Callable[[Any], Any], items: Sequence[Any]
                  ) -> List[Any]:
        """One stage with a synchronous barrier + closure re-serialization."""
        blob = encode_payload({"items": list(items)})
        decode_payload(blob)  # driver-side round trip (closure ship stand-in)
        results = [None] * len(items)
        threads = []
        barrier = threading.Barrier(self.num_workers)

        def worker(wi: int):
            barrier.wait()  # stage start barrier
            for i in range(wi, len(items), self.num_workers):
                results[i] = fn(items[i])
            barrier.wait()  # stage end barrier

        for wi in range(self.num_workers):
            t = threading.Thread(target=worker, args=(wi,))
            t.start()
            threads.append(t)
        for t in threads:
            t.join()
        return results

    def teardown(self):
        if self.master:
            self.master.stop()
