"""Emit the EXPERIMENTS.md §Dry-run and §Roofline tables from results/.

Run:  PYTHONPATH=src python -m benchmarks.roofline [--write]
With --write, rewrites the marked sections of EXPERIMENTS.md in place.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(dirname: str) -> Dict:
    out = {}
    for p in sorted(glob.glob(os.path.join("results", dirname, "*.json"))):
        r = json.load(open(p))
        key = (r["arch"], r["shape"],
               bool(r.get("multi_pod", False)) if dirname == "dryrun" else None,
               r.get("tag", ""))
        out[key] = r
    return out


def dryrun_table() -> List[str]:
    recs = _load("dryrun")
    lines = [
        "| arch | shape | mesh | status | compile s | HBM/dev GiB | fits 16GiB | collective GiB/dev/step |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mp, tag), r in sorted(recs.items()):
        if tag:
            continue
        mesh = "2×16×16" if mp else "16×16"
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | SKIP (sub-quadratic "
                         f"only) | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR | — | — | — | — |")
            continue
        hbm = r["hbm_per_device_gib"]
        coll = r["collectives"]["total_bytes"] / 2 ** 30
        lines.append(
            f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']:.0f} | "
            f"{hbm:.2f} | {'✓' if hbm <= 16 else '✗'} | {coll:.2f} |")
    return lines


def roofline_table(tag: str = "") -> List[str]:
    recs = _load("roofline")
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant |"
        " 6ND/HLO useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, _, t), r in sorted(recs.items()):
        if t != tag:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | skipped | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | — | — | — | ERROR | — | — |")
            continue
        t_ = r["terms_s"]
        lines.append(
            f"| {arch} | {shape} | {t_['compute_s']*1e3:.2f} | "
            f"{t_['memory_s']*1e3:.2f} | {t_['collective_s']*1e3:.2f} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return lines


def perf_section() -> List[str]:
    lines: List[str] = []
    for p in sorted(glob.glob("results/perf/*.json")):
        r = json.load(open(p))
        lines.append(f"### {r['arch']} × {r['shape']}")
        lines.append("")
        lines.append(f"Roofline fraction: **{r['baseline_fraction']:.3f} "
                     f"(baseline) → {r['final_fraction']:.3f} (optimized)**; "
                     f"step bound {max(r['baseline'].values())*1e3:.1f} ms → "
                     f"{max(r['final'].values())*1e3:.1f} ms.")
        lines.append("")
        lines.append("| iteration | verdict | compute ms | memory ms | "
                     "collective ms | step bound ms |")
        lines.append("|---|---|---|---|---|---|")
        for e in r["log"]:
            t = e.get("after_s", e.get("terms_s"))
            bound = max(t.values()) * 1e3
            verdict = e.get("verdict", "baseline")
            kept = "" if e.get("kept", True) else " (reverted)"
            lines.append(
                f"| {e['iter']} | {verdict}{kept} | {t['compute_s']*1e3:.2f} |"
                f" {t['memory_s']*1e3:.2f} | {t['collective_s']*1e3:.2f} |"
                f" {bound:.2f} |")
        lines.append("")
        for e in r["log"]:
            if "hypothesis" in e:
                lines.append(f"- **{e['iter']}** [{e['verdict']}]: "
                             f"{e['hypothesis']}")
        lines.append("")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args()
    dr = "\n".join(dryrun_table())
    rf = "\n".join(roofline_table())
    pf = "\n".join(perf_section())
    if not args.write:
        print("## Dry-run\n")
        print(dr)
        print("\n## Roofline\n")
        print(rf)
        print("\n## Perf\n")
        print(pf)
        return
    path = "EXPERIMENTS.md"
    text = open(path).read() if os.path.exists(path) else ""
    for marker, table in (("DRYRUN", dr), ("ROOFLINE", rf), ("PERF", pf)):
        begin, end = f"<!-- {marker}:BEGIN -->", f"<!-- {marker}:END -->"
        if begin in text and end in text:
            pre, rest = text.split(begin, 1)
            _, post = rest.split(end, 1)
            text = pre + begin + "\n" + table + "\n" + end + post
    with open(path, "w") as fh:
        fh.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
