"""Quickstart: SerPyTor's context-aware durable graph on a worker cluster.

Builds the paper's Figure-2 style graph (including the co-dependent A/B
union node), runs it twice against a journal to show durable replay, and
dispatches a batch of tasks through the Gateway with heartbeat monitoring.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.core import (Context, ContextGraph, Gateway, InProcWorker, Journal,
                        LocalExecutor, TaskRegistry, WithContext)


def main() -> None:
    # ── 1. a context-aware graph (Figure 2 shape) ─────────────────────────
    g = ContextGraph(origin=Context.origin({"env": "quickstart", "seed": 7}),
                     name="fig2")
    g.add("D", lambda ctx: 10, data={"source": "D"})
    g.add("E", lambda ctx: 32, data={"source": "E"})
    # co-dependent pair → contracted into a union node A' (§4.1 rule 3)
    g.add("A", lambda ctx, B=None: (B or 0) + 1, deps=["B"], data={"pa": 1})
    g.add("B", lambda ctx, A=None: (A or 0) + 2, deps=["A"], data={"pb": 2})
    g.add("F", lambda ctx, D, E: WithContext(D + E, {"f_sum": D + E}),
          deps=["D", "E"])
    g.add("G", lambda ctx, F, A: F + A, deps=["F", "A"], aliases={"A": "A"})

    exec_nodes, m2g = g.contract()
    print("union nodes:", [k for k in exec_nodes if k.startswith("∪")])
    xi = g.propagate_contexts(exec_nodes)
    print("ξ(G) keys:", sorted(xi["G"].keys()))

    with tempfile.TemporaryDirectory() as d:
        journal = Journal(os.path.join(d, "run.wal"), sync="always")
        report = LocalExecutor(journal=journal).run(g)
        print("first run outputs:", {k: report.outputs[k]
                                     for k in ("F", "G")})
        print("executed:", sorted(report.executed))
        journal.close()

        # durable replay: same graph + same journal ⇒ zero re-execution
        journal2 = Journal(os.path.join(d, "run.wal"), sync="always")
        report2 = LocalExecutor(journal=journal2).run(g)
        print("second run replayed:", sorted(report2.replayed),
              "(executed:", list(report2.executed), ")")
        journal2.close()

    # ── 2. gateway dispatch over heartbeat-monitored workers ──────────────
    reg = TaskRegistry()

    @reg.task("hash_shard")
    def hash_shard(ctx, shard: int) -> str:
        import hashlib

        return hashlib.sha256(f"{ctx.get('env')}:{shard}".encode()).hexdigest()[:8]

    workers = [InProcWorker(f"w{i}", reg) for i in range(4)]
    with Gateway(workers, allocation=("round_robin",)) as gw:
        futs = gw.map("hash_shard", [{"shard": i} for i in range(12)],
                      ctx=Context.origin({"env": "quickstart"}))
        results = [f.result(timeout=10) for f in futs]
        print("gateway results:", results[:4], "...")
        print(f"scheduled={gw.metrics['scheduled']} "
              f"mean_alloc={gw.mean_alloc_us():.1f}µs")
        per_worker = {h.name: h.completed for h in gw.handles}
        print("per-worker completion:", per_worker)


if __name__ == "__main__":
    main()
