"""Streaming-pipeline demo: chunked execution, mid-stream kill, durable resume.

 1. runs a producer → per-chunk map → reduce pipeline where consumers start
    on the FIRST chunk (pipelined, backpressured — repro.stream),
 2. "crashes" the run mid-stream at chunk 5: every chunk that was committed
    before the crash is durable in the journal (CHUNK_COMMIT records),
 3. re-runs on the same journal: committed chunks replay from the journal
    with ZERO producer re-emission, the producer resumes from its last
    committed offset, and the final result equals an uninterrupted run.

Run:  PYTHONPATH=src python examples/stream_pipeline.py [--base-dir DIR]

Writes to a throwaway temp directory by default; pass --base-dir (or set
SERPYTOR_DEMO_DIR) to keep the journal somewhere inspectable.
"""

import argparse
import os
import shutil
import tempfile
import time

from repro.core import ContextGraph, Journal, LocalExecutor

CHUNKS = 8
KILL_AT = 5


class KillSwitch(RuntimeError):
    """The injected mid-stream 'crash'."""


def build_pipeline(trace: dict, kill: bool) -> ContextGraph:
    """producer → per-chunk map → reduce, with an optional mid-stream kill."""

    def producer(ctx, start=0):
        # the durable-resume contract: yield chunks from index `start`
        trace["starts"].append(start)
        for i in range(start, CHUNKS):
            trace["emitted"].append(i)
            time.sleep(0.01)  # pretend each record costs something
            yield {"record": i, "payload": i * i}

    def enrich(ctx, chunk):
        if kill and chunk["record"] == KILL_AT:
            raise KillSwitch(f"injected crash at chunk {KILL_AT}")
        trace["mapped"].append(chunk["record"])
        time.sleep(0.01)
        return {**chunk, "enriched": chunk["payload"] + 1000}

    def aggregate(ctx, stream):
        total = 0
        for chunk in stream:
            total += chunk["enriched"]
        return total

    g = ContextGraph(name="stream-demo")
    g.add_stream("ingest", producer)
    g.add("enrich", enrich, deps=["ingest"], stream="map",
          aliases={"ingest": "chunk"})
    g.add("aggregate", aggregate, deps=["enrich"], stream="reduce",
          aliases={"enrich": "stream"})
    return g


def main(base_dir: str = "") -> None:
    base = base_dir or os.environ.get("SERPYTOR_DEMO_DIR") or ""
    ephemeral = not base
    if ephemeral:
        base = tempfile.mkdtemp(prefix="serpytor-stream-")
    try:
        _run_demo(base)
    finally:
        if ephemeral:
            shutil.rmtree(base, ignore_errors=True)


def _run_demo(base: str) -> None:
    path = os.path.join(base, "stream_demo.wal")
    if os.path.exists(path):
        os.remove(path)
    print(f"journal: {path}\n")

    print(f"=== run 1: killed mid-stream at chunk {KILL_AT} ===")
    t1 = {"starts": [], "emitted": [], "mapped": []}
    try:
        with Journal(path, sync="batch") as j:
            LocalExecutor(journal=j).run(build_pipeline(t1, kill=True))
        raise SystemExit("expected the injected crash!")
    except KillSwitch as exc:
        print(f"crashed as planned: {exc}")
    with Journal(path, sync="batch") as j:
        kinds = j.kinds()
        committed = [r.meta["seq"] for r in j.records()
                     if r.kind == "CHUNK_COMMIT" and r.node_id == "enrich"]
    print(f"journal kinds after crash: {kinds}")
    print(f"map chunks durable before the crash: {committed}\n")

    print("=== run 2: resume on the same journal ===")
    t2 = {"starts": [], "emitted": [], "mapped": []}
    with Journal(path, sync="batch") as j:
        rep = LocalExecutor(journal=j).run(build_pipeline(t2, kill=False))
    print(f"result: {rep.outputs['aggregate']}")
    print(f"producer invoked with start={t2['starts'] or '(fully replayed)'} "
          f"(run 1 started at {t1['starts']})")
    print(f"chunks re-emitted by the producer: {t2['emitted'] or 'NONE'}")
    print(f"chunks mapped fresh in run 2: {t2['mapped']} "
          f"(0..{KILL_AT - 1} came from the journal)")

    # verify against an uninterrupted reference run in a fresh journal
    ref_path = os.path.join(base, "stream_ref.wal")
    t3 = {"starts": [], "emitted": [], "mapped": []}
    with Journal(ref_path, sync="batch") as j:
        ref = LocalExecutor(journal=j).run(build_pipeline(t3, kill=False))
    assert rep.outputs["aggregate"] == ref.outputs["aggregate"], "divergence!"
    assert all(s > 0 for s in t2["starts"]), "producer must not restart at 0"
    assert all(m >= KILL_AT for m in t2["mapped"]), "no committed chunk re-maps"
    print("\nresumed result == uninterrupted reference result ✓")
    print("zero re-emission of committed chunks ✓")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base-dir", type=str, default="",
                    help="keep artifacts here instead of a throwaway tempdir")
    main(ap.parse_args().base_dir)
