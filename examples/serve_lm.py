"""Serve a small LM with batched requests through the SerPyTor Gateway.

Architecture (the paper's physical layer, §3):
  - N WorkerServer-style workers (in-proc transport), each owning a model
    replica + heartbeat; the worker batches concurrent requests into one
    prefill + decode loop (continuous batching at request granularity);
  - a Gateway with context-affinity allocation routes sessions;
  - requests are atomic durable tasks: a generation is journaled by digest,
    so re-submitting an identical request replays instead of recomputing.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Context, Gateway, InProcWorker, TaskRegistry
from repro.models import build


def make_worker_registry(cfg, params, model, max_new: int) -> TaskRegistry:
    reg = TaskRegistry()
    decode = jax.jit(model.decode_step)

    @reg.task("generate")
    def generate(ctx, prompt, new_tokens):
        toks = jnp.asarray(np.asarray(prompt, np.int32))[None, :]
        S = toks.shape[1]
        logits, cache = model.prefill(params, {"tokens": toks},
                                      pad_to=S + int(new_tokens))
        out = []
        tok = jnp.argmax(logits, axis=-1)
        for _ in range(int(new_tokens)):
            out.append(int(tok[0]))
            logits, cache = decode(params, cache, {"token": tok})
            tok = jnp.argmax(logits, axis=-1)
        return {"prompt_len": S, "tokens": out}

    return reg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("serpytor-demo-100m"), name="serve-demo",
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
        d_ff=1024, vocab_size=8192)
    model = build(cfg)
    params, _ = model.init(jax.random.key(0))
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.workers} workers")

    workers = [InProcWorker(f"w{i}",
                            make_worker_registry(cfg, params, model,
                                                 args.new_tokens))
               for i in range(args.workers)]
    rng = np.random.default_rng(0)
    t0 = time.time()
    with Gateway(workers, allocation=("context_affinity", "least_loaded")) as gw:
        futs = []
        for i in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=args.prompt_len).tolist()
            futs.append(gw.submit(
                "generate", Context.origin({"session": f"s{i % 4}"}),
                {"prompt": prompt, "new_tokens": args.new_tokens},
                affinity_key=f"s{i % 4}"))
        outs = [f.result(timeout=600) for f in futs]
    wall = time.time() - t0
    total_new = sum(len(o["tokens"]) for o in outs)
    print(f"{args.requests} requests, {total_new} tokens in {wall:.2f}s "
          f"({total_new/wall:.1f} tok/s)")
    print(f"gateway: scheduled={gw.metrics['scheduled']} "
          f"alloc={gw.mean_alloc_us():.1f}µs/decision")
    per_worker = {h.name: h.completed for h in gw.handles}
    print("per-worker:", per_worker)
    print("sample generation:", outs[0]["tokens"][:10])


if __name__ == "__main__":
    main()
