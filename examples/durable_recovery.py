"""Durable-execution demo: crash a training run, restart, prove continuity.

 1. trains with checkpoints every 5 steps, hard-"crashes" at step 12
 2. restarts in the same run_dir: the trainer restores the step-10 snapshot
    and replays 10-11 deterministically before continuing
 3. verifies the resumed trajectory equals an uninterrupted reference run
    (bitwise data determinism + journal digest verification)

Run:  PYTHONPATH=src python examples/durable_recovery.py [--base-dir DIR]

Writes to a throwaway temp directory by default; pass --base-dir (or set
SERPYTOR_DEMO_DIR) to keep the journals/checkpoints somewhere inspectable.
"""
import argparse
import dataclasses
import os
import shutil
import tempfile


from repro.configs import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer

CFG = dataclasses.replace(
    get_config("serpytor-demo-100m"), name="recovery-demo",
    num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=512, vocab_size=4096)


def tc(run_dir: str, steps: int) -> TrainConfig:
    return TrainConfig(run_dir=run_dir, num_steps=steps, checkpoint_every=5,
                       log_every=5, global_batch=2, seq_len=64,
                       heartbeat=False, journal_sync="always",
                       opt=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=20))


class CrashAt(Exception):
    pass


def main(base_dir: str = "") -> None:
    base = base_dir or os.environ.get("SERPYTOR_DEMO_DIR") or ""
    ephemeral = not base
    if ephemeral:
        base = tempfile.mkdtemp(prefix="serpytor-recovery-")
    try:
        _run_demo(base)
    finally:
        if ephemeral:  # throwaway means throwaway: don't leak ~100 MB in /tmp
            shutil.rmtree(base, ignore_errors=True)


def _run_demo(base: str) -> None:
    demo_dir = os.path.join(base, "recovery_demo")
    ref_dir = os.path.join(base, "recovery_ref")
    print(f"run artifacts under: {base}")
    for d in (demo_dir, ref_dir):
        shutil.rmtree(d, ignore_errors=True)

    print("=== reference run (uninterrupted, 20 steps) ===")
    ref = Trainer(CFG, tc(ref_dir, 20))
    ref.train()
    ref_losses = {m["step"]: m["loss"] for m in ref.metrics_log}

    print("\n=== run A: crash after step 11 ===")
    crash = Trainer(CFG, tc(demo_dir, 20))
    orig = crash._train_step

    def crashing_step(params, opt_state, batch):
        out = orig(params, opt_state, batch)
        if int(out[1]["step"][()]) > 12:   # opt step counter
            raise CrashAt("simulated node failure (power loss)")
        return out

    crash._train_step = crashing_step
    try:
        crash.train()
    except Exception as e:
        print(f"!! crashed as planned: {type(e).__name__}: {e}")
    finally:
        crash.store.wait()
        crash.journal.close()

    print("\n=== run B: restart in the same run_dir ===")
    resumed = Trainer(CFG, tc(demo_dir, 20))
    print("latest snapshot:", resumed.store.latest())
    resumed.train()
    got = {m["step"]: m["loss"] for m in resumed.metrics_log}

    print("\n=== verification ===")
    diffs = [abs(got[s] - ref_losses[s]) for s in got]
    print(f"resumed steps {sorted(got)[0]}..{sorted(got)[-1]}; "
          f"max |loss - reference| = {max(diffs):.2e}")
    ok = max(diffs) < 1e-4
    print("DURABLE RECOVERY:", "VERIFIED ✓" if ok else "MISMATCH ✗")
    assert ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description="durable-recovery demo")
    ap.add_argument("--base-dir", default="",
                    help="where to write run artifacts (default: a fresh tempdir)")
    main(ap.parse_args().base_dir)
