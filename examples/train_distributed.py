"""End-to-end driver: data-parallel training on the cluster substrate.

Each training step fans out per-shard gradient tasks across gateway workers
(in-proc here; ``WorkerServer`` hosts in production), reduces them, applies
the optimizer update, and journals everything — kill the process mid-run and
re-launch with the same ``--run-dir`` to watch it resume bit-identically.

Pass ``--kill-worker`` to crash one worker mid-round and watch the gateway
requeue its orphaned shard on the survivors (the run still converges to the
same params as an undisturbed one — compare the printed digest).

Run:  PYTHONPATH=src python examples/train_distributed.py --steps 8
      PYTHONPATH=src python examples/train_distributed.py --steps 8 --kill-worker
"""

import argparse
import dataclasses
import os
import tempfile

from repro.configs import get_config, smoke_variant
from repro.core import FlakyWorker, InProcWorker, Journal
from repro.optim.adamw import AdamWConfig
from repro.train import DistTrainConfig, DistributedTrainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--run-dir", default="")
    ap.add_argument(
        "--kill-worker",
        action="store_true",
        help="crash one worker mid-round (elastic re-shard demo)",
    )
    args = ap.parse_args()

    run_dir = args.run_dir or os.path.join(
        tempfile.gettempdir(), "serpytor-train-distributed"
    )
    cfg = smoke_variant(get_config("serpytor-demo-100m"))
    cfg = dataclasses.replace(cfg, name="serpytor-demo-smoke")
    tc = DistTrainConfig(
        run_dir=run_dir,
        num_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        log_every=1,
        global_batch=args.shards,
        seq_len=32,
        journal_sync="batch",
        heartbeat=False,
        num_shards=args.shards,
        num_workers=args.workers,
        opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=args.steps),
    )
    trainer = DistributedTrainer(cfg, tc)
    if args.kill_worker:
        trainer.workers = [
            FlakyWorker(
                "w0", trainer.registry, kill_after_starts=2, max_concurrency=1
            )
        ] + [
            InProcWorker(f"w{i}", trainer.registry, max_concurrency=1)
            for i in range(1, args.workers)
        ]

    print(
        f"arch={cfg.name} shards={args.shards} workers={args.workers} "
        f"run_dir={run_dir}"
    )
    out = trainer.train()
    digest = trainer.store.manifest(trainer.store.latest())["digest"]
    kinds = Journal(os.path.join(run_dir, "journal.wal"), sync="never").kinds()
    print(
        f"done: {out['steps']} steps in {out['wall_s']:.1f}s, "
        f"final loss {out['final_loss']:.4f}"
    )
    print(f"final params digest: {digest}")
    print(f"journal kinds: {kinds}")
    if kinds.get("NODE_REQUEUE"):
        print(
            f"elastic re-shard: {kinds['NODE_REQUEUE']} orphaned shard task(s) "
            "absorbed by surviving workers"
        )


if __name__ == "__main__":
    main()
