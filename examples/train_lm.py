"""End-to-end driver: train a decoder LM with the SerPyTor durable trainer.

The run is orchestrated as durable context-graph rounds (data → step →
checkpoint), journaled, resumable with `--resume`, heartbeat-monitored.

Default preset is CPU-sized (this container has one core); `--preset demo100m`
selects the paper-demo ~100M config used on real hardware.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
      PYTHONPATH=src python examples/train_lm.py --preset demo100m --steps 300
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer

PRESETS = {
    # ~10M params: a few hundred steps in minutes on one CPU core
    "small": lambda: dataclasses.replace(
        get_config("serpytor-demo-100m"), name="serpytor-demo-10m",
        num_layers=6, d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
        d_ff=1024, vocab_size=8192),
    # the paper-demo ~100M config (for real hardware / longer CPU runs)
    "demo100m": lambda: get_config("serpytor-demo-100m"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--run-dir", default="runs/train_lm")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--journal-sync", default="batch",
                    choices=["always", "batch", "never"])
    args = ap.parse_args()

    cfg = PRESETS[args.preset]()
    n = cfg.param_count()
    print(f"arch={cfg.name} params={n/1e6:.1f}M steps={args.steps} "
          f"batch={args.batch}x{args.seq}")

    tc = TrainConfig(
        run_dir=args.run_dir, num_steps=args.steps,
        checkpoint_every=args.checkpoint_every, log_every=10,
        global_batch=args.batch, seq_len=args.seq,
        journal_sync=args.journal_sync,
        opt=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps))
    out = Trainer(cfg, tc).train()
    print(f"done: {out['steps']} steps in {out['wall_s']:.1f}s "
          f"({out['steps_per_s']:.2f} steps/s), final loss "
          f"{out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
