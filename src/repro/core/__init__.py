"""SerPyTor core: context-aware durable computational-graph execution.

The paper's contribution, realized for JAX/TPU clusters. See DESIGN.md §2-3.
"""

from .aio import AsyncGateway, AsyncWorkerClient, AsyncWorkerServer, ShardedGateway
from .context import EMPTY_CONTEXT, Context, ContextEntry, canonical_digest
from .durable import (
    KNOWN_KINDS,
    Interrupted,
    Journal,
    JournalRecord,
    ReplayCache,
    atomic_task,
    decode_payload,
    encode_payload,
    interrupt,
    payload_digest,
)
from .executor import ClusterExecutor, ExecutionReport, LocalExecutor, WithContext
from .failure import FailureKind, LivenessDetector, RetryPolicy, StragglerWatch, Verdict
from .gateway import (
    AllocationError,
    Gateway,
    TaskCancelled,
    TaskRequest,
    WorkerHandle,
    context_affinity,
    least_loaded,
    power_of_two,
    round_robin,
)
from .graph import ContextGraph, CycleError, Node, UnionNode, toposort_levels
from .heartbeat import HeartbeatServer, check_heartbeat, check_heartbeat_async, telemetry
from .server import (
    FlakyWorker,
    InProcWorker,
    TaskRegistry,
    WorkerClient,
    WorkerServer,
    WorkerStreamError,
)

__all__ = [
    "Context",
    "ContextEntry",
    "EMPTY_CONTEXT",
    "canonical_digest",
    "Journal",
    "JournalRecord",
    "KNOWN_KINDS",
    "ReplayCache",
    "Interrupted",
    "interrupt",
    "atomic_task",
    "encode_payload",
    "decode_payload",
    "payload_digest",
    "LocalExecutor",
    "ClusterExecutor",
    "ExecutionReport",
    "WithContext",
    "FailureKind",
    "Verdict",
    "LivenessDetector",
    "RetryPolicy",
    "StragglerWatch",
    "Gateway",
    "TaskRequest",
    "WorkerHandle",
    "AllocationError",
    "TaskCancelled",
    "round_robin",
    "least_loaded",
    "power_of_two",
    "context_affinity",
    "ContextGraph",
    "Node",
    "UnionNode",
    "CycleError",
    "toposort_levels",
    "HeartbeatServer",
    "check_heartbeat",
    "check_heartbeat_async",
    "telemetry",
    "AsyncGateway",
    "AsyncWorkerClient",
    "AsyncWorkerServer",
    "ShardedGateway",
    "TaskRegistry",
    "WorkerServer",
    "WorkerClient",
    "InProcWorker",
    "FlakyWorker",
    "WorkerStreamError",
]
