"""Gateway (§3.3): the central authoritative scheduler.

The gateway stores the context for its servers, queues tasks (single-level
queue or a priority "queue silo"), and picks the optimal worker with an
allocation algorithm. Allocation must be fast — the paper warns (§5) that
gateway bottlenecks magnify at scale — so every built-in algorithm is O(1)
or O(log n) per decision, and decisions use *cached* heartbeat telemetry
refreshed by a background poller rather than a synchronous probe per task.

Fallback chain: if an algorithm raises or returns no worker, the next one in
the chain is consulted; the terminal fallback is round-robin over live
workers — graceful degradation, never a hard stop from the scheduler itself.
"""
from __future__ import annotations

import heapq
import itertools
import random
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .context import Context, EMPTY_CONTEXT

__all__ = ["TaskRequest", "WorkerHandle", "AllocationError", "Gateway",
           "round_robin", "least_loaded", "power_of_two", "context_affinity"]


class AllocationError(RuntimeError):
    pass


@dataclass
class TaskRequest:
    task_name: str
    ctx: Context = EMPTY_CONTEXT
    inputs: Mapping[str, Any] = field(default_factory=dict)
    priority: int = 0                  # lower = more urgent (silo key)
    affinity_key: str = ""             # context-affinity routing hint
    future: Future = field(default_factory=Future)
    submitted_at: float = field(default_factory=time.time)
    attempts: int = 0
    max_attempts: int = 3


@dataclass
class WorkerHandle:
    """Gateway-side view of a Server: transport + cached telemetry (context)."""

    worker: Any                        # InProcWorker | WorkerClient surface
    name: str
    live: bool = True                  # heartbeat verdict (system level)
    app_live: bool = True              # application verdict
    telemetry: Optional[Dict[str, Any]] = None
    last_seen: float = 0.0
    inflight: int = 0
    completed: int = 0
    ewma_latency_s: float = 0.0        # straggler detection input
    held_contexts: set = field(default_factory=set)  # affinity state

    def load_score(self) -> float:
        """Cheap load proxy: inflight + reported cpu usage."""
        cpu = 0.0
        if self.telemetry:
            cpu = float(self.telemetry.get("cpu", {}).get("used_frac", 0.0))
        return self.inflight + cpu


# --------------------------------------------------------------------------
# allocation algorithms (pluggable, §3.3 assumption 3)
# --------------------------------------------------------------------------

def round_robin(workers: Sequence[WorkerHandle], req: TaskRequest,
                state: Dict[str, Any]) -> Optional[WorkerHandle]:
    live = [w for w in workers if w.live and w.app_live]
    if not live:
        return None
    i = state.setdefault("rr", itertools.count())
    return live[next(i) % len(live)]


def least_loaded(workers: Sequence[WorkerHandle], req: TaskRequest,
                 state: Dict[str, Any]) -> Optional[WorkerHandle]:
    live = [w for w in workers if w.live and w.app_live]
    if not live:
        return None
    return min(live, key=lambda w: (w.load_score(), w.name))


def power_of_two(workers: Sequence[WorkerHandle], req: TaskRequest,
                 state: Dict[str, Any]) -> Optional[WorkerHandle]:
    """Power-of-two-choices: O(1) with near-least-loaded quality."""
    live = [w for w in workers if w.live and w.app_live]
    if not live:
        return None
    rng: random.Random = state.setdefault("rng", random.Random(0))
    a, b = rng.choice(live), rng.choice(live)
    return min((a, b), key=lambda w: (w.load_score(), w.name))


def context_affinity(workers: Sequence[WorkerHandle], req: TaskRequest,
                     state: Dict[str, Any]) -> Optional[WorkerHandle]:
    """Prefer the worker already holding the task's context (sharded state)."""
    if not req.affinity_key:
        return None  # fall through the chain
    live = [w for w in workers if w.live and w.app_live]
    holders = [w for w in live if req.affinity_key in w.held_contexts]
    if holders:
        return min(holders, key=lambda w: (w.load_score(), w.name))
    return None


_ALGOS: Dict[str, Callable] = {
    "round_robin": round_robin,
    "least_loaded": least_loaded,
    "power_of_two": power_of_two,
    "context_affinity": context_affinity,
}


class Gateway:
    """Central task router with queue/queue-silo + allocation fallback chain."""

    def __init__(self, workers: Sequence[Any], *,
                 allocation: Sequence[str] = ("context_affinity", "least_loaded"),
                 silo: bool = False,
                 heartbeat_interval_s: float = 0.5,
                 dispatch_threads: int = 8,
                 name: str = "gateway"):
        self.name = name
        self.handles: List[WorkerHandle] = [
            WorkerHandle(worker=w, name=getattr(w, "name", f"w{i}"))
            for i, w in enumerate(workers)
        ]
        chain = [(_ALGOS[a] if isinstance(a, str) else a) for a in allocation]
        if round_robin not in chain:
            chain.append(round_robin)  # terminal graceful-degradation fallback
        self.allocation_chain = chain
        self._alloc_state: Dict[str, Any] = {}
        self.silo = silo
        self._queue: deque = deque()
        self._silo: List[Tuple[int, int, TaskRequest]] = []  # heap
        self._silo_counter = itertools.count()
        self._cv = threading.Condition()
        self._stop = threading.Event()
        self._hb_interval = heartbeat_interval_s
        self._threads: List[threading.Thread] = []
        self._dispatch_threads = dispatch_threads
        self.on_worker_down: Optional[Callable[[WorkerHandle], None]] = None
        self.metrics = {"scheduled": 0, "rejected": 0, "requeued": 0,
                        "alloc_ns_total": 0, "alloc_calls": 0}

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Gateway":
        hb = threading.Thread(target=self._heartbeat_loop, name=f"{self.name}:hb",
                              daemon=True)
        hb.start()
        self._threads.append(hb)
        for i in range(self._dispatch_threads):
            t = threading.Thread(target=self._dispatch_loop,
                                 name=f"{self.name}:dispatch{i}", daemon=True)
            t.start()
            self._threads.append(t)
        self._refresh_heartbeats()  # synchronous first pass: start with fresh context
        return self

    def stop(self) -> None:
        self._stop.set()
        with self._cv:
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=2)

    def __enter__(self) -> "Gateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ------------------------------------------------------------
    def submit(self, task_name: str, ctx: Context = EMPTY_CONTEXT,
               inputs: Optional[Mapping[str, Any]] = None, *, priority: int = 0,
               affinity_key: str = "", max_attempts: int = 3) -> Future:
        req = TaskRequest(task_name=task_name, ctx=ctx, inputs=dict(inputs or {}),
                          priority=priority, affinity_key=affinity_key,
                          max_attempts=max_attempts)
        with self._cv:
            if self.silo:
                heapq.heappush(self._silo, (priority, next(self._silo_counter), req))
            else:
                self._queue.append(req)
            self._cv.notify()
        return req.future

    def map(self, task_name: str, inputs_list: Sequence[Mapping[str, Any]],
            ctx: Context = EMPTY_CONTEXT, **kw) -> List[Future]:
        return [self.submit(task_name, ctx, inp, **kw) for inp in inputs_list]

    # -- internals ------------------------------------------------------------
    def _pop(self, timeout: float = 0.1) -> Optional[TaskRequest]:
        with self._cv:
            if not self._queue and not self._silo:
                self._cv.wait(timeout)
            if self.silo and self._silo:
                return heapq.heappop(self._silo)[2]
            if self._queue:
                return self._queue.popleft()
        return None

    def _allocate(self, req: TaskRequest) -> Optional[WorkerHandle]:
        t0 = time.perf_counter_ns()
        try:
            for algo in self.allocation_chain:
                try:
                    w = algo(self.handles, req, self._alloc_state)
                except Exception:
                    continue  # fallback on algorithm failure (§3.3)
                if w is not None:
                    return w
            return None
        finally:
            self.metrics["alloc_ns_total"] += time.perf_counter_ns() - t0
            self.metrics["alloc_calls"] += 1

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            req = self._pop()
            if req is None:
                continue
            handle = self._allocate(req)
            if handle is None:
                # no live workers: retry later rather than dropping (degrade)
                time.sleep(0.05)
                req.attempts += 1
                if req.attempts >= req.max_attempts * 4:
                    req.future.set_exception(
                        AllocationError("no live workers available"))
                    self.metrics["rejected"] += 1
                else:
                    self._resubmit(req)
                continue
            self._run_on(handle, req)

    def _resubmit(self, req: TaskRequest) -> None:
        with self._cv:
            if self.silo:
                heapq.heappush(self._silo, (req.priority, next(self._silo_counter), req))
            else:
                self._queue.append(req)
            self._cv.notify()
        self.metrics["requeued"] += 1

    def _run_on(self, handle: WorkerHandle, req: TaskRequest) -> None:
        handle.inflight += 1
        t0 = time.time()
        try:
            result = handle.worker.run_task(req.task_name, req.ctx, req.inputs)
        except ConnectionError:
            # system-level failure: mark dead, requeue elsewhere
            handle.live = False
            handle.inflight -= 1
            if self.on_worker_down:
                self.on_worker_down(handle)
            req.attempts += 1
            if req.attempts >= req.max_attempts:
                req.future.set_exception(AllocationError(
                    f"task {req.task_name} exhausted retries (system failures)"))
            else:
                self._resubmit(req)
            return
        except TimeoutError as exc:
            # application-level failure: heartbeat may still be fine
            handle.app_live = False
            handle.inflight -= 1
            req.attempts += 1
            if req.attempts >= req.max_attempts:
                req.future.set_exception(exc)
            else:
                self._resubmit(req)
            return
        dt = time.time() - t0
        handle.inflight -= 1
        handle.completed += 1
        handle.ewma_latency_s = (0.8 * handle.ewma_latency_s + 0.2 * dt
                                 if handle.ewma_latency_s else dt)
        if req.affinity_key:
            handle.held_contexts.add(req.affinity_key)
        self.metrics["scheduled"] += 1
        status = result.get("status")
        if status == "ok":
            if not req.future.done():  # speculative duplicates race benignly
                req.future.set_result(result["output"])
        elif status == "rejected":
            req.future.set_exception(PermissionError(result.get("reason", "rejected")))
            self.metrics["rejected"] += 1
        else:
            req.attempts += 1
            if req.attempts >= req.max_attempts:
                if not req.future.done():
                    req.future.set_exception(
                        RuntimeError(result.get("error", "task failed")))
            else:
                self._resubmit(req)

    def _refresh_heartbeats(self) -> None:
        for h in self.handles:
            tel = None
            try:
                tel = h.worker.heartbeat()
            except Exception:
                tel = None
            was_live = h.live
            h.live = tel is not None
            h.telemetry = tel
            h.last_seen = time.time() if tel else h.last_seen
            if tel is not None:
                h.app_live = getattr(h.worker, "app_alive", True)
            if was_live and not h.live and self.on_worker_down:
                self.on_worker_down(h)

    def _heartbeat_loop(self) -> None:
        while not self._stop.is_set():
            self._refresh_heartbeats()
            self._stop.wait(self._hb_interval)

    # -- introspection ----------------------------------------------------------
    def cluster_context(self) -> Context:
        """The gateway 'stores the context required for the associated Servers'."""
        facts = {}
        for h in self.handles:
            facts[f"worker/{h.name}/live"] = h.live
            facts[f"worker/{h.name}/app_live"] = h.app_live
            facts[f"worker/{h.name}/completed"] = h.completed
            if h.telemetry:
                facts[f"worker/{h.name}/cpu"] = h.telemetry["cpu"]["used_frac"]
        return Context.origin(facts, origin=self.name)

    def live_workers(self) -> List[WorkerHandle]:
        return [h for h in self.handles if h.live and h.app_live]

    def mean_alloc_us(self) -> float:
        calls = max(1, self.metrics["alloc_calls"])
        return self.metrics["alloc_ns_total"] / calls / 1e3
