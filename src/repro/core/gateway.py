"""Gateway (§3.3): the central authoritative scheduler.

The gateway stores the context for its servers, queues tasks (single-level
queue or a priority "queue silo"), and picks the optimal worker with an
allocation algorithm. Allocation must be fast — the paper warns (§5) that
gateway bottlenecks magnify at scale — so every built-in algorithm is O(1)
or O(log n) per decision, and decisions use *cached* heartbeat telemetry
refreshed by a background poller rather than a synchronous probe per task.

Fallback chain: if an algorithm raises or returns no worker, the next one in
the chain is consulted; the terminal fallback is round-robin over live
workers — graceful degradation, never a hard stop from the scheduler itself.
"""

from __future__ import annotations

import heapq
import itertools
import os
import random
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs.trace import extract_trace, get_tracer
from repro.wire import PayloadDecodeError, unwrap_digested

from .context import Context, EMPTY_CONTEXT
from .durable import Interrupted

__all__ = [
    "TaskRequest",
    "WorkerHandle",
    "AllocationError",
    "TaskCancelled",
    "Gateway",
    "round_robin",
    "least_loaded",
    "power_of_two",
    "context_affinity",
]


class AllocationError(RuntimeError):
    """No worker could (ever) take the request — retries/backoffs exhausted."""


class TaskCancelled(RuntimeError):
    """A queued request was withdrawn by ``cancel_run`` before dispatch.

    Benign by contract: the submitting executor treats it as "this node
    returns to the pending frontier", never as a task failure.
    """


@dataclass
class TaskRequest:
    """One queued unit of work: task name, context, inputs, routing hints."""

    task_name: str
    ctx: Context = EMPTY_CONTEXT
    inputs: Mapping[str, Any] = field(default_factory=dict)
    priority: int = 0  # lower = more urgent (silo key)
    affinity_key: str = ""  # context-affinity routing hint
    future: Future = field(default_factory=Future)
    submitted_at: float = field(default_factory=time.time)
    attempts: int = 0  # failure budget: real execution failures/evictions
    backoffs: int = 0  # empty-pool waits — NOT charged to the budget
    max_attempts: int = 3
    meta: Dict[str, Any] = field(default_factory=dict)  # caller attribution
    last_error: Optional[BaseException] = None  # surfaced if backoffs exhaust


@dataclass
class WorkerHandle:
    """Gateway-side view of a Server: transport + cached telemetry (context)."""

    worker: Any  # InProcWorker | WorkerClient surface
    name: str
    live: bool = True  # heartbeat verdict (system level)
    app_live: bool = True  # application verdict
    telemetry: Optional[Dict[str, Any]] = None
    last_seen: float = 0.0  # monotonic stamp of the last successful probe
    inflight: int = 0
    completed: int = 0
    ewma_latency_s: float = 0.0  # straggler detection input (monotonic deltas)
    held_contexts: set = field(default_factory=set)  # affinity state
    hb_misses: int = 0  # consecutive failed heartbeat probes
    app_quarantined_until: float = 0.0  # monotonic deadline for app_live self-heal
    inflight_reqs: Dict[int, "TaskRequest"] = field(default_factory=dict)
    # ^ id(req) → req for every request currently running on this worker;
    #   the eviction path drains it to requeue orphans on survivors.

    def load_score(self) -> float:
        """Cheap load proxy: inflight + reported cpu usage."""
        cpu = 0.0
        if self.telemetry:
            cpu = float(self.telemetry.get("cpu", {}).get("used_frac", 0.0))
        return self.inflight + cpu


# --------------------------------------------------------------------------
# allocation algorithms (pluggable, §3.3 assumption 3)
# --------------------------------------------------------------------------


def round_robin(
    workers: Sequence[WorkerHandle], req: TaskRequest, state: Dict[str, Any]
) -> Optional[WorkerHandle]:
    """Cycle over live workers — the terminal graceful-degradation fallback."""
    live = [w for w in workers if w.live and w.app_live]
    if not live:
        return None
    i = state.setdefault("rr", itertools.count())
    return live[next(i) % len(live)]


def least_loaded(
    workers: Sequence[WorkerHandle], req: TaskRequest, state: Dict[str, Any]
) -> Optional[WorkerHandle]:
    """Pick the live worker with the lowest (inflight + cpu) load score."""
    live = [w for w in workers if w.live and w.app_live]
    if not live:
        return None
    return min(live, key=lambda w: (w.load_score(), w.name))


def power_of_two(
    workers: Sequence[WorkerHandle], req: TaskRequest, state: Dict[str, Any]
) -> Optional[WorkerHandle]:
    """Power-of-two-choices: O(1) with near-least-loaded quality."""
    live = [w for w in workers if w.live and w.app_live]
    if not live:
        return None
    rng: random.Random = state.setdefault("rng", random.Random(0))
    a, b = rng.choice(live), rng.choice(live)
    return min((a, b), key=lambda w: (w.load_score(), w.name))


def context_affinity(
    workers: Sequence[WorkerHandle], req: TaskRequest, state: Dict[str, Any]
) -> Optional[WorkerHandle]:
    """Prefer the worker already holding the task's context (sharded state)."""
    if not req.affinity_key:
        return None  # fall through the chain
    live = [w for w in workers if w.live and w.app_live]
    holders = [w for w in live if req.affinity_key in w.held_contexts]
    if holders:
        return min(holders, key=lambda w: (w.load_score(), w.name))
    return None


_ALGOS: Dict[str, Callable] = {
    "round_robin": round_robin,
    "least_loaded": least_loaded,
    "power_of_two": power_of_two,
    "context_affinity": context_affinity,
}


class Gateway:
    """Central task router with queue/queue-silo + allocation fallback chain.

    Two runtimes share this class's semantics: the default thread-per-request
    runtime implemented here, and the asyncio runtime in
    :mod:`repro.core.aio` (an event-loop pump on a dedicated thread behind
    the same blocking API). Setting ``REPRO_RUNTIME=async`` makes plain
    ``Gateway(...)`` construction transparently build the async subclass, so
    existing callers and tests exercise either runtime unmodified.
    """

    def __new__(cls, *args, **kw):
        """Dispatch to the asyncio runtime when ``REPRO_RUNTIME=async``."""
        if cls is Gateway and os.environ.get("REPRO_RUNTIME", "").lower() == "async":
            from .aio.gateway import AsyncGateway

            gw = AsyncGateway(*args, **kw)
            gw.__dispatched_init__ = True  # __init__ below must not run twice
            return gw
        return super().__new__(cls)

    def __init__(
        self,
        workers: Sequence[Any],
        *,
        allocation: Sequence[str] = ("context_affinity", "least_loaded"),
        silo: bool = False,
        heartbeat_interval_s: float = 0.5,
        dispatch_threads: int = 8,
        evict_after_misses: int = 2,
        quarantine_s: float = 2.0,
        name: str = "gateway",
    ):
        if getattr(self, "__dispatched_init__", False):
            return  # __new__ already ran the async subclass's full __init__
        self.name = name
        self.handles: List[WorkerHandle] = [
            WorkerHandle(worker=w, name=getattr(w, "name", f"w{i}"))
            for i, w in enumerate(workers)
        ]
        chain = [(_ALGOS[a] if isinstance(a, str) else a) for a in allocation]
        if round_robin not in chain:
            chain.append(round_robin)  # terminal graceful-degradation fallback
        self.allocation_chain = chain
        self._alloc_state: Dict[str, Any] = {}
        self.silo = silo
        self._queue: deque = deque()
        self._silo: List[Tuple[int, int, TaskRequest]] = []  # heap
        self._silo_counter = itertools.count()
        self._cv = threading.Condition()
        self._stop = threading.Event()
        self._hb_interval = heartbeat_interval_s
        self.evict_after_misses = evict_after_misses
        self.quarantine_s = quarantine_s
        self._threads: List[threading.Thread] = []
        self._dispatch_threads = dispatch_threads
        self._track_lock = threading.Lock()  # guards inflight counters/registries
        self.on_worker_down: Optional[Callable[[WorkerHandle], None]] = None
        self.on_requeue: Optional[Callable[[TaskRequest, str], None]] = None
        self.metrics = {
            "scheduled": 0,
            "rejected": 0,
            "requeued": 0,
            "evicted": 0,
            "corrupt": 0,
            "cancelled": 0,
            "alloc_ns_total": 0,
            "alloc_calls": 0,
        }
        self.suspended_runs: Dict[str, Dict[str, Any]] = {}  # run token → info
        self.crashed = False  # set by crash() — fault injection, not shutdown

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Gateway":
        """Start heartbeat + dispatch threads; probe workers once, synchronously."""
        hb = threading.Thread(target=self._heartbeat_loop, name=f"{self.name}:hb", daemon=True)
        hb.start()
        self._threads.append(hb)
        for i in range(self._dispatch_threads):
            t = threading.Thread(
                target=self._dispatch_loop, name=f"{self.name}:dispatch{i}", daemon=True
            )
            t.start()
            self._threads.append(t)
        self._refresh_heartbeats()  # synchronous first pass: start with fresh context
        return self

    def stop(self) -> None:
        """Signal every gateway thread to exit and join them (bounded wait)."""
        self._stop.set()
        with self._cv:
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=2)

    def crash(self) -> None:
        """Sudden-death simulation: halt dispatch/heartbeats WITHOUT draining.

        Unlike :meth:`stop` this is fault injection, not shutdown — queued
        requests stay unresolved and in-flight futures are left dangling,
        exactly as if the gateway process died. A :class:`~repro.core.aio.
        shards.ShardedGateway` detects the ``crashed`` flag and hands the
        replica's partition to a survivor via the shared journal.
        """
        self.crashed = True
        self.stop()

    def __enter__(self) -> "Gateway":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission ------------------------------------------------------------
    def submit(
        self,
        task_name: str,
        ctx: Context = EMPTY_CONTEXT,
        inputs: Optional[Mapping[str, Any]] = None,
        *,
        priority: int = 0,
        affinity_key: str = "",
        max_attempts: int = 3,
        meta: Optional[Mapping[str, Any]] = None,
    ) -> Future:
        """Enqueue one task for dispatch; returns the Future of its result.

        A streaming task (the worker's function is a generator) resolves its
        Future with a live chunk *iterator* instead of a value — see
        docs/streaming.md §5.

        ``Digested`` input wrappers (precomputed-digest hints from the
        executor's tensor path) are stripped here: workers and transports
        always see plain payload values.
        """
        req = TaskRequest(
            task_name=task_name,
            ctx=ctx,
            inputs=unwrap_digested(dict(inputs or {})),
            priority=priority,
            affinity_key=affinity_key,
            max_attempts=max_attempts,
            meta=dict(meta or {}),
        )
        with self._cv:
            if self.silo:
                heapq.heappush(self._silo, (priority, next(self._silo_counter), req))
            else:
                self._queue.append(req)
            self._cv.notify()
        return req.future

    def map(
        self,
        task_name: str,
        inputs_list: Sequence[Mapping[str, Any]],
        ctx: Context = EMPTY_CONTEXT,
        **kw,
    ) -> List[Future]:
        """Submit one task per input mapping; returns the Futures in order."""
        return [self.submit(task_name, ctx, inp, **kw) for inp in inputs_list]

    # -- run-level control (suspension) ---------------------------------------
    def cancel_run(self, run_token: str) -> int:
        """Withdraw every still-QUEUED request whose ``meta["run"]`` matches.

        Requests already handed to a worker are left to finish (a suspend is
        a clean drain, not an abort). Each withdrawn future fails with
        :class:`TaskCancelled`; returns the number withdrawn.
        """
        cancelled: List[TaskRequest] = []
        with self._cv:
            kept = deque()
            while self._queue:
                req = self._queue.popleft()
                (cancelled if req.meta.get("run") == run_token else kept).append(req)
            self._queue = kept
            kept_silo = []
            for entry in self._silo:
                if entry[2].meta.get("run") == run_token:
                    cancelled.append(entry[2])
                else:
                    kept_silo.append(entry)
            heapq.heapify(kept_silo)
            self._silo = kept_silo
        for req in cancelled:
            self.metrics["cancelled"] += 1
            self._fail(req, TaskCancelled(f"run {run_token} suspended"))
        return len(cancelled)

    def mark_suspended(self, run_token: str, interrupt: str) -> None:
        """Book a run as suspended at a named interrupt (shows up in stats())."""
        with self._track_lock:
            self.suspended_runs[run_token] = {
                "interrupt": interrupt,
                "since": time.time(),  # record timestamp
            }

    # -- internals ------------------------------------------------------------
    def _pop(self, timeout: float = 0.1) -> Optional[TaskRequest]:
        with self._cv:
            if not self._queue and not self._silo:
                self._cv.wait(timeout)
            if self.silo and self._silo:
                return heapq.heappop(self._silo)[2]
            if self._queue:
                return self._queue.popleft()
        return None

    def _allocate(self, req: TaskRequest) -> Optional[WorkerHandle]:
        t0 = time.perf_counter_ns()
        try:
            for algo in self.allocation_chain:
                try:
                    w = algo(self.handles, req, self._alloc_state)
                except Exception:
                    continue  # fallback on algorithm failure (§3.3)
                if w is not None:
                    return w
            return None
        finally:
            self.metrics["alloc_ns_total"] += time.perf_counter_ns() - t0
            self.metrics["alloc_calls"] += 1

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            req = self._pop()
            if req is None:
                continue
            handle = self._allocate(req)
            if handle is None:
                # no live workers: retry later rather than dropping (degrade).
                # Queue-waiting is not a task failure: it burns the separate
                # backoff budget, never req.attempts.
                time.sleep(0.05)
                req.backoffs += 1
                if req.backoffs >= req.max_attempts * 4:
                    # surface the request's own last failure (e.g. a typed
                    # PayloadDecodeError that quarantined every worker)
                    # rather than a generic allocation error
                    self._fail(
                        req,
                        req.last_error or AllocationError("no live workers available"),
                    )
                    self.metrics["rejected"] += 1
                else:
                    self._resubmit(req, "no live workers (backoff)", notify=False)
                continue
            self._run_on(handle, req)

    def _resubmit(self, req: TaskRequest, reason: str = "", *, notify: bool = True) -> None:
        with self._cv:
            if self.silo:
                heapq.heappush(self._silo, (req.priority, next(self._silo_counter), req))
            else:
                self._queue.append(req)
            self._cv.notify()
        self.metrics["requeued"] += 1
        if notify and self.on_requeue is not None:
            try:
                self.on_requeue(req, reason)
            except Exception:
                pass  # observer errors must not take down dispatch

    @staticmethod
    def _fail(req: TaskRequest, exc: BaseException) -> None:
        # a dispatch thread and the heartbeat eviction path may race to
        # resolve the same future; losing that race is benign (first wins)
        try:
            if not req.future.done():
                req.future.set_exception(exc)
        except InvalidStateError:
            pass

    @staticmethod
    def _resolve(req: TaskRequest, value: Any) -> None:
        try:
            if not req.future.done():  # speculative duplicates race benignly
                req.future.set_result(value)
        except InvalidStateError:
            pass

    def _release(self, handle: WorkerHandle, req: TaskRequest) -> bool:
        """Unregister a returned request; False ⇒ eviction already requeued it."""
        with self._track_lock:
            handle.inflight = max(0, handle.inflight - 1)
            return handle.inflight_reqs.pop(id(req), None) is not None

    def _evict(self, handle: WorkerHandle, reason: str) -> None:
        """Requeue every in-flight request of a dead worker on survivors.

        Consumes the heartbeat verdict: called when the monitor (or a
        system-level transport error) declares the worker dead. Orphaned
        requests are re-enqueued with their attempt count bumped; callers
        that registered ``on_requeue`` (the ClusterExecutor) journal each
        one. Idempotent — a request is drained exactly once.
        """
        with self._track_lock:
            orphans = list(handle.inflight_reqs.values())
            handle.inflight_reqs.clear()
        for req in orphans:
            if req.future.done():
                continue
            req.attempts += 1
            self.metrics["evicted"] += 1
            if req.attempts >= req.max_attempts:
                self._fail(
                    req,
                    AllocationError(
                        f"task {req.task_name} lost with evicted worker {handle.name}"
                    ),
                )
            else:
                self._resubmit(req, f"{reason}: evicted from {handle.name}")

    def _rpc_span(self, handle: WorkerHandle, req: TaskRequest):
        """Open the gateway→worker rpc span for ``req``, or None when off.

        Parent identity is read from the obs fact riding ``req.ctx`` — the
        same context that crosses the wire — so the span chain survives
        resubmission, speculation copies, and sharded-gateway handoffs.
        """
        tracer = get_tracer()
        if not tracer.enabled:
            return None
        parent = extract_trace(req.ctx)
        return tracer.start_span(
            f"rpc:{req.task_name}",
            trace_id=parent[0] if parent else "",
            parent_id=parent[1] if parent else "",
            kind="rpc",
            attrs={
                "worker": handle.name,
                "task": req.task_name,
                "node": str(req.meta.get("node", "")),
                "attempt": req.attempts,
            },
        )

    def _run_on(self, handle: WorkerHandle, req: TaskRequest) -> None:
        with self._track_lock:
            handle.inflight += 1
            handle.inflight_reqs[id(req)] = req
        span = self._rpc_span(handle, req)
        t0 = time.monotonic()  # interval math must survive wall-clock steps
        try:
            result = handle.worker.run_task(req.task_name, req.ctx, req.inputs)
        except (ConnectionError, TimeoutError, PayloadDecodeError) as exc:
            if span is not None:
                get_tracer().end(span, status="error", attrs={"error": type(exc).__name__})
            self._on_invoke_error(handle, req, exc)
            return
        if span is not None:
            get_tracer().end(span, status=str(result.get("status", "ok")))
        self._on_result(handle, req, result, time.monotonic() - t0)

    def _on_invoke_error(
        self, handle: WorkerHandle, req: TaskRequest, exc: BaseException
    ) -> None:
        """Shared failure taxonomy for a worker invocation (both runtimes).

        ``ConnectionError`` is a system-level failure: mark dead, requeue
        elsewhere. Siblings still executing on the handle are NOT evicted
        here — in-flight calls may yet succeed, and the heartbeat path
        (consecutive misses) recovers the truly-stuck ones without
        double-running the healthy ones. ``TimeoutError`` and
        ``PayloadDecodeError`` are application-level: heartbeat may still be
        fine, so the worker is quarantined rather than declared dead, and
        the request retries on a healthy worker with its typed last_error
        preserved.
        """
        if isinstance(exc, ConnectionError):
            owned = self._release(handle, req)
            with self._track_lock:
                was_live, handle.live = handle.live, False
            if was_live and self.on_worker_down:  # once per death, not per call
                self.on_worker_down(handle)
            if not owned:
                return  # heartbeat eviction already requeued this request
            req.attempts += 1
            if req.attempts >= req.max_attempts:
                self._fail(
                    req,
                    AllocationError(
                        f"task {req.task_name} exhausted retries (system failures)"
                    ),
                )
            else:
                self._resubmit(req, f"system failure on {handle.name}")
            return
        owned = self._release(handle, req)
        handle.app_live = False
        handle.app_quarantined_until = time.monotonic() + self.quarantine_s
        req.last_error = exc
        corrupt = isinstance(exc, PayloadDecodeError)
        if corrupt:
            self.metrics["corrupt"] += 1
        if not owned:
            return
        req.attempts += 1
        if req.attempts >= req.max_attempts:
            self._fail(req, exc)
        elif corrupt:
            self._resubmit(req, f"corrupt payload from {handle.name}")
        else:
            self._resubmit(req, f"application failure on {handle.name}")

    def _on_result(
        self, handle: WorkerHandle, req: TaskRequest, result: Mapping[str, Any], dt: float
    ) -> None:
        """Shared status-dict handling for a completed invocation (both runtimes)."""
        owned = self._release(handle, req)
        handle.completed += 1
        handle.ewma_latency_s = (
            0.8 * handle.ewma_latency_s + 0.2 * dt if handle.ewma_latency_s else dt
        )
        if req.affinity_key:
            handle.held_contexts.add(req.affinity_key)
        self.metrics["scheduled"] += 1
        status = result.get("status")
        if status == "ok":
            self._resolve(req, result["output"])
        elif status == "stream":
            # a stream-source task: the future resolves with the live chunk
            # iterator (chunk framing happens in the worker transport); the
            # consumer drives it and handles mid-stream failures by
            # re-dispatching from its last durable offset (streaming.md §5)
            self._resolve(req, result["stream"])
        elif status == "interrupt":
            # the task reached a named interrupt point: surface the typed
            # suspension request to the submitter — never retried, never
            # charged to the failure budget
            if not owned:
                return
            self._fail(
                req,
                Interrupted(str(result.get("name", "")), result.get("payload")),
            )
        elif status == "rejected":
            if not owned:
                return  # a requeued copy owns the outcome now
            self._fail(req, PermissionError(result.get("reason", "rejected")))
            self.metrics["rejected"] += 1
        else:
            if not owned:
                return  # already requeued by eviction; don't double-count
            req.attempts += 1
            if req.attempts >= req.max_attempts:
                self._fail(req, RuntimeError(result.get("error", "task failed")))
            else:
                self._resubmit(req, f"application error on {handle.name}")

    def _apply_probe(self, h: WorkerHandle, tel: Optional[Dict[str, Any]]) -> None:
        """Apply one heartbeat verdict to a handle (both runtimes).

        Liveness transition, telemetry/last_seen/miss bookkeeping, app-level
        self-heal, the once-per-death ``on_worker_down`` edge, and the
        consecutive-miss eviction threshold all live here so the asyncio
        prober shares the exact state machine of the threaded one.
        """
        with self._track_lock:  # transition must be atomic vs _run_on's
            was_live, h.live = h.live, tel is not None
        h.telemetry = tel
        # monotonic, not wall: last_seen feeds liveness-age math and must
        # not jump under NTP steps (clock policy, docs/static-analysis.md)
        h.last_seen = time.monotonic() if tel else h.last_seen
        h.hb_misses = 0 if tel is not None else h.hb_misses + 1
        if tel is not None:
            reported = getattr(h.worker, "app_alive", None)
            if reported is not None:
                h.app_live = reported  # the worker self-reports: trust it
            elif time.monotonic() >= h.app_quarantined_until:
                # workers without a self-report (HTTP transports) only
                # self-heal after the quarantine window — a corrupt-but-
                # alive worker must not re-enter rotation every probe
                h.app_live = True
        if was_live and not h.live and self.on_worker_down:
            self.on_worker_down(h)
        if not h.live and h.inflight_reqs and h.hb_misses >= self.evict_after_misses:
            # the heartbeat verdict drives recovery, not just routing —
            # but a single missed probe is routing-only (self-heals on the
            # next probe); eviction needs consecutive misses so one GC
            # pause or network blip can't charge the task failure budget
            self._evict(h, "heartbeat lost")

    def _refresh_heartbeats(self) -> None:
        for h in self.handles:
            tel = None
            t0 = time.perf_counter()
            try:
                tel = h.worker.heartbeat()
            except Exception:
                tel = None
            if tel is not None:
                # HTTP probes stamp their own RTT (check_heartbeat); stamp
                # in-proc workers with the gateway-measured probe time so
                # stats() always carries a probe_latency_s signal
                tel.setdefault("probe_latency_s", time.perf_counter() - t0)
            self._apply_probe(h, tel)

    def _heartbeat_loop(self) -> None:
        while not self._stop.is_set():
            self._refresh_heartbeats()
            self._stop.wait(self._hb_interval)

    # -- introspection ----------------------------------------------------------
    def cluster_context(self) -> Context:
        """The gateway 'stores the context required for the associated Servers'."""
        facts = {}
        for h in self.handles:
            facts[f"worker/{h.name}/live"] = h.live
            facts[f"worker/{h.name}/app_live"] = h.app_live
            facts[f"worker/{h.name}/completed"] = h.completed
            if h.telemetry:
                facts[f"worker/{h.name}/cpu"] = h.telemetry["cpu"]["used_frac"]
        return Context.origin(facts, origin=self.name)

    def live_workers(self) -> List[WorkerHandle]:
        """Workers currently passing both system and application liveness."""
        return [h for h in self.handles if h.live and h.app_live]

    def stats(self) -> Dict[str, Any]:
        """One coherent telemetry snapshot of the whole gateway.

        Per-worker liveness, inflight/completed counts, EWMA task latency,
        the last heartbeat's ``probe_latency_s``, plus queue/silo depths and
        the dispatch metrics — the inputs a stream-aware allocator needs
        (route a chunk stream to the worker with headroom AND a fast probe).
        """
        with self._cv:
            queue_depth = len(self._queue)
            silo_depth = len(self._silo)
        workers: Dict[str, Dict[str, Any]] = {}
        with self._track_lock:
            for h in self.handles:
                tel = h.telemetry or {}
                workers[h.name] = {
                    "live": h.live,
                    "app_live": h.app_live,
                    "inflight": h.inflight,
                    "completed": h.completed,
                    "hb_misses": h.hb_misses,
                    "ewma_latency_s": h.ewma_latency_s,
                    "probe_latency_s": float(tel.get("probe_latency_s", 0.0)),
                    # age, not a wall timestamp: last_seen is monotonic
                    "last_seen_age_s": (
                        max(0.0, time.monotonic() - h.last_seen) if h.last_seen else -1.0
                    ),
                    "held_contexts": len(h.held_contexts),
                }
        with self._track_lock:
            suspended = {k: dict(v) for k, v in self.suspended_runs.items()}
        return {
            "workers": workers,
            "queue_depth": queue_depth,
            "silo_depth": silo_depth,
            "suspended_runs": suspended,
            "live_workers": sum(1 for w in workers.values() if w["live"] and w["app_live"]),
            "metrics": dict(self.metrics),
            "mean_alloc_us": self.mean_alloc_us(),
        }

    def mean_alloc_us(self) -> float:
        """Mean allocation-decision latency in microseconds (§5 bottleneck gauge)."""
        calls = max(1, self.metrics["alloc_calls"])
        return self.metrics["alloc_ns_total"] / calls / 1e3
