"""Asyncio control-plane runtime: event-loop gateway, workers, and shards.

Everything here preserves the sync runtime's stage semantics — the same
allocation chain, failure taxonomy, journal kinds, and blocking public API —
while swapping threads-and-condition-variables for one event loop per
component. ``REPRO_RUNTIME=async`` routes plain ``Gateway(...)`` construction
to :class:`AsyncGateway`, so either runtime runs the whole existing test
suite unmodified.
"""

from .gateway import AsyncGateway
from .server import AsyncWorkerClient, AsyncWorkerServer
from .shards import ShardedGateway

__all__ = [
    "AsyncGateway",
    "AsyncWorkerClient",
    "AsyncWorkerServer",
    "ShardedGateway",
]
