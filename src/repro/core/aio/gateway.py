"""AsyncGateway: the asyncio control-plane runtime behind the sync Gateway API.

The threaded Gateway dedicates a pool of dispatch threads plus condition-
variable wakeups to pump the queue — a hard ceiling of a few hundred inflight
requests per host. This runtime replaces the pump with a single event loop on
a dedicated thread: one dispatcher coroutine pops and allocates, each worker
invocation is an asyncio task (bounded by a semaphore, not a thread), and
heartbeat probes fan out concurrently with ``asyncio.gather`` instead of a
serial walk. Workers exposing coroutine endpoints (``run_task_async`` /
``heartbeat_async`` on :class:`~repro.core.aio.server.AsyncWorkerClient`) are
awaited natively; plain sync workers are offloaded to a small thread pool so
both kinds interoperate behind one gateway.

The public surface is *identical* to the threaded Gateway — ``submit`` still
returns a ``concurrent.futures.Future``, ``stats``/``cancel_run``/
``mark_suspended`` are inherited unchanged — so the ClusterExecutor and every
existing test drive this runtime unmodified (``REPRO_RUNTIME=async``
dispatches plain ``Gateway(...)`` construction here). All scheduling policy
(allocation chain, failure taxonomy, eviction, quarantine) is shared with the
base class via ``_allocate`` / ``_on_invoke_error`` / ``_on_result`` /
``_apply_probe``; this module only swaps the concurrency substrate.
"""

from __future__ import annotations

import asyncio
import heapq
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

from repro.obs.trace import get_tracer
from repro.wire import PayloadDecodeError

from ..gateway import AllocationError, Gateway, TaskRequest, WorkerHandle

__all__ = ["AsyncGateway"]


class AsyncGateway(Gateway):
    """Event-loop gateway runtime: same semantics, coroutine concurrency.

    ``max_inflight_rpc`` bounds concurrently-outstanding worker invocations
    (asyncio tasks are cheap, so this is 256 versus the threaded runtime's
    8 dispatch threads); ``offload_threads`` sizes the pool that runs plain
    sync workers (in-proc test workers, legacy ``WorkerClient`` transports).
    """

    def __init__(
        self,
        *args: Any,
        max_inflight_rpc: int = 256,
        offload_threads: int = 32,
        **kw: Any,
    ):
        if getattr(self, "__dispatched_init__", False):
            return  # Gateway.__new__ already ran this constructor fully
        super().__init__(*args, **kw)
        self._max_rpc = max_inflight_rpc
        self._offload_threads = offload_threads
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._loop_thread: Optional[threading.Thread] = None
        self._offload: Optional[ThreadPoolExecutor] = None
        self._wake: Optional[asyncio.Event] = None
        self._stopped: Optional[asyncio.Event] = None
        self._rpc_sem: Optional[asyncio.Semaphore] = None

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "AsyncGateway":
        """Start the loop thread; probe workers once, synchronously."""
        ready = threading.Event()
        self._loop_thread = threading.Thread(
            target=self._loop_main, args=(ready,), name=f"{self.name}:aio", daemon=True
        )
        self._loop_thread.start()
        ready.wait()
        if self._loop is not None:
            # synchronous first heartbeat pass: start with fresh context,
            # exactly like the threaded runtime's start()
            asyncio.run_coroutine_threadsafe(self._probe_all(), self._loop).result()
        return self

    def stop(self) -> None:
        """Signal the loop to exit, join its thread, release the offload pool."""
        self._stop.set()
        loop = self._loop
        if loop is not None:
            try:
                loop.call_soon_threadsafe(self._signal_stop)
            except RuntimeError:
                pass  # loop already closed
        if self._loop_thread is not None:
            self._loop_thread.join(timeout=5)
        if self._offload is not None:
            self._offload.shutdown(wait=False, cancel_futures=True)

    def _signal_stop(self) -> None:
        if self._stopped is not None:
            self._stopped.set()
        if self._wake is not None:
            self._wake.set()

    def _loop_main(self, ready: threading.Event) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        try:
            loop.run_until_complete(self._main(ready))
        finally:
            pending = asyncio.all_tasks(loop)
            for task in pending:
                task.cancel()
            if pending:
                loop.run_until_complete(asyncio.gather(*pending, return_exceptions=True))
            loop.close()
            self._loop = None
            ready.set()  # never leave start() blocked if startup itself died

    async def _main(self, ready: threading.Event) -> None:
        self._wake = asyncio.Event()
        self._stopped = asyncio.Event()
        self._rpc_sem = asyncio.Semaphore(self._max_rpc)
        self._offload = ThreadPoolExecutor(
            max_workers=self._offload_threads, thread_name_prefix=f"{self.name}:offload"
        )
        pumps = [
            asyncio.create_task(self._dispatch_pump()),
            asyncio.create_task(self._heartbeat_pump()),
        ]
        ready.set()
        await self._stopped.wait()
        for pump in pumps:
            pump.cancel()
        await asyncio.gather(*pumps, return_exceptions=True)

    # -- submission ---------------------------------------------------------
    def submit(self, *args: Any, **kw: Any) -> Future:
        """Enqueue one task (thread-safe) and nudge the loop's dispatcher."""
        fut = super().submit(*args, **kw)
        self._nudge()
        return fut

    def _resubmit(self, req: TaskRequest, reason: str = "", *, notify: bool = True) -> None:
        super()._resubmit(req, reason, notify=notify)
        self._nudge()

    def _nudge(self) -> None:
        loop = self._loop
        if loop is None:
            return
        try:
            loop.call_soon_threadsafe(self._wake_event)
        except RuntimeError:
            pass  # loop shut down — a crashed replica leaves futures dangling

    def _wake_event(self) -> None:
        if self._wake is not None:
            self._wake.set()

    # -- dispatch -----------------------------------------------------------
    def _pop_nowait(self) -> Optional[TaskRequest]:
        with self._cv:
            if self.silo and self._silo:
                return heapq.heappop(self._silo)[2]
            if self._queue:
                return self._queue.popleft()
        return None

    async def _dispatch_pump(self) -> None:
        assert self._wake is not None and self._rpc_sem is not None
        while not self._stop.is_set():
            req = self._pop_nowait()
            if req is None:
                self._wake.clear()
                if self._queue or self._silo:
                    continue  # raced with a submit between pop and clear
                try:
                    await asyncio.wait_for(self._wake.wait(), timeout=0.1)
                except asyncio.TimeoutError:
                    pass
                continue
            handle = self._allocate(req)
            if handle is None:
                # no live workers: same degrade-not-drop policy as the
                # threaded runtime — burn the backoff budget, never attempts
                await asyncio.sleep(0.05)
                req.backoffs += 1
                if req.backoffs >= req.max_attempts * 4:
                    self._fail(
                        req,
                        req.last_error or AllocationError("no live workers available"),
                    )
                    self.metrics["rejected"] += 1
                else:
                    self._resubmit(req, "no live workers (backoff)", notify=False)
                continue
            # register inflight at ALLOCATION time, exactly like the threaded
            # runtime's _run_on: the pump drains a queued burst without
            # yielding, so deferring this into the spawned task would let the
            # whole burst allocate against stale inflight counts and pile onto
            # one worker (least_loaded ties always break the same way)
            with self._track_lock:
                handle.inflight += 1
                handle.inflight_reqs[id(req)] = req
            await self._rpc_sem.acquire()
            task = asyncio.create_task(self._run_on_async(handle, req))
            task.add_done_callback(lambda _t: self._rpc_sem.release())

    async def _run_on_async(self, handle: WorkerHandle, req: TaskRequest) -> None:
        span = self._rpc_span(handle, req)  # same span contract as _run_on
        t0 = time.monotonic()  # interval math must survive wall-clock steps
        try:
            result = await self._invoke(handle, req)
        except asyncio.CancelledError:
            raise
        except (ConnectionError, TimeoutError, PayloadDecodeError) as exc:
            if span is not None:
                get_tracer().end(span, status="error", attrs={"error": type(exc).__name__})
            self._on_invoke_error(handle, req, exc)
            return
        if span is not None:
            get_tracer().end(span, status=str(result.get("status", "ok")))
        self._on_result(handle, req, result, time.monotonic() - t0)

    async def _invoke(self, handle: WorkerHandle, req: TaskRequest) -> Dict[str, Any]:
        run_async = getattr(handle.worker, "run_task_async", None)
        if run_async is not None:
            return await run_async(req.task_name, req.ctx, req.inputs)
        return await asyncio.get_running_loop().run_in_executor(
            self._offload, handle.worker.run_task, req.task_name, req.ctx, req.inputs
        )

    # -- heartbeats ---------------------------------------------------------
    async def _heartbeat_pump(self) -> None:
        assert self._stopped is not None
        while not self._stop.is_set():
            try:
                await asyncio.wait_for(self._stopped.wait(), timeout=self._hb_interval)
                return
            except asyncio.TimeoutError:
                pass
            await self._probe_all()

    async def _probe_all(self) -> None:
        await asyncio.gather(*(self._probe_one(h) for h in self.handles))

    async def _probe_one(self, h: WorkerHandle) -> None:
        tel = None
        t0 = time.perf_counter()
        try:
            hb_async = getattr(h.worker, "heartbeat_async", None)
            if hb_async is not None:
                tel = await hb_async()
            else:
                tel = await asyncio.get_running_loop().run_in_executor(
                    self._offload, h.worker.heartbeat
                )
        except Exception:
            tel = None
        if tel is not None:
            # async HTTP probes stamp their own RTT; stamp offloaded in-proc
            # workers with the loop-measured probe time (same rule as sync)
            tel.setdefault("probe_latency_s", time.perf_counter() - t0)
        self._apply_probe(h, tel)
