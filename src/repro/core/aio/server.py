"""Async worker transport: asyncio HTTP server + client sessions (§3.2).

Same wire protocol as the threaded ``WorkerServer``/``WorkerClient`` pair —
msgpack-framed POST /task, GET /tasks, a separate heartbeat port, and
HTTP/1.1 chunked responses carrying crc-checked stream frames — rebuilt on
``asyncio.start_server``/``open_connection`` so one event loop multiplexes
thousands of concurrent connections instead of one thread per request. Task
*bodies* stay synchronous Python functions and run on a small offload pool;
only the transport is coroutine-native.

Interop contract with the sync world:

- :class:`AsyncWorkerClient` raises the same exception taxonomy as
  ``WorkerClient`` (connect/read failures ⇒ ``TimeoutError`` at the
  application level, undecodable answers ⇒ ``PayloadDecodeError``), so the
  gateway's failure handling is runtime-agnostic.
- A streaming response resolves to a plain *synchronous* chunk iterator: the
  consumer (an executor stream thread) pulls frames through
  :class:`_SyncStreamBridge`, which marshals each read onto the client's
  event loop — pull-based, so HTTP chunked transfer provides natural
  backpressure end to end.
"""

from __future__ import annotations

import asyncio
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Mapping, Optional, Tuple
from urllib.parse import urlsplit

from repro.wire import canonical_bytes, decode_payload, encode_frame, encode_payload

from ..context import Context
from ..heartbeat import check_heartbeat_async, telemetry
from ..server import (
    STREAM_CONTENT_TYPE,
    Middleware,
    TaskRegistry,
    _execute,
    _stream_values,
    _WorkerState,
)

__all__ = ["AsyncWorkerServer", "AsyncWorkerClient"]

_SENTINEL = object()  # exhausted-generator marker for offloaded next() calls


async def _read_head(
    reader: asyncio.StreamReader,
) -> Optional[Tuple[str, str, Dict[str, str]]]:
    """Parse one HTTP/1.1 request head: (method, path, lowercase headers)."""
    line = await reader.readline()
    if not line:
        return None
    parts = line.decode("latin-1").split()
    if len(parts) < 2:
        return None
    headers: Dict[str, str] = {}
    while True:
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            break
        key, _, value = raw.decode("latin-1").partition(":")
        headers[key.strip().lower()] = value.strip()
    return parts[0], parts[1], headers


async def _write_response(
    writer: asyncio.StreamWriter,
    status: int,
    body: bytes,
    content_type: str = "",
) -> None:
    reason = {200: "OK", 404: "Not Found"}.get(status, "Error")
    head = f"HTTP/1.1 {status} {reason}\r\nContent-Length: {len(body)}\r\n"
    if content_type:
        head += f"Content-Type: {content_type}\r\n"
    head += "Connection: close\r\n\r\n"
    writer.write(head.encode("latin-1") + body)
    await writer.drain()


class AsyncWorkerServer:
    """Application server + separate heartbeat server on one event loop.

    The two-port rule of §3.2 is preserved: the heartbeat listener is a
    distinct asyncio server on its own port, so :meth:`crash_application`
    (close ONLY the app listener) leaves the system-liveness signal up —
    the asymmetry the failure detector reads.
    """

    def __init__(
        self,
        name: str,
        registry: TaskRegistry,
        host: str = "127.0.0.1",
        port: int = 0,
        middleware: Optional[List[Middleware]] = None,
        offload_threads: int = 16,
    ):
        self.name = name
        self.registry = registry
        self.middleware = list(middleware or [])
        self.state = _WorkerState()
        self.host = host
        self.port = port  # rebound to the OS-assigned port at start()
        self.hb_port = 0
        self._offload_threads = offload_threads
        self._offload: Optional[ThreadPoolExecutor] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._stopped: Optional[asyncio.Event] = None
        self._app_server: Optional[asyncio.base_events.Server] = None
        self._hb_server: Optional[asyncio.base_events.Server] = None
        self._startup_error: Optional[BaseException] = None

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "AsyncWorkerServer":
        """Bind both listeners on a fresh loop thread; returns when bound."""
        ready = threading.Event()
        self._thread = threading.Thread(
            target=self._loop_main, args=(ready,), name=f"aioworker:{self.name}", daemon=True
        )
        self._thread.start()
        ready.wait()
        if self._startup_error is not None:
            raise RuntimeError(
                f"async worker {self.name} failed to start"
            ) from self._startup_error
        return self

    def stop(self, stop_heartbeat: bool = True) -> None:
        """Close listeners and join the loop thread (bounded wait)."""
        loop = self._loop
        if loop is not None:
            try:
                loop.call_soon_threadsafe(self._signal_stop, stop_heartbeat)
            except RuntimeError:
                pass
        if stop_heartbeat:
            if self._thread is not None:
                self._thread.join(timeout=5)
            if self._offload is not None:
                self._offload.shutdown(wait=False, cancel_futures=True)

    def crash_application(self) -> None:
        """Kill ONLY the app listener — heartbeat stays up (application-level)."""
        self.stop(stop_heartbeat=False)

    def _signal_stop(self, stop_heartbeat: bool) -> None:
        if self._app_server is not None:
            self._app_server.close()
            self._app_server = None
        if stop_heartbeat and self._stopped is not None:
            self._stopped.set()

    def _loop_main(self, ready: threading.Event) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        try:
            loop.run_until_complete(self._main(ready))
        except BaseException as exc:  # surface bind failures to start()
            self._startup_error = exc
        finally:
            pending = asyncio.all_tasks(loop)
            for task in pending:
                task.cancel()
            if pending:
                loop.run_until_complete(asyncio.gather(*pending, return_exceptions=True))
            loop.close()
            self._loop = None
            ready.set()

    async def _main(self, ready: threading.Event) -> None:
        self._stopped = asyncio.Event()
        self._offload = ThreadPoolExecutor(
            max_workers=self._offload_threads, thread_name_prefix=f"{self.name}:task"
        )
        self._app_server = await asyncio.start_server(self._handle_app, self.host, self.port)
        self._hb_server = await asyncio.start_server(self._handle_hb, self.host, 0)
        self.port = self._app_server.sockets[0].getsockname()[1]
        self.hb_port = self._hb_server.sockets[0].getsockname()[1]
        ready.set()
        await self._stopped.wait()
        for srv in (self._app_server, self._hb_server):
            if srv is not None:
                srv.close()
                await srv.wait_closed()
        self._app_server = self._hb_server = None

    @property
    def address(self) -> str:
        """The application endpoint URL (valid once started)."""
        return f"http://{self.host}:{self.port}"

    @property
    def heartbeat_address(self) -> str:
        """The separate heartbeat endpoint URL (valid once started)."""
        return f"http://{self.host}:{self.hb_port}"

    def __enter__(self) -> "AsyncWorkerServer":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    def client(self, timeout: float = 30.0) -> "AsyncWorkerClient":
        """An :class:`AsyncWorkerClient` wired to this server's two ports."""
        return AsyncWorkerClient(self.name, self.address, self.heartbeat_address, timeout)

    # -- handlers -----------------------------------------------------------
    async def _handle_app(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter
    ) -> None:
        loop = asyncio.get_running_loop()
        try:
            head = await _read_head(reader)
            if head is None:
                return
            method, path, headers = head
            path = path.rstrip("/") or "/"
            if method == "GET" and path == "/tasks":
                await _write_response(writer, 200, canonical_bytes(self.registry.names()))
                return
            if method != "POST" or path != "/task":
                await _write_response(writer, 404, b"not found", "text/plain")
                return
            length = int(headers.get("content-length", "0"))
            body = await reader.readexactly(length) if length else b""
            try:
                req = decode_payload(body)
                ctx = Context.from_wire(req["context"])
                # the task body is synchronous Python: run it on the offload
                # pool so a slow task never stalls the accept/transport loop
                result = await loop.run_in_executor(
                    self._offload,
                    _execute,
                    self.registry,
                    self.middleware,
                    self.state,
                    req["task"],
                    ctx,
                    req["inputs"],
                )
            except Exception as exc:  # malformed request
                result = {"status": "error", "error": str(exc)}
            if result.get("status") == "stream":
                await self._send_stream(writer, result)
                return
            await _write_response(
                writer, 200, encode_payload(result), "application/x-msgpack-zstd"
            )
        except (ConnectionError, asyncio.IncompleteReadError):
            pass  # client went away mid-request; nothing left to tell it
        finally:
            await _close_writer(writer)

    async def _send_stream(
        self, writer: asyncio.StreamWriter, result: Dict[str, Any]
    ) -> None:
        """Incremental chunk transport: one wire frame per produced chunk.

        Identical frame protocol to the threaded worker (docs/streaming.md
        §5): ``{"s": seq, "c": chunk}`` per chunk, terminal ``{"eos": n}``,
        ``{"err": msg}`` on a mid-stream task failure. The generator body is
        pulled chunk-by-chunk on the offload pool; each frame is drained
        before the next pull, so the event loop's write buffer — and behind
        it HTTP chunked transfer — provides pull-based backpressure.
        """
        loop = asyncio.get_running_loop()
        writer.write(
            (
                "HTTP/1.1 200 OK\r\n"
                f"Content-Type: {STREAM_CONTENT_TYPE}\r\n"
                "Transfer-Encoding: chunked\r\n"
                "Connection: close\r\n\r\n"
            ).encode("latin-1")
        )
        await writer.drain()

        async def emit(frame: bytes) -> None:
            writer.write(f"{len(frame):X}\r\n".encode("latin-1") + frame + b"\r\n")
            await writer.drain()

        seq = int(result.get("start", 0) or 0)
        state, gen = self.state, result["stream"]
        with state.lock:
            state.busy += 1  # the task body runs HERE, not in _execute
        try:
            while True:
                chunk = await loop.run_in_executor(self._offload, next, gen, _SENTINEL)
                if chunk is _SENTINEL:
                    break
                await emit(encode_frame({"s": seq, "c": chunk}))
                seq += 1
            await emit(encode_frame({"eos": seq}))
            with state.lock:
                state.completed += 1
        except Exception as exc:  # mid-stream task failure: typed error frame
            with state.lock:
                state.failed += 1
            try:
                await emit(encode_frame({"err": f"{type(exc).__name__}: {exc}"}))
            except Exception:
                pass  # consumer already gone; nothing left to tell it
        finally:
            with state.lock:
                state.busy -= 1
        try:
            writer.write(b"0\r\n\r\n")  # terminate the chunked body
            await writer.drain()
        except Exception:
            pass

    async def _handle_hb(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter
    ) -> None:
        try:
            head = await _read_head(reader)
            if head is None:
                return
            method, path, _ = head
            if method == "GET" and path.rstrip("/") in ("", "/heartbeat", "/health"):
                body = json.dumps(telemetry({"worker": self.name})).encode()
                await _write_response(writer, 200, body, "application/json")
            else:
                await _write_response(writer, 404, b"not found", "text/plain")
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            await _close_writer(writer)


async def _close_writer(writer: asyncio.StreamWriter) -> None:
    try:
        writer.close()
        await writer.wait_closed()
    except Exception:
        pass


class _ChunkedBodyReader:
    """Decode an HTTP/1.1 chunked body into a plain byte stream (async)."""

    def __init__(self, reader: asyncio.StreamReader):
        self._reader = reader
        self._buf = b""
        self._eof = False

    async def read(self, n: int) -> bytes:
        while not self._buf and not self._eof:
            await self._fill()
        out, self._buf = self._buf[:n], self._buf[n:]
        return out

    async def _fill(self) -> None:
        size_line = await self._reader.readline()
        size = int(size_line.strip() or b"0", 16)
        if size == 0:
            self._eof = True  # terminal chunk (or torn line ⇒ frame layer torn)
            return
        self._buf += await self._reader.readexactly(size)
        await self._reader.readexactly(2)  # chunk-terminating CRLF


class _SyncStreamBridge:
    """Blocking file-like view of an async chunked body, for ``read_frames``.

    Each ``read`` marshals onto the client's event loop and blocks the
    calling (consumer) thread for the result — so sync stream stages consume
    async transports unchanged. A transport error surfaces as a short read,
    which the frame layer reports as a torn stream (missing EOS).
    """

    def __init__(
        self,
        areader: _ChunkedBodyReader,
        writer: asyncio.StreamWriter,
        loop: asyncio.AbstractEventLoop,
    ):
        self._areader = areader
        self._writer = writer
        self._loop = loop

    def read(self, n: int) -> bytes:
        try:
            return asyncio.run_coroutine_threadsafe(
                self._areader.read(n), self._loop
            ).result()
        except Exception:
            return b""  # torn transport ⇒ missing EOS at the frame layer

    def close(self) -> None:
        try:
            self._loop.call_soon_threadsafe(self._writer.close)
        except RuntimeError:
            pass  # loop already gone; the socket dies with it


class AsyncWorkerClient:
    """Coroutine worker transport with ``WorkerClient``'s failure taxonomy.

    The async gateway awaits :meth:`run_task_async` / :meth:`heartbeat_async`
    natively (no offload thread per call). Streaming responses resolve to a
    synchronous chunk iterator backed by :class:`_SyncStreamBridge`.
    """

    def __init__(
        self, name: str, address: str, heartbeat_address: str, timeout: float = 30.0
    ):
        self.name = name
        self.address = address
        self.heartbeat_address = heartbeat_address
        self.timeout = timeout
        parts = urlsplit(address)
        self._host, self._port = parts.hostname or "127.0.0.1", parts.port or 80

    async def heartbeat_async(self) -> Optional[Dict[str, Any]]:
        """Probe the separate heartbeat port; None ⇒ system-level failure."""
        return await check_heartbeat_async(
            self.heartbeat_address, timeout=min(2.0, self.timeout)
        )

    async def run_task_async(
        self, task_name: str, ctx: Context, inputs: Mapping[str, Any]
    ) -> Dict[str, Any]:
        """POST one task; returns the worker's status dict (or a live stream)."""
        body = encode_payload(
            {"task": task_name, "context": ctx.to_wire(), "inputs": dict(inputs)}
        )
        try:
            reader, writer = await asyncio.wait_for(
                asyncio.open_connection(self._host, self._port), timeout=self.timeout
            )
        except Exception as exc:
            raise TimeoutError(f"worker {self.name} application not responding: {exc}") from exc
        try:
            writer.write(
                (
                    f"POST /task HTTP/1.1\r\nHost: {self._host}\r\n"
                    f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n"
                ).encode("latin-1")
                + body
            )
            await writer.drain()
            headers = await asyncio.wait_for(
                self._read_response_head(reader), timeout=self.timeout
            )
        except Exception as exc:
            await _close_writer(writer)
            raise TimeoutError(f"worker {self.name} application not responding: {exc}") from exc
        if headers.get("content-type", "") == STREAM_CONTENT_TYPE:
            # incremental chunk stream: hand back a live frame iterator over
            # the open connection; the bridge closes it when the stream ends
            bridge = _SyncStreamBridge(
                _ChunkedBodyReader(reader), writer, asyncio.get_running_loop()
            )
            return {"status": "stream", "stream": _stream_values(bridge, self.name)}
        try:
            length = headers.get("content-length")
            if length is not None:
                raw = await asyncio.wait_for(
                    reader.readexactly(int(length)), timeout=self.timeout
                )
            else:
                raw = await asyncio.wait_for(reader.read(-1), timeout=self.timeout)
        except Exception as exc:
            raise TimeoutError(f"worker {self.name} application not responding: {exc}") from exc
        finally:
            await _close_writer(writer)
        # a transport that answered but with undecodable bytes is a TYPED
        # failure (PayloadDecodeError) — the gateway retries it elsewhere
        return decode_payload(raw)

    @staticmethod
    async def _read_response_head(reader: asyncio.StreamReader) -> Dict[str, str]:
        status_line = await reader.readline()
        if not status_line:
            raise ConnectionError("empty response")
        parts = status_line.split()
        if len(parts) < 2 or parts[1] != b"200":
            raise ConnectionError(f"bad response status: {status_line!r}")
        headers: Dict[str, str] = {}
        while True:
            raw = await reader.readline()
            if raw in (b"\r\n", b"\n", b""):
                break
            key, _, value = raw.decode("latin-1").partition(":")
            headers[key.strip().lower()] = value.strip()
        return headers
