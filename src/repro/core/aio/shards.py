"""Sharded gateway replicas with journal-backed handoff (ROADMAP item 1).

One gateway is a single point of failure: when it dies, every future it
holds dangles and the run is lost even though the workers — and the journal
— survived. :class:`ShardedGateway` removes that by running N independent
gateway replicas (each with the full worker fleet) and partitioning requests
across them by node-key hash. The shard map is the recovery unit:

- every submit registers a *pending entry* (task, context, inputs, routing
  kwargs) against its owner replica, resolved through a group future that is
  the only future callers ever see;
- when a replica crashes (the ``crashed`` flag set by fault injection or a
  monitor-detected death), a survivor **adopts its partition**: each orphaned
  entry is first checked against the shared journal's ``ReplayCache`` — work
  that already reached ``NODE_COMMIT`` resolves straight from the journaled
  payload (zero duplicated commits) — and everything else is resubmitted to
  the next alive replica on the hash ring (zero lost commits);
- the adoption itself is journaled as a ``GW_HANDOFF`` record so replay and
  audit can see exactly which partition moved where and why.

Duplicate-safety does not depend on timing: group futures are set-once, and
the ClusterExecutor's first-commit-wins stale detection ignores late
resolutions from a copy that lost the race, so a resubmitted task whose
original secretly completed can never double-commit.
"""

from __future__ import annotations

import threading
import time
import zlib
from concurrent.futures import Future, InvalidStateError
from typing import Any, Callable, Dict, List, Mapping, Optional

from repro.obs.trace import get_tracer
from repro.wire import payload_digest

from ..context import Context, EMPTY_CONTEXT
from ..durable import Journal, JournalRecord, ReplayCache
from ..gateway import AllocationError, Gateway, TaskRequest, WorkerHandle

__all__ = ["ShardedGateway"]


def _set_result(fut: Future, value: Any) -> None:
    try:
        if not fut.done():
            fut.set_result(value)
    except InvalidStateError:
        pass  # a racing resolution won; set-once is the dedup


def _set_exception(fut: Future, exc: BaseException) -> None:
    try:
        if not fut.done():
            fut.set_exception(exc)
    except InvalidStateError:
        pass


def _chain(group: Future, inner: Future) -> None:
    """Propagate a replica-side future into the caller-visible group future."""
    if group.done():
        return
    exc = inner.exception()
    if exc is not None:
        _set_exception(group, exc)
    else:
        _set_result(group, inner.result())


class _PendingSubmit:
    """One routed request: everything needed to re-route it after a crash."""

    __slots__ = ("task_name", "ctx", "inputs", "kwargs", "key", "group", "replica", "inner")

    def __init__(
        self,
        task_name: str,
        ctx: Context,
        inputs: Dict[str, Any],
        kwargs: Dict[str, Any],
        key: str,
        group: Future,
    ):
        self.task_name = task_name
        self.ctx = ctx
        self.inputs = inputs
        self.kwargs = kwargs
        self.key = key
        self.group = group
        self.replica: int = -1
        self.inner: Optional[Future] = None


class ShardedGateway:
    """N gateway replicas behind one Gateway-shaped surface.

    Construction kwargs beyond ``shards``/``journal`` are forwarded to each
    replica's ``Gateway(...)`` constructor, which honours ``REPRO_RUNTIME``
    — so a sharded control plane runs threaded or asyncio replicas with the
    same code. The executor-facing surface (``submit`` / ``cancel_run`` /
    ``mark_suspended`` / ``on_requeue``) matches :class:`Gateway` so the
    ClusterExecutor drives shards unmodified.
    """

    def __init__(
        self,
        workers: Any,
        *,
        shards: int = 2,
        journal: Optional[Journal] = None,
        name: str = "shardedgw",
        **gateway_kw: Any,
    ):
        if shards < 1:
            raise ValueError("shards must be >= 1")
        self.name = name
        self.journal = journal
        self.replicas: List[Gateway] = [
            Gateway(workers, name=f"{name}:r{i}", **gateway_kw) for i in range(shards)
        ]
        self._alive = set(range(shards))
        self._pending: Dict[int, Dict[int, _PendingSubmit]] = {
            i: {} for i in range(shards)
        }
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        self._on_requeue: Optional[Callable[[TaskRequest, str], None]] = None
        self._on_worker_down: Optional[Callable[[WorkerHandle], None]] = None
        self.metrics = {"handoffs": 0, "recovered": 0, "resubmitted": 0}
        for replica in self.replicas:
            replica.on_requeue = self._forward_requeue
            replica.on_worker_down = self._forward_worker_down

    # -- observer forwarding (executor installs these on the façade) ---------
    @property
    def on_requeue(self) -> Optional[Callable[[TaskRequest, str], None]]:
        """Requeue observer, forwarded from every replica."""
        return self._on_requeue

    @on_requeue.setter
    def on_requeue(self, cb: Optional[Callable[[TaskRequest, str], None]]) -> None:
        """Install the requeue observer (fans out through every replica)."""
        self._on_requeue = cb

    @property
    def on_worker_down(self) -> Optional[Callable[[WorkerHandle], None]]:
        """Worker-death observer, forwarded from every replica."""
        return self._on_worker_down

    @on_worker_down.setter
    def on_worker_down(self, cb: Optional[Callable[[WorkerHandle], None]]) -> None:
        """Install the worker-death observer (fans out through every replica)."""
        self._on_worker_down = cb

    def _forward_requeue(self, req: TaskRequest, reason: str) -> None:
        cb = self._on_requeue
        if cb is not None:
            cb(req, reason)

    def _forward_worker_down(self, handle: WorkerHandle) -> None:
        cb = self._on_worker_down
        if cb is not None:
            cb(handle)

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "ShardedGateway":
        """Start every replica plus the crash monitor."""
        for replica in self.replicas:
            replica.start()
        self._stop.clear()
        self._monitor = threading.Thread(
            target=self._monitor_loop, name=f"{self.name}:monitor", daemon=True
        )
        self._monitor.start()
        return self

    def stop(self) -> None:
        """Stop the monitor and every still-alive replica."""
        self._stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=2)
        for replica in self.replicas:
            if not replica.crashed:
                replica.stop()

    def __enter__(self) -> "ShardedGateway":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    def _monitor_loop(self) -> None:
        while not self._stop.wait(0.05):
            for idx, replica in enumerate(self.replicas):
                if replica.crashed:
                    with self._lock:
                        needs_handoff = idx in self._alive
                    if needs_handoff:
                        self.handoff(idx)

    # -- routing ------------------------------------------------------------
    def _owner(self, key: str) -> int:
        """Hash-ring owner: crc32 start slot, successor fallback over alive."""
        n = len(self.replicas)
        start = zlib.crc32(key.encode("utf-8", "replace")) % n
        for off in range(n):
            idx = (start + off) % n
            if idx in self._alive:
                return idx
        raise AllocationError("no live gateway replicas")

    def submit(
        self,
        task_name: str,
        ctx: Context = EMPTY_CONTEXT,
        inputs: Optional[Mapping[str, Any]] = None,
        *,
        priority: int = 0,
        affinity_key: str = "",
        max_attempts: int = 3,
        meta: Optional[Mapping[str, Any]] = None,
    ) -> Future:
        """Route one task to its partition owner; returns the group Future."""
        meta_d = dict(meta or {})
        key = str(meta_d.get("node") or affinity_key or task_name)
        group: Future = Future()
        entry = _PendingSubmit(
            task_name=task_name,
            ctx=ctx,
            inputs=dict(inputs or {}),
            kwargs={
                "priority": priority,
                "affinity_key": affinity_key,
                "max_attempts": max_attempts,
                "meta": meta_d,
            },
            key=key,
            group=group,
        )
        group.add_done_callback(lambda _f, e=entry: self._forget(e))
        try:
            self._route(entry)
        except Exception as exc:  # no alive replicas at all
            _set_exception(group, exc)
        return group

    def map(
        self,
        task_name: str,
        inputs_list: Any,
        ctx: Context = EMPTY_CONTEXT,
        **kw: Any,
    ) -> List[Future]:
        """Submit one task per input mapping; returns the Futures in order."""
        return [self.submit(task_name, ctx, inp, **kw) for inp in inputs_list]

    def _route(self, entry: _PendingSubmit) -> None:
        with self._lock:
            idx = self._owner(entry.key)
            entry.replica = idx
            self._pending[idx][id(entry.group)] = entry
            replica = self.replicas[idx]
        inner = replica.submit(entry.task_name, entry.ctx, entry.inputs, **entry.kwargs)
        entry.inner = inner
        inner.add_done_callback(lambda f, g=entry.group: _chain(g, f))

    def _forget(self, entry: _PendingSubmit) -> None:
        with self._lock:
            self._pending.get(entry.replica, {}).pop(id(entry.group), None)

    # -- handoff ------------------------------------------------------------
    def handoff(self, dead_idx: int, reason: str = "gateway replica crashed") -> int:
        """Adopt a dead replica's partition from journaled dispatch state.

        Every orphaned pending entry is either *recovered* (its node already
        reached ``NODE_COMMIT`` in the shared journal — resolve the group
        future straight from the journaled payload, no re-execution) or
        *resubmitted* to the next alive replica on the ring. Appends one
        ``GW_HANDOFF`` audit record; returns the number of orphans handled.
        """
        with self._lock:
            if dead_idx not in self._alive:
                return 0  # already handed off (monitor/test race)
            self._alive.discard(dead_idx)
            orphans = list(self._pending.pop(dead_idx, {}).values())
        tracer = get_tracer()
        span = (
            tracer.start_span(
                f"handoff:{self.replicas[dead_idx].name}",
                kind="handoff",
                attrs={"from": self.replicas[dead_idx].name, "reason": reason},
            )
            if tracer.enabled
            else None
        )
        replica = self.replicas[dead_idx]
        if not replica.crashed:
            replica.stop()  # administrative removal: same adoption path
        replay = ReplayCache(self.journal) if self.journal is not None else None
        recovered = resubmitted = 0
        for entry in orphans:
            if entry.group.done():
                continue
            rec = None
            node_id = str(entry.kwargs["meta"].get("node") or "")
            if replay is not None and node_id:
                rec = replay.lookup(
                    node_id, entry.ctx.digest(), payload_digest(entry.inputs)
                )
            if rec is not None and rec.payload is not None:
                _set_result(entry.group, rec.payload)
                recovered += 1
                continue
            try:
                self._route(entry)
            except Exception as exc:  # every replica is gone
                _set_exception(entry.group, exc)
                continue
            resubmitted += 1
        self.metrics["handoffs"] += 1
        self.metrics["recovered"] += recovered
        self.metrics["resubmitted"] += resubmitted
        if self.journal is not None:
            with self._lock:
                survivors = sorted(self._alive)
            self.journal.append(
                JournalRecord(
                    kind="GW_HANDOFF",
                    node_id="",
                    wall_time=time.time(),  # record timestamp
                    meta={
                        "from": self.replicas[dead_idx].name,
                        "to": [self.replicas[i].name for i in survivors],
                        "reason": reason,
                        "recovered": recovered,
                        "resubmitted": resubmitted,
                    },
                )
            )
            self.journal.flush()
        if span is not None:
            tracer.end(span, attrs={"recovered": recovered, "resubmitted": resubmitted})
        return recovered + resubmitted

    # -- run-level control (suspension) --------------------------------------
    def cancel_run(self, run_token: str) -> int:
        """Withdraw queued requests for a run on every alive replica."""
        with self._lock:
            alive = [self.replicas[i] for i in sorted(self._alive)]
        return sum(r.cancel_run(run_token) for r in alive)

    def mark_suspended(self, run_token: str, interrupt: str) -> None:
        """Book a suspension on every alive replica (any survivor can report)."""
        with self._lock:
            alive = [self.replicas[i] for i in sorted(self._alive)]
        for r in alive:
            r.mark_suspended(run_token, interrupt)

    # -- introspection -------------------------------------------------------
    def live_workers(self) -> List[WorkerHandle]:
        """Live workers as seen by the first alive replica."""
        with self._lock:
            alive = sorted(self._alive)
        if not alive:
            return []
        return self.replicas[alive[0]].live_workers()

    def stats(self) -> Dict[str, Any]:
        """Merged control-plane snapshot: ring state + per-replica stats."""
        with self._lock:
            alive = sorted(self._alive)
            pending = {
                self.replicas[i].name: len(m) for i, m in self._pending.items()
            }
        return {
            "shards": len(self.replicas),
            "alive": [self.replicas[i].name for i in alive],
            "pending": pending,
            "metrics": dict(self.metrics),
            "replicas": {self.replicas[i].name: self.replicas[i].stats() for i in alive},
        }
