"""Executors: run a ContextGraph durably, locally or through a Gateway.

Execution semantics (the paper's logical flow, §4):
  1. contract SCCs → union nodes (DAG guarantee),
  2. propagate ξ per the union rules,
  3. execute nodes in dependency order with dependency-injected inputs,
  4. journal every commit; replay skips nodes whose (id, ξ-digest, input-digest)
     already committed — durable, effectively-once execution.

Union nodes execute their members as ONE atomic unit (single commit), in
deterministic member order, with intra-group outputs injected among members.

``LocalExecutor`` runs tasks on a thread pool with dependency-counted
readiness (maximum overlap). ``ClusterExecutor`` dispatches named tasks
through a Gateway to remote/in-proc workers with the same barrier-free
dependency-counted readiness, event-driven completion consumption, global
straggler speculation, and requeue-on-eviction fault tolerance (first
commit wins — duplicates are idempotent by replay). The full dispatch/
readiness/eviction/speculation state machine is specified in
docs/distributed-execution.md.

Both executors optionally consult a cross-run ``repro.cache.ResultCache``
(keyed by fn/input/context digests) after the replay oracle and before any
execution or dispatch; hits and stores are journaled as ``CACHE_HIT`` /
``CACHE_STORE`` records so cache-accelerated runs stay fully replayable.
See docs/result-cache.md for the cache/journal contract.

Nodes declared with ``stream=`` ("source" / "map" / "reduce") execute as
*pipelined stream stages* on dedicated threads: consumers start on the
producer's first chunk, chunks flow through bounded backpressured channels
(``repro.stream``), every chunk is journaled as a ``CHUNK_COMMIT`` before
it becomes visible downstream, and a killed run resumes producers from
their last committed offset. A dependency edge INTO a stream consumer from
its stream producer is satisfied when the producer *starts*; every other
edge keeps batch semantics (satisfied at commit). See docs/streaming.md.
"""

from __future__ import annotations

import inspect
import itertools
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.cache import CacheKey, CachedResult, ResultCache
from repro.obs.trace import get_tracer, inject_trace
from repro.wire import unwrap_digested
from repro.stream import (
    ChannelClosed,
    ChunkLog,
    StreamCancelled,
    StreamHandle,
    StreamPlan,
    plan_streams,
    reduce_iter,
    run_map_stage,
    run_source_stage,
    stream_input_marker,
)

from .context import Context
from .durable import (
    Interrupted,
    Journal,
    JournalRecord,
    ReplayCache,
    encode_payload,
    payload_digest,
)
from .failure import RetryPolicy, StragglerWatch
from .gateway import Gateway, TaskCancelled
from .graph import ContextGraph, Node, UnionNode

__all__ = ["WithContext", "ExecutionReport", "LocalExecutor", "ClusterExecutor"]

_INLINE_LIMIT = 1 << 20  # 1 MiB: larger outputs must go through the spill store

_RUN_TOKENS = itertools.count()  # distinguishes concurrent runs on one gateway


@dataclass
class WithContext:
    """Task return wrapper: ``return WithContext(out, {"fact": 1})`` emits facts."""

    output: Any
    facts: Mapping[str, Any]


@dataclass
class ExecutionReport:
    """What a run did: outputs/contexts per node, and how each node resolved.

    Every exec node lands in exactly one of ``replayed`` (this journal
    already committed it — for stream nodes: every chunk AND the EOS came
    from the journal), ``cached`` (answered by the cross-run result cache),
    or ``executed`` (actually ran, possibly resuming a committed prefix).
    """

    outputs: Dict[str, Any]
    contexts: Dict[str, Context]
    replayed: Tuple[str, ...]
    executed: Tuple[str, ...]
    wall_s: float
    cached: Tuple[str, ...] = ()
    suspended: bool = False  # a named interrupt point suspended the run
    interrupt: str = ""  # name of the interrupt that suspended it
    interrupt_node: str = ""  # node that raised the interrupt
    frontier: Tuple[str, ...] = ()  # exec nodes still pending at suspension


def _accepts_start(fn: Callable[..., Any]) -> bool:
    """True iff ``fn`` declares an explicit ``start`` parameter.

    Only an explicit parameter counts — passing ``start`` into a bare
    ``**kwargs`` producer that ignores it would silently re-emit from 0 and
    corrupt chunk numbering, so those producers get the skip-side resume.
    """
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    return "start" in sig.parameters


class _BaseExecutor:
    """Shared durable-commit, replay-lookup, and result-cache machinery."""

    def __init__(
        self,
        journal: Optional[Journal] = None,
        retry: Optional[RetryPolicy] = None,
        cache: Optional[ResultCache] = None,
        spill_put: Optional[Callable[[str, Any], str]] = None,
        spill_get: Optional[Callable[[str], Any]] = None,
        channel_capacity: int = 8,
    ):
        self.journal = journal
        self.retry = retry or RetryPolicy()
        self.cache = cache
        self.replay = ReplayCache(journal) if journal is not None else ReplayCache()
        self.channel_capacity = channel_capacity
        self._spill_put = spill_put
        self._spill_get = spill_get

    # -- durable commit machinery -------------------------------------------
    def _commit(
        self,
        node_id: str,
        ctx_digest: str,
        in_digest: str,
        output: Any,
        attempt: int,
        meta: Optional[dict] = None,
        volatile: bool = False,
        expected: Optional[str] = None,
        deps: Optional[Iterable[str]] = None,
    ) -> None:
        """Journal one NODE_COMMIT and index it for replay.

        ``volatile`` commits carry only the output *digest* (``payload=None``
        — tensors never enter the journal); when ``expected`` is set (the
        digest a previous incarnation committed for the same identity), a
        disagreeing re-execution is surfaced as a hard non-determinism error
        before anything downstream can consume the divergent value.
        ``deps`` (the node's upstream ids) are recorded in ``meta`` for the
        lineage index (repro.journal.lineage) — provenance annotations the
        replay oracle itself ignores.
        """
        if deps:
            meta = {**(meta or {}), "deps": sorted(set(deps))}
        payload, ref = output, ""
        if self._spill_put is not None and not volatile:
            try:
                approx = payload_digest(output)  # also probes serializability
                del approx
            except Exception:
                ref = self._spill_put(node_id, output)
                payload = None
        out_digest = payload_digest(output) if ref == "" else ref
        if volatile:
            if expected is not None and expected != out_digest:
                raise RuntimeError(
                    f"non-deterministic re-execution at node {node_id!r}: "
                    f"journal={expected} recomputed={out_digest}"
                )
            payload = None
            meta = {**(meta or {}), "volatile": True}
        rec = JournalRecord(
            kind="NODE_COMMIT",
            node_id=node_id,
            context_digest=ctx_digest,
            input_digest=in_digest,
            output_digest=out_digest,
            payload=payload if ref == "" else None,
            ref=ref,
            attempt=attempt,
            meta=meta or {},
        )
        if self.journal is not None:
            self.journal.append(rec)
        self.replay.record(rec)

    @staticmethod
    def _readiness(
        exec_nodes: Mapping[str, Any],
        member_to_group: Mapping[str, str],
    ):
        """Dependency-counted scheduling state shared by both executors:
        (gdeps, deps_left, children)."""
        gdeps = ContextGraph.group_deps(exec_nodes, member_to_group)
        deps_left = {nid: len(gdeps[nid]) for nid in exec_nodes}
        children: Dict[str, List[str]] = {nid: [] for nid in exec_nodes}
        for nid in exec_nodes:
            for d in gdeps[nid]:
                children[d].append(nid)
        return gdeps, deps_left, children

    # -- cross-run result cache (repro.cache; docs/result-cache.md) ----------
    def _cache_key(
        self,
        node: "Node | UnionNode",
        ctx_digest: str,
        in_digest: str,
    ) -> Optional[CacheKey]:
        """Content-addressed key for this (fn, inputs, ξ) — None when uncached.

        Stream nodes never use the cross-run cache (chunk-granular replay
        supersedes it — docs/streaming.md §4.3); volatile nodes never do
        either (their outputs are transient tensors kept out of every store).
        """
        if self.cache is None or getattr(node, "stream", "") or getattr(node, "volatile", False):
            return None
        return CacheKey(fn=node.fn_digest(), inputs=in_digest, context=ctx_digest)

    def _cache_probe(
        self,
        node_id: str,
        key: Optional[CacheKey],
        ctx_digest: str,
        in_digest: str,
        deps: Optional[Iterable[str]] = None,
    ) -> Optional[CachedResult]:
        """Consult the result cache; a hit journals CACHE_HIT + NODE_COMMIT.

        The commit carries the cached payload, so the journal of a
        cache-accelerated run replays standalone — auditability is never
        delegated to cache availability.
        """
        if key is None:
            return None
        ent = self.cache.get(key)
        if ent is None:
            return None
        if self.journal is not None:
            self.journal.append(
                JournalRecord(
                    kind="CACHE_HIT",
                    node_id=node_id,
                    context_digest=ctx_digest,
                    input_digest=in_digest,
                    output_digest=ent.output_digest,
                    meta={"key": key.id},
                )
            )
        meta: Dict[str, Any] = {"cache": key.id}
        if ent.facts:
            meta["facts"] = dict(ent.facts)
        self._commit(node_id, ctx_digest, in_digest, ent.value, 0, meta=meta, deps=deps)
        return ent

    def _cache_store(
        self,
        node_id: str,
        key: Optional[CacheKey],
        ctx_digest: str,
        in_digest: str,
        value: Any,
        facts: Optional[Mapping[str, Any]] = None,
    ) -> None:
        """Commit a freshly-executed result into the cache (journals CACHE_STORE).

        Uncacheable outputs (unserializable by the payload codec) are skipped
        without failing the run — the node simply stays cold.
        """
        if key is None:
            return
        try:
            ent = self.cache.put(key, value, facts=facts)
        except Exception:
            self.cache.stats["uncacheable"] += 1
            return
        if self.journal is not None:
            self.journal.append(
                JournalRecord(
                    kind="CACHE_STORE",
                    node_id=node_id,
                    context_digest=ctx_digest,
                    input_digest=in_digest,
                    output_digest=ent.output_digest,
                    meta={"key": key.id},
                )
            )

    def _lookup(
        self,
        node_id: str,
        ctx_digest: str,
        in_digest: str,
    ) -> "Optional[_Found]":
        """Replay oracle: the committed output for (node, ξ, inputs), if any.

        Stream-node commits carry no payload; their value materializes from
        the journaled chunk sequence (docs/streaming.md §4.2). Volatile
        commits also carry no payload — they answer with a *verify-only*
        hit (``reexecute=True``): the caller must re-execute the node and
        check the fresh digest against ``expected``.
        """
        rec = self.replay.lookup(node_id, ctx_digest, in_digest)
        if rec is None:
            return None
        facts = rec.meta.get("facts")
        if rec.meta.get("volatile"):
            return _Found(None, facts, reexecute=True, expected=rec.output_digest)
        if rec.meta.get("stream") is not None:
            chunks = self.replay.stream_chunks(node_id, ctx_digest, in_digest)
            return _Found([c.payload for c in chunks], facts)
        if rec.ref:
            if self._spill_get is None:
                return None  # cannot resolve; re-execute
            return _Found(self._spill_get(rec.ref), facts)
        return _Found(rec.payload, facts)

    # -- stream-stage plumbing shared by both executors ----------------------
    def _stream_stage_inputs(
        self,
        node: Node,
        splan: StreamPlan,
        outputs: Mapping[str, Any],
        member_to_group: Mapping[str, str],
        stream_identity: Mapping[str, Tuple[str, str]],
    ) -> Tuple[Dict[str, Any], Dict[str, Any], Optional[str], Optional[str]]:
        """Split a stream node's deps into injectable values vs. the stream.

        Returns ``(fn_inputs, digest_inputs, stream_kwarg, stream_dep_gid)``:
        ``fn_inputs`` are the batch inputs actually passed to ``fn``;
        ``digest_inputs`` additionally carry the stream-identity marker under
        the stream kwarg, making the node's input digest replay-stable
        without hashing unbounded chunk data.
        """
        sdep = splan.stream_dep.get(node.id)
        fn_inputs: Dict[str, Any] = {}
        digest_inputs: Dict[str, Any] = {}
        stream_kwarg: Optional[str] = None
        for dep in node.deps:
            gid = member_to_group.get(dep, dep)
            kwarg = node.kwarg_for(dep)
            if gid == sdep:
                stream_kwarg = kwarg
                up_ctx_d, up_in_d = stream_identity[gid]
                digest_inputs[kwarg] = stream_input_marker(gid, up_ctx_d, up_in_d)
                continue
            out = outputs[gid]
            if gid != dep and isinstance(out, Mapping) and dep in out:
                out = out[dep]  # a specific member of a union node
            fn_inputs[kwarg] = out
            digest_inputs[kwarg] = out
        return fn_inputs, digest_inputs, stream_kwarg, sdep

    def _journal_suspend(
        self,
        suspend: Mapping[str, Interrupted],
        frontier: Tuple[str, ...],
        nodes: Optional[Mapping[str, Any]] = None,
    ) -> None:
        """Journal one SUSPEND per interrupted node; the run ends WITHOUT RUN_END.

        The frontier (exec nodes without a committed output) is recorded so a
        resume can audit what remained; an unserializable interrupt payload
        degrades to its repr rather than failing the suspension itself.

        A node declaring ``interrupt_timeout_s`` stamps its SUSPEND with the
        *absolute* answer deadline plus the on-timeout policy and (for the
        ``"default"`` policy) the journaled default answer — the deadline is
        resolved to wall time HERE, at suspension, so replaying the journal
        later reaches the identical timeout verdict (docs/durable-workflows.md).
        """
        if self.journal is None:
            return
        for nid, exc in suspend.items():
            meta: Dict[str, Any] = {"interrupt": exc.name, "frontier": list(frontier)}
            if exc.payload is not None:
                try:
                    encode_payload(exc.payload)  # probes wire serializability
                    meta["payload"] = exc.payload
                except Exception:
                    meta["payload_repr"] = repr(exc.payload)
            node = (nodes or {}).get(nid)
            timeout_s = getattr(node, "interrupt_timeout_s", None)
            if timeout_s is not None:
                meta["timeout_s"] = float(timeout_s)
                # an absolute wall deadline survives process restarts;
                # record timestamp: journaled for cross-process expiry
                meta["deadline"] = time.time() + float(timeout_s)
                policy = getattr(node, "interrupt_on_timeout", "") or "escalate"
                if policy == "default":
                    default = getattr(node, "interrupt_default", None)
                    try:
                        encode_payload(default)  # probes wire serializability
                        meta["default"] = default
                    except Exception:
                        # an unjournalable auto-answer cannot replay
                        # deterministically — degrade to escalation
                        policy = "escalate"
                meta["on_timeout"] = policy
            self.journal.append(JournalRecord(kind="SUSPEND", node_id=nid, meta=meta))
        self.journal.flush()

    def _journal_stream_start(
        self,
        nid: str,
        kind: str,
        ctx_digest: str,
        in_digest: str,
        resume_seq: int,
    ) -> None:
        """NODE_START for a stream stage, annotated with the resume offset."""
        if self.journal is not None:
            self.journal.append(
                JournalRecord(
                    kind="NODE_START",
                    node_id=nid,
                    context_digest=ctx_digest,
                    input_digest=in_digest,
                    meta={"stream": kind, "resume_seq": resume_seq},
                )
            )


@dataclass
class _Found:
    value: Any
    facts: Optional[Mapping[str, Any]] = None  # journaled WithContext facts
    reexecute: bool = False  # volatile hit: no payload — run again and verify
    expected: Optional[str] = None  # the digest the re-execution must match


def _inject_inputs(
    node: Node,
    outputs: Mapping[str, Any],
    member_to_group: Mapping[str, str],
) -> Dict[str, Any]:
    """Dependency injection: map each dep's output to the node's kwarg."""
    inputs: Dict[str, Any] = {}
    for dep in node.deps:
        gid = member_to_group.get(dep, dep)
        out = outputs[gid]
        if gid != dep and isinstance(out, Mapping) and dep in out:
            out = out[dep]  # a specific member of a union node
        inputs[node.kwarg_for(dep)] = out
    return inputs


class LocalExecutor(_BaseExecutor):
    """In-process threaded executor with dependency-counted scheduling.

    Batch nodes run on a bounded thread pool; stream stages run on
    dedicated threads (they live as long as their stream and block on
    channel backpressure, so parking them in the pool could starve it).
    """

    def __init__(self, max_workers: int = 8, **kw):
        super().__init__(**kw)
        self.max_workers = max_workers

    def run(
        self,
        graph: ContextGraph,
        run_meta: Optional[Mapping[str, Any]] = None,
    ) -> ExecutionReport:
        """Execute ``graph`` on the thread pool; returns the run's report.

        ``run_meta`` is merged into the RUN_START record (e.g. a workflow id).
        A node raising :class:`Interrupted` suspends the run: launched work
        drains to commit, nothing new starts, SUSPEND records are journaled
        with the pending frontier, and the report comes back with
        ``suspended=True`` instead of an exception.
        """
        t0 = time.monotonic()  # wall_s is a duration: clock steps must not skew it
        tracer = get_tracer()
        run_span = (
            tracer.start_span(f"run:{graph.name}", kind="run", attrs={"graph": graph.name})
            if tracer.enabled
            else None
        )
        levels, exec_nodes, member_to_group = graph.schedule()
        splan = plan_streams(exec_nodes)
        outputs: Dict[str, Any] = {}
        out_ctx: Dict[str, Context] = {}
        resolved: Dict[str, List[str]] = {"replayed": [], "cached": [], "executed": []}
        suspend: Dict[str, Interrupted] = {}
        lock = threading.Lock()

        # dependency counting for maximal overlap (scheduling-level deps)
        gdeps, deps_left, children = self._readiness(exec_nodes, member_to_group)

        stream_handles: Dict[str, StreamHandle] = {}
        stream_identity: Dict[str, Tuple[str, str]] = {}
        cancel = threading.Event()
        futures: Dict[Future, str] = {}
        pool = ThreadPoolExecutor(max_workers=self.max_workers)

        if self.journal is not None:
            self.journal.append(
                JournalRecord(
                    kind="RUN_START",
                    node_id=graph.name,
                    meta={"nodes": len(exec_nodes), **dict(run_meta or {})},
                )
            )

        def effective_ctx(nid: str) -> Context:
            node = exec_nodes[nid]
            parents = [out_ctx[d] for d in gdeps[nid]]
            base = Context.union_all(parents) if parents else graph.origin_context
            if isinstance(node, UnionNode):
                for m in sorted(node.members, key=lambda n: n.id):
                    if m.data:
                        base = base.with_data(m.data, origin=m.id)
            elif node.data:
                base = base.with_data(node.data, origin=node.id)
            return base

        def launch(nid: str) -> None:
            if splan.kinds.get(nid):
                fut: Future = Future()
                with lock:
                    futures[fut] = nid
                thread = threading.Thread(
                    target=stage_thread,
                    args=(nid, fut),
                    name=f"stream:{nid}",
                    daemon=True,
                )
                thread.start()
            else:
                f = pool.submit(run_node, nid)
                with lock:
                    futures[f] = nid

        def satisfy_stream_edges(nid: str) -> None:
            # the producer started: its stream consumers become dispatchable
            to_launch = []
            with lock:
                for c in children[nid]:
                    if (nid, c) not in splan.stream_edges:
                        continue
                    deps_left[c] -= 1
                    if deps_left[c] == 0:
                        to_launch.append(c)
            for c in to_launch:
                launch(c)

        def stage_thread(nid: str, fut: Future) -> None:
            try:
                value, ctx, status = self._run_stream_node(
                    exec_nodes[nid],
                    splan,
                    effective_ctx(nid),
                    outputs,
                    out_ctx,
                    member_to_group,
                    stream_identity,
                    stream_handles,
                    satisfy_stream_edges,
                    cancel,
                    lock,
                    parent=run_span,
                )
                with lock:
                    outputs[nid] = value
                    out_ctx[nid] = ctx
                    resolved[status].append(nid)
                fut.set_result(None)
            except BaseException as exc:
                cancel.set()
                fut.set_exception(exc)

        def run_node(nid: str) -> None:
            node = exec_nodes[nid]
            ctx = effective_ctx(nid)
            if isinstance(node, UnionNode):
                self._run_union(node, ctx, outputs, member_to_group, resolved, lock)
            else:
                inputs = _inject_inputs(node, outputs, member_to_group)
                value, status = self._run_atomic(node, ctx, inputs, parent=run_span)
                with lock:
                    if isinstance(value, WithContext):
                        ctx = ctx.with_data(value.facts, origin=node.id)
                        value = value.output
                    outputs[nid] = value
                    resolved[status].append(nid)
            with lock:
                out_ctx[nid] = ctx

        frontier = [nid for nid, c in deps_left.items() if c == 0]
        cascade_errors: List[BaseException] = []
        try:
            with pool:
                for nid in sorted(frontier):
                    launch(nid)
                while True:
                    with lock:
                        pending = list(futures)
                    if not pending:
                        break
                    done, _ = wait(pending, return_when=FIRST_COMPLETED)
                    for f in done:
                        with lock:
                            nid = futures.pop(f)
                        try:
                            f.result()  # re-raise task errors
                        except Interrupted as exc:
                            # a named interrupt point: suspend, don't fail —
                            # stop launching and let in-flight work drain
                            suspend.setdefault(nid, exc)
                            continue
                        except (StreamCancelled, ChannelClosed) as exc:
                            # a stage stopped because the run is already
                            # doomed elsewhere; keep draining so the ROOT
                            # error (the stage that actually failed)
                            # surfaces instead of this cascade
                            cascade_errors.append(exc)
                            continue
                        for c in children[nid]:
                            if (nid, c) in splan.stream_edges:
                                continue  # satisfied at stage start
                            with lock:
                                deps_left[c] -= 1
                                ready = deps_left[c] == 0
                            if ready and not suspend:
                                launch(c)
                if cascade_errors and not suspend:
                    raise cascade_errors[0]  # every failure was a cascade
        except BaseException as exc:
            # stop sibling stream stages from committing past a doomed run,
            # and unblock anything parked on a channel
            cancel.set()
            for handle in list(stream_handles.values()):
                handle.close(error=exc)
            if run_span is not None:
                tracer.end(run_span, status="error")
            raise
        finally:
            if self.journal is not None:
                self.journal.flush()

        if suspend:
            frontier = tuple(sorted(n for n in exec_nodes if n not in outputs))
            self._journal_suspend(suspend, frontier, exec_nodes)
            first_nid = next(iter(suspend))
            if run_span is not None:
                tracer.end(run_span, status="interrupt")
            return ExecutionReport(
                outputs=outputs,
                contexts=out_ctx,
                replayed=tuple(resolved["replayed"]),
                executed=tuple(resolved["executed"]),
                cached=tuple(resolved["cached"]),
                wall_s=time.monotonic() - t0,
                suspended=True,
                interrupt=suspend[first_nid].name,
                interrupt_node=first_nid,
                frontier=frontier,
            )
        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_END", node_id=graph.name))
            self.journal.flush()
        if run_span is not None:
            tracer.end(
                run_span,
                attrs={
                    "executed": len(resolved["executed"]),
                    "replayed": len(resolved["replayed"]),
                    "cached": len(resolved["cached"]),
                },
            )
        return ExecutionReport(
            outputs=outputs,
            contexts=out_ctx,
            replayed=tuple(resolved["replayed"]),
            executed=tuple(resolved["executed"]),
            cached=tuple(resolved["cached"]),
            wall_s=time.monotonic() - t0,
        )

    # -- stream stages --------------------------------------------------------
    def _source_invoker(
        self,
        node: Node,
        ctx: Context,
        inputs: Mapping[str, Any],
    ) -> Callable[[int], Any]:
        """invoke(start) → chunk iterable, resuming at chunk index ``start``."""
        fn = node.fn
        if fn is None or not callable(fn):
            raise ValueError(f"stream source {node.id!r} needs a callable fn")
        if _accepts_start(fn):
            return lambda start: fn(ctx, start=start, **inputs)
        return lambda start: itertools.islice(fn(ctx, **inputs), start, None)

    def _map_invoker(
        self,
        node: Node,
        ctx: Context,
        inputs: Mapping[str, Any],
        stream_kwarg: str,
    ) -> Callable[[int, Any], Any]:
        fn = node.fn
        if fn is None or not callable(fn):
            raise ValueError(f"stream map {node.id!r} needs a callable fn")
        return lambda seq, chunk: fn(ctx, **{stream_kwarg: chunk}, **inputs)

    def _reduce_invoke(
        self,
        node: Node,
        ctx: Context,
        inputs: Mapping[str, Any],
        stream_kwarg: str,
        chunk_iter: Any,
    ) -> Any:
        fn = node.fn
        if fn is None or not callable(fn):
            raise ValueError(f"stream reduce {node.id!r} needs a callable fn")
        return fn(ctx, **{stream_kwarg: chunk_iter}, **inputs)

    def _run_stream_node(
        self,
        node: Node,
        splan: StreamPlan,
        ctx: Context,
        outputs: Mapping[str, Any],
        out_ctx: Dict[str, Context],
        member_to_group: Mapping[str, str],
        stream_identity: Dict[str, Tuple[str, str]],
        stream_handles: Dict[str, StreamHandle],
        satisfy_stream_edges: Callable[[str], None],
        cancel: threading.Event,
        lock: threading.Lock,
        parent: Optional[Any] = None,
    ) -> Tuple[Any, Context, str]:
        """One stream stage, start to commit. Returns (value, ctx, status).

        The stage span wraps :meth:`_run_stream_node_inner`; a stage that
        resolves entirely by replay discards its span (zero emission).
        """
        tracer = get_tracer()
        if not tracer.enabled:
            return self._run_stream_node_inner(
                node, splan, ctx, outputs, out_ctx, member_to_group,
                stream_identity, stream_handles, satisfy_stream_edges, cancel, lock,
            )
        span = tracer.start_span(
            node.id,
            parent=parent,
            kind="stream",
            attrs={"node": node.id, "ctx": ctx.digest()},
        )
        try:
            value, out, status = self._run_stream_node_inner(
                node, splan, ctx, outputs, out_ctx, member_to_group,
                stream_identity, stream_handles, satisfy_stream_edges, cancel, lock,
            )
        except BaseException:
            tracer.end(span, status="error")
            raise
        if status == "replayed":
            tracer.discard(span)
        else:
            tracer.end(span, attrs={"status": status})
        return value, out, status

    def _run_stream_node_inner(
        self,
        node: Node,
        splan: StreamPlan,
        ctx: Context,
        outputs: Mapping[str, Any],
        out_ctx: Dict[str, Context],
        member_to_group: Mapping[str, str],
        stream_identity: Dict[str, Tuple[str, str]],
        stream_handles: Dict[str, StreamHandle],
        satisfy_stream_edges: Callable[[str], None],
        cancel: threading.Event,
        lock: threading.Lock,
    ) -> Tuple[Any, Context, str]:
        """The uninstrumented stream-stage body (see ``_run_stream_node``)."""
        nid = node.id
        kind = splan.kinds[nid]
        fn_inputs, digest_inputs, stream_kwarg, sdep = self._stream_stage_inputs(
            node, splan, outputs, member_to_group, stream_identity
        )
        ctx_d = ctx.digest()
        in_d = payload_digest(digest_inputs)

        handle: Optional[StreamHandle] = None
        if kind in ("source", "map"):
            handle = StreamHandle(
                nid,
                splan.subscribers.get(nid, ()),
                capacity=self.channel_capacity,
            )
        with lock:
            # publish identity/ctx/handle BEFORE unblocking consumers: a
            # stream stage's ξ is final at start (stages cannot emit facts),
            # and consumers union it into their own ξ the moment they launch
            out_ctx[nid] = ctx
            stream_identity[nid] = (ctx_d, in_d)
            if handle is not None:
                stream_handles[nid] = handle
        satisfy_stream_edges(nid)

        upstream = stream_handles[sdep].subscribe(nid) if sdep else None

        if kind == "reduce":
            hit = self._lookup(nid, ctx_d, in_d)
            if hit is not None:
                upstream.abandon()
                if hit.facts:
                    ctx = ctx.with_data(hit.facts, origin=nid)
                return hit.value, ctx, "replayed"
            self._journal_stream_start(nid, kind, ctx_d, in_d, 0)
            value = self._reduce_invoke(
                node, ctx, fn_inputs, stream_kwarg, reduce_iter(upstream, cancel)
            )
            facts = dict(value.facts) if isinstance(value, WithContext) else None
            if isinstance(value, WithContext):
                ctx = ctx.with_data(value.facts, origin=nid)
                value = value.output
            self._commit(
                nid, ctx_d, in_d, value, 0,
                meta={"facts": facts} if facts else None, deps=node.deps,
            )
            return value, ctx, "executed"

        log = ChunkLog(self.journal, self.replay, nid, ctx_d, in_d, deps=node.deps)
        if not log.eos:
            self._journal_stream_start(nid, kind, ctx_d, in_d, log.next_seq)
        if kind == "source":
            values, status = run_source_stage(
                nid,
                log,
                handle,
                self._source_invoker(node, ctx, fn_inputs),
                cancel,
                retries=node.retry_limit(0),
            )
        else:
            values, status = run_map_stage(
                nid,
                log,
                upstream,
                handle,
                self._map_invoker(node, ctx, fn_inputs, stream_kwarg),
                cancel,
                retries=node.retry_limit(0),
            )
        return values, ctx, status

    # -- atomic execution with retries ----------------------------------------
    def _run_atomic(
        self,
        node: Node,
        ctx: Context,
        inputs: Mapping[str, Any],
        parent: Optional[Any] = None,
    ) -> Tuple[Any, str]:
        """Resolve one node; returns (value, "replayed"|"cached"|"executed").

        ``parent`` is the enclosing run span (or None): the node span opens
        only AFTER the replay and cache probes miss, so resolved-for-free
        nodes emit zero spans.
        """
        ctx_d = ctx.digest()
        in_d = payload_digest(inputs)
        hit = self._lookup(node.id, ctx_d, in_d)
        expected: Optional[str] = None
        if hit is not None:
            if hit.reexecute:
                expected = hit.expected  # volatile: run again, verify digest
            elif hit.facts:
                # re-emit journaled context facts so downstream ξ digests
                # match the original run exactly (replay completeness)
                return WithContext(hit.value, hit.facts), "replayed"
            else:
                return hit.value, "replayed"
        key = self._cache_key(node, ctx_d, in_d)
        ent = self._cache_probe(node.id, key, ctx_d, in_d, deps=node.deps)
        if ent is not None:
            if ent.facts:
                return WithContext(ent.value, ent.facts), "cached"
            return ent.value, "cached"
        if node.fn is None:
            raise ValueError(f"node {node.id!r} has no callable")
        tracer = get_tracer()
        span = (
            tracer.start_span(
                node.id,
                parent=parent,
                kind="node",
                attrs={"node": node.id, "ctx": ctx_d, "in": in_d},
            )
            if tracer.enabled
            else None
        )
        fn_inputs = unwrap_digested(dict(inputs))
        retry_limit = node.retry_limit(self.retry.max_attempts - 1)
        attempt = 0
        while True:
            try:
                if self.journal is not None:
                    self.journal.append(
                        JournalRecord(
                            kind="NODE_START",
                            node_id=node.id,
                            context_digest=ctx_d,
                            input_digest=in_d,
                            attempt=attempt,
                        )
                    )
                value = node.fn(ctx, **fn_inputs)
                break
            except Interrupted:
                if span is not None:
                    tracer.end(span, status="interrupt")
                raise  # suspension request, not a failure: no retry, no NODE_FAIL
            except Exception:
                attempt += 1
                if attempt > retry_limit:
                    if self.journal is not None:
                        self.journal.append(
                            JournalRecord(
                                kind="NODE_FAIL",
                                node_id=node.id,
                                context_digest=ctx_d,
                                input_digest=in_d,
                                attempt=attempt,
                            )
                        )
                    if span is not None:
                        tracer.end(span, status="error", attrs={"attempts": attempt})
                    raise
                time.sleep(self.retry.delay(attempt))
        commit_value = value.output if isinstance(value, WithContext) else value
        facts = dict(value.facts) if isinstance(value, WithContext) else None
        meta = {"facts": facts} if facts else None
        self._commit(node.id, ctx_d, in_d, commit_value, attempt, meta=meta,
                     volatile=node.volatile, expected=expected, deps=node.deps)
        self._cache_store(node.id, key, ctx_d, in_d, commit_value, facts=facts)
        if span is not None:
            tracer.end(span, attrs={"attempts": attempt + 1})
        return value, "executed"

    def _run_union(
        self,
        group: UnionNode,
        ctx: Context,
        outputs: Dict[str, Any],
        member_to_group: Mapping[str, str],
        resolved: Dict[str, List[str]],
        lock: threading.Lock,
    ) -> None:
        """Union node = ONE atomic commit over deterministic member order."""
        ctx_d = ctx.digest()
        ext_inputs = {}
        with lock:
            for m in group.members:
                for d in m.deps:
                    gid = member_to_group.get(d, d)
                    if gid != group.id and gid in outputs:
                        ext_inputs[d] = outputs[gid]
        in_d = payload_digest(ext_inputs)
        hit = self._lookup(group.id, ctx_d, in_d)
        if hit is not None:
            with lock:
                outputs[group.id] = hit.value
                resolved["replayed"].append(group.id)
            return
        ext_deps = sorted(
            {
                d
                for m in group.members
                for d in m.deps
                if member_to_group.get(d, d) != group.id
            }
        )
        key = self._cache_key(group, ctx_d, in_d)
        ent = self._cache_probe(group.id, key, ctx_d, in_d, deps=ext_deps)
        if ent is not None:
            with lock:
                outputs[group.id] = ent.value
                resolved["cached"].append(group.id)
            return
        member_out: Dict[str, Any] = {}
        # fixed-point style deterministic order: members sorted by id; a member
        # whose intra-group dep isn't ready yet sees the PREVIOUS iteration's
        # value (co-dependent semantics), seeded by its Ψ data or None.
        order = sorted(group.members, key=lambda n: n.id)
        seed = {m.id: dict(m.data).get("__seed__") for m in order}
        for m in order:
            inputs = {}
            for d in m.deps:
                gid = member_to_group.get(d, d)
                if gid == group.id:
                    inputs[m.kwarg_for(d)] = member_out.get(d, seed.get(d))
                else:
                    out = ext_inputs.get(d)
                    inputs[m.kwarg_for(d)] = out
            if m.fn is None:
                raise ValueError(f"union member {m.id!r} has no callable")
            v = m.fn(ctx, **unwrap_digested(inputs))
            member_out[m.id] = v.output if isinstance(v, WithContext) else v
        self._commit(
            group.id, ctx_d, in_d, member_out, 0,
            meta={"members": [m.id for m in order]}, deps=ext_deps,
        )
        self._cache_store(group.id, key, ctx_d, in_d, member_out)
        with lock:
            outputs[group.id] = member_out
            resolved["executed"].append(group.id)


@dataclass
class _Inflight:
    """Scheduler-side state of a node currently dispatched through the gateway."""

    node: Node
    ctx: Context
    ctx_digest: str
    input_digest: str
    inputs: Dict[str, Any]
    futures: List[Future] = field(default_factory=list)  # still-live attempts
    copies: int = 0  # total submissions ever made (speculation budget)
    attempts: int = 0  # gateway-level requeues observed (evictions, failures)
    cache_key: Optional[CacheKey] = None  # store target once the result lands
    expected: Optional[str] = None  # volatile: digest the result must match


class ClusterExecutor(_BaseExecutor):
    """Gateway-dispatched executor: barrier-free dependency-counted dataflow.

    Node.fn may be a string (registry task name) — required for remote
    dispatch — or a callable (executed gateway-side, e.g. reductions).

    Scheduling is event-driven, not staged: a node is dispatched the moment
    its last dependency commits (no toposort-level barriers), and completions
    are consumed from a condition-variable pump fed by future callbacks — the
    scheduler blocks in ``Condition.wait``, never in a sleep-poll loop.

    Straggler speculation is global rather than per-level: on every
    ``speculation_tick_s`` wakeup without completions, any inflight node whose
    elapsed time exceeds ``straggler.threshold × median`` of same-task
    completions gets a duplicate on another worker, up to ``max_copies``.
    The first completion wins; duplicates are idempotent by durable replay.

    Fault tolerance: when the gateway evicts a dead worker (heartbeat lost or
    system-level failure), in-flight requests are requeued on survivors and
    each requeue is journaled as a ``NODE_REQUEUE`` record carrying the
    attempt count. See docs/distributed-execution.md for the state machine.

    Stream stages run on dedicated executor-side threads: a named *source*
    is dispatched once and its chunks stream back over the worker transport
    incrementally (chunk-framed HTTP — docs/streaming.md §5); a named *map*
    is dispatched once per chunk through normal gateway routing; reduce
    callables fold executor-side. Chunk commits make mid-stream worker
    death recoverable: the source is re-dispatched with ``start`` set to
    the next uncommitted offset. Stream stages are exempt from straggler
    speculation (a duplicate producer would double-emit).
    """

    def __init__(
        self,
        gateway: Gateway,
        speculative: bool = True,
        speculation_tick_s: float = 0.05,
        max_copies: int = 3,
        stream_retries: int = 2,
        **kw,
    ):
        super().__init__(**kw)
        self.gateway = gateway
        self.speculative = speculative
        self.speculation_tick_s = speculation_tick_s
        self.max_copies = max_copies
        self.stream_retries = stream_retries
        self.straggler = StragglerWatch()

    def run(
        self,
        graph: ContextGraph,
        run_meta: Optional[Mapping[str, Any]] = None,
    ) -> ExecutionReport:
        """Execute ``graph`` through the gateway; returns the run's report.

        ``run_meta`` is merged into the RUN_START record (e.g. a workflow id).
        An :class:`Interrupted` raised by an inline callable — or answered by
        a worker as an ``"interrupt"`` status — suspends the run as a clean
        drain: queued dispatches of this run are cancelled at the gateway
        (:class:`TaskCancelled` is benign — those nodes return to the pending
        frontier), in-flight work commits, SUSPEND records are journaled, and
        the gateway books the run as suspended.
        """
        t0 = time.monotonic()  # wall_s is a duration: clock steps must not skew it
        tracer = get_tracer()
        run_span = (
            tracer.start_span(f"run:{graph.name}", kind="run", attrs={"graph": graph.name})
            if tracer.enabled
            else None
        )
        _levels, exec_nodes, member_to_group = graph.schedule()  # validates DAG
        splan = plan_streams(exec_nodes)
        gdeps, deps_left, children = self._readiness(exec_nodes, member_to_group)
        run_token = f"{graph.name}#{next(_RUN_TOKENS)}"  # this run's requests

        outputs: Dict[str, Any] = {}
        out_ctx: Dict[str, Context] = {}
        resolved: Dict[str, List[str]] = {"replayed": [], "cached": [], "executed": []}
        suspend: Dict[str, Interrupted] = {}
        replayed, cached, executed = (
            resolved["replayed"],
            resolved["cached"],
            resolved["executed"],
        )
        ready = deque(sorted(nid for nid, c in deps_left.items() if c == 0))
        cv = threading.Condition()
        completions: deque = deque()  # (nid, Future) pairs, fed by callbacks
        inflight: Dict[str, _Inflight] = {}
        node_spans: Dict[str, Any] = {}  # open node spans, keyed like inflight
        stream_handles: Dict[str, StreamHandle] = {}
        stream_identity: Dict[str, Tuple[str, str]] = {}
        stream_running = [0]  # stages alive (stall detection must see them)
        cancel = threading.Event()

        if self.journal is not None:
            self.journal.append(
                JournalRecord(
                    kind="RUN_START",
                    node_id=graph.name,
                    meta={"nodes": len(exec_nodes), **dict(run_meta or {})},
                )
            )

        def pump(nid: str, fut: Future) -> None:
            # runs on gateway threads: hand the completion to the scheduler
            with cv:
                completions.append((nid, fut))
                cv.notify()

        def request_suspend(nid: str, exc: Interrupted) -> None:
            # first interrupt wins: flush this run's queued dispatches so the
            # drain is bounded, and book the suspension at the gateway
            with cv:
                first = not suspend
                suspend.setdefault(nid, exc)
                cv.notify()
            if first:
                self.gateway.cancel_run(run_token)
                self.gateway.mark_suspended(run_token, exc.name)

        def on_requeue(req: Any, reason: str) -> None:
            # gateway requeued one of our requests (eviction / worker failure);
            # requests of other runs/clients sharing the gateway chain through
            if req.meta.get("run") != run_token:
                if prev_requeue is not None:
                    prev_requeue(req, reason)
                return
            nid = req.meta.get("node", "")
            with cv:
                st = inflight.get(nid)
                if st is not None:
                    st.attempts += 1
            if st is not None and self.journal is not None:
                self.journal.append(
                    JournalRecord(
                        kind="NODE_REQUEUE",
                        node_id=nid,
                        attempt=req.attempts,
                        meta={"task": req.task_name, "reason": reason},
                    )
                )

        def done_count() -> int:
            return len(replayed) + len(cached) + len(executed)

        def finish(nid: str, value: Any, ctx: Context, status: str) -> None:
            outputs[nid] = value
            out_ctx[nid] = ctx
            resolved[status].append(nid)
            with cv:  # stage threads decrement stream edges concurrently
                for c in children[nid]:
                    if (nid, c) in splan.stream_edges:
                        continue  # satisfied when the stage started
                    deps_left[c] -= 1
                    if deps_left[c] == 0:
                        ready.append(c)

        def satisfy_stream_edges(nid: str) -> None:
            # a stage started: unblock its stream consumers and wake the pump
            with cv:
                for c in children[nid]:
                    if (nid, c) not in splan.stream_edges:
                        continue
                    deps_left[c] -= 1
                    if deps_left[c] == 0:
                        ready.append(c)
                cv.notify()

        def stage_ctx(nid: str) -> Context:
            node = exec_nodes[nid]
            parents = [out_ctx[d] for d in gdeps[nid]]
            ctx = Context.union_all(parents) if parents else graph.origin_context
            if node.data:
                ctx = ctx.with_data(node.data, origin=node.id)
            return ctx

        def stage_thread(nid: str, fut: Future) -> None:
            try:
                result = self._run_cluster_stream_node(
                    exec_nodes[nid],
                    splan,
                    stage_ctx(nid),
                    outputs,
                    out_ctx,
                    member_to_group,
                    stream_identity,
                    stream_handles,
                    satisfy_stream_edges,
                    cancel,
                    cv,
                    run_token,
                    parent=run_span,
                )
                fut.set_result(result)
            except BaseException as exc:
                cancel.set()
                fut.set_exception(exc)

        def dispatch_stream(nid: str) -> None:
            fut: Future = Future()
            with cv:
                stream_running[0] += 1
            fut.add_done_callback(lambda f, _n=nid: pump(_n, f))
            threading.Thread(
                target=stage_thread,
                args=(nid, fut),
                name=f"stream:{nid}",
                daemon=True,
            ).start()

        def dispatch(nid: str) -> None:
            node = exec_nodes[nid]
            if isinstance(node, UnionNode):
                raise NotImplementedError(
                    "union nodes execute locally; contract before remote dispatch"
                )
            if splan.kinds.get(nid):
                dispatch_stream(nid)
                return
            parents = [out_ctx[d] for d in gdeps[nid]]
            ctx = Context.union_all(parents) if parents else graph.origin_context
            if node.data:
                ctx = ctx.with_data(node.data, origin=node.id)
            inputs = _inject_inputs(node, outputs, member_to_group)
            ctx_d, in_d = ctx.digest(), payload_digest(inputs)
            hit = self._lookup(nid, ctx_d, in_d)
            expected: Optional[str] = None
            if hit is not None:
                if hit.reexecute:
                    expected = hit.expected  # volatile: run again, verify
                else:
                    if hit.facts:
                        # re-emit journaled context facts so downstream ξ
                        # digests match the original run exactly
                        ctx = ctx.with_data(hit.facts, origin=nid)
                    finish(nid, hit.value, ctx, "replayed")
                    return
            key = self._cache_key(node, ctx_d, in_d)
            ent = self._cache_probe(nid, key, ctx_d, in_d, deps=node.deps)
            if ent is not None:
                # answered before dispatch: no gateway round-trip, no worker
                if ent.facts:
                    ctx = ctx.with_data(ent.facts, origin=nid)
                finish(nid, ent.value, ctx, "cached")
                return
            if self.journal is not None:
                self.journal.append(
                    JournalRecord(
                        kind="NODE_START",
                        node_id=nid,
                        context_digest=ctx_d,
                        input_digest=in_d,
                    )
                )
            # the node span opens only after both probes missed — replayed
            # and cached nodes emit zero spans, keeping span↔NODE_COMMIT 1:1
            span = (
                tracer.start_span(
                    nid,
                    parent=run_span,
                    kind="node",
                    attrs={"node": nid, "ctx": ctx_d, "in": in_d, "run": run_token},
                )
                if tracer.enabled
                else None
            )
            if callable(node.fn):
                fn_inputs = unwrap_digested(dict(inputs))
                attempt = 0
                while True:  # immediate retries: never sleep in the scheduler
                    try:
                        value = node.fn(ctx, **fn_inputs)
                        break
                    except Interrupted as exc:
                        if span is not None:
                            tracer.end(span, status="interrupt")
                        request_suspend(nid, exc)
                        return
                    except Exception:
                        attempt += 1
                        if attempt > node.retry_limit(0):
                            if self.journal is not None:
                                self.journal.append(
                                    JournalRecord(
                                        kind="NODE_FAIL",
                                        node_id=nid,
                                        context_digest=ctx_d,
                                        input_digest=in_d,
                                        attempt=attempt,
                                    )
                                )
                                self.journal.flush()
                            if span is not None:
                                tracer.end(span, status="error", attrs={"attempts": attempt})
                            raise
                facts = dict(value.facts) if isinstance(value, WithContext) else None
                meta = {"facts": facts} if facts else None
                if isinstance(value, WithContext):
                    ctx = ctx.with_data(value.facts, origin=nid)
                    value = value.output
                self._commit(nid, ctx_d, in_d, value, attempt, meta=meta,
                             volatile=node.volatile, expected=expected,
                             deps=node.deps)
                self._cache_store(nid, key, ctx_d, in_d, value, facts=facts)
                if span is not None:
                    tracer.end(span, attrs={"attempts": attempt + 1})
                finish(nid, value, ctx, "executed")
                return
            # register BEFORE submit: a requeue can fire the instant the
            # gateway pops the request, and it must find the node inflight
            st = _Inflight(node, ctx, ctx_d, in_d, dict(inputs), cache_key=key,
                           expected=expected)
            with cv:
                inflight[nid] = st
                if span is not None:
                    node_spans[nid] = span
            self.straggler.started(str(node.fn), nid)
            fut = self.gateway.submit(
                str(node.fn),
                # the wire context carries the node span's identity as a
                # transient obs.* fact; st.ctx (and every commit/output
                # path) keeps the clean, digest-identical original
                inject_trace(ctx, span) if span is not None else ctx,
                inputs,
                affinity_key=str(node.resources.get("affinity", "")),
                meta={"node": nid, "run": run_token},
            )
            with cv:
                st.futures.append(fut)
                st.copies += 1
            fut.add_done_callback(lambda f, _n=nid: pump(_n, f))

        def speculate() -> None:
            with cv:
                candidates = [
                    (nid, st)
                    for nid, st in inflight.items()
                    if st.copies < self.max_copies
                ]
            for nid, st in candidates:
                if st.node.resources.get("affinity"):
                    # pinned to worker-held state: a copy elsewhere could be
                    # wrong, a copy on the holder is useless — don't race it
                    continue
                name = str(st.node.fn)
                if not self.straggler.should_speculate(
                    name, nid, st.copies, self.max_copies
                ):
                    continue
                with cv:
                    spec_span = node_spans.get(nid)
                dup = self.gateway.submit(
                    name,
                    # a speculative copy belongs to the same node span
                    inject_trace(st.ctx, spec_span) if spec_span is not None else st.ctx,
                    dict(st.inputs),
                    meta={"node": nid, "run": run_token, "speculative": True},
                )
                with cv:
                    st.futures.append(dup)
                    st.copies += 1
                dup.add_done_callback(lambda f, _n=nid: pump(_n, f))

        prev_requeue = self.gateway.on_requeue
        self.gateway.on_requeue = on_requeue
        cascade_errors: List[BaseException] = []
        try:
            total = len(exec_nodes)
            while done_count() < total:
                while not suspend:  # suspending: park ready nodes, drain only
                    with cv:
                        nid = ready.popleft() if ready else None
                    if nid is None:
                        break
                    dispatch(nid)
                if done_count() >= total:
                    break
                with cv:
                    if (
                        suspend
                        and not inflight
                        and not stream_running[0]
                        and not completions
                    ):
                        break  # clean drain complete: everything launched committed
                    if not completions and (suspend or not ready):
                        if not inflight and not stream_running[0]:
                            if suspend:
                                break
                            if cascade_errors:
                                raise cascade_errors[0]  # all roots cascaded
                            left = total - done_count()
                            raise RuntimeError(
                                f"scheduler stalled: {left} nodes unfinished "
                                "with nothing in flight"
                            )
                        cv.wait(self.speculation_tick_s if self.speculative else None)
                    drained = []
                    while completions:
                        drained.append(completions.popleft())
                if not drained:
                    if self.speculative and not suspend:
                        speculate()
                    continue
                for nid, fut in drained:
                    if splan.kinds.get(nid):
                        with cv:
                            stream_running[0] -= 1
                        try:
                            value, ctx, status = fut.result()  # re-raise errors
                        except (StreamCancelled, ChannelClosed) as exc:
                            # cascade from a failure elsewhere: keep draining
                            # so the root error's own future surfaces it
                            cascade_errors.append(exc)
                            continue
                        finish(nid, value, ctx, status)
                        continue
                    with cv:
                        st = inflight.get(nid)
                        stale = st is None or fut not in st.futures
                    if stale:
                        continue  # duplicate of an already-committed node
                    try:
                        value = fut.result()
                    except Interrupted as exc:
                        # a worker reached a named interrupt point: suspend the
                        # run; any other copies of this node become stale
                        with cv:
                            inflight.pop(nid, None)
                            span = node_spans.pop(nid, None)
                        if span is not None:
                            tracer.end(span, status="interrupt")
                        self.straggler.finished(str(st.node.fn), nid)
                        request_suspend(nid, exc)
                        continue
                    except TaskCancelled:
                        # our own cancel_run flushed this queued dispatch; the
                        # node returns to the pending frontier. A still-running
                        # copy (speculation) is left to commit normally.
                        with cv:
                            st.futures.remove(fut)
                            if not st.futures:
                                inflight.pop(nid, None)
                                # redispatch opens a fresh span; drop this one
                                # unemitted so the node still maps to one span
                                span = node_spans.pop(nid, None)
                                if span is not None:
                                    tracer.discard(span)
                                self.straggler.finished(str(st.node.fn), nid)
                        continue
                    except Exception:
                        with cv:
                            st.futures.remove(fut)
                            copies_left = len(st.futures)
                        if copies_left:
                            continue  # a speculative copy may still win
                        with cv:
                            del inflight[nid]
                            span = node_spans.pop(nid, None)
                        if span is not None:
                            tracer.end(span, status="error", attrs={"attempts": st.attempts})
                        self.straggler.finished(str(st.node.fn), nid)
                        if self.journal is not None:
                            self.journal.append(
                                JournalRecord(
                                    kind="NODE_FAIL",
                                    node_id=nid,
                                    context_digest=st.ctx_digest,
                                    input_digest=st.input_digest,
                                    attempt=st.attempts,
                                )
                            )
                            self.journal.flush()
                        raise
                    with cv:
                        copies = st.copies
                        requeues = st.attempts
                        del inflight[nid]
                        span = node_spans.pop(nid, None)
                    self.straggler.finished(str(st.node.fn), nid)
                    self._commit(
                        nid, st.ctx_digest, st.input_digest, value,
                        requeues + copies - 1,
                        volatile=st.node.volatile, expected=st.expected,
                        deps=st.node.deps,
                    )
                    self._cache_store(
                        nid, st.cache_key, st.ctx_digest, st.input_digest, value
                    )
                    if span is not None:
                        tracer.end(
                            span, attrs={"copies": copies, "requeues": requeues}
                        )
                    finish(nid, value, st.ctx, "executed")
            if suspend:
                frontier = tuple(sorted(n for n in exec_nodes if n not in outputs))
                self._journal_suspend(suspend, frontier, exec_nodes)
            elif self.journal is not None:
                self.journal.append(JournalRecord(kind="RUN_END", node_id=graph.name))
                self.journal.flush()
        except BaseException as exc:
            cancel.set()
            for handle in list(stream_handles.values()):
                handle.close(error=exc)
            if self.journal is not None:
                self.journal.flush()
            if run_span is not None:
                tracer.end(run_span, status="error")
            raise
        finally:
            if self.gateway.on_requeue is on_requeue:  # don't clobber a later client
                self.gateway.on_requeue = prev_requeue
            with cv:
                inflight.clear()  # keep a dead chained handler's closure cheap
                node_spans.clear()
        if suspend:
            first_nid = next(iter(suspend))
            if run_span is not None:
                tracer.end(run_span, status="interrupt")
            return ExecutionReport(
                outputs=outputs,
                contexts=out_ctx,
                replayed=tuple(replayed),
                executed=tuple(executed),
                cached=tuple(cached),
                wall_s=time.monotonic() - t0,
                suspended=True,
                interrupt=suspend[first_nid].name,
                interrupt_node=first_nid,
                frontier=tuple(sorted(n for n in exec_nodes if n not in outputs)),
            )
        if run_span is not None:
            tracer.end(
                run_span,
                attrs={
                    "executed": len(executed),
                    "replayed": len(replayed),
                    "cached": len(cached),
                },
            )
        return ExecutionReport(
            outputs=outputs,
            contexts=out_ctx,
            replayed=tuple(replayed),
            executed=tuple(executed),
            cached=tuple(cached),
            wall_s=time.monotonic() - t0,
        )

    # -- stream stages over the gateway ---------------------------------------
    def _source_invoker(
        self,
        node: Node,
        ctx: Context,
        inputs: Mapping[str, Any],
        run_token: str,
    ) -> Callable[[int], Any]:
        """invoke(start) → chunk iterable, local generator or remote stream.

        Named sources are dispatched once through the gateway; the worker
        answers with an incremental chunk stream (frame-decoded by the
        transport — docs/streaming.md §5). The resolved future's value IS
        the chunk iterator, so iteration overlaps with remote production.
        The ``start`` offset is part of the task protocol: a registry task
        used as a stream source always receives ``start`` in its inputs.
        """
        fn = node.fn
        if callable(fn):
            if _accepts_start(fn):
                return lambda start: fn(ctx, start=start, **inputs)
            return lambda start: itertools.islice(fn(ctx, **inputs), start, None)
        name = str(fn)

        def invoke(start: int) -> Any:
            fut = self.gateway.submit(
                name,
                ctx,
                {**inputs, "start": start},
                affinity_key=str(node.resources.get("affinity", "")),
                meta={"node": node.id, "run": run_token, "stream": "source"},
            )
            stream = fut.result()
            if not hasattr(stream, "__iter__"):
                raise TypeError(
                    f"stream source task {name!r} returned a non-iterable "
                    f"{type(stream).__name__}; a source must be a generator"
                )
            return stream

        return invoke

    def _map_invoker(
        self,
        node: Node,
        ctx: Context,
        inputs: Mapping[str, Any],
        stream_kwarg: str,
        run_token: str,
    ) -> Callable[[int, Any], Any]:
        """Per-chunk mapper: named tasks become one routed request per chunk."""
        fn = node.fn
        if callable(fn):
            return lambda seq, chunk: fn(ctx, **{stream_kwarg: chunk}, **inputs)
        name = str(fn)

        def invoke_chunk(seq: int, chunk: Any) -> Any:
            fut = self.gateway.submit(
                name,
                ctx,
                {**inputs, stream_kwarg: chunk},
                affinity_key=str(node.resources.get("affinity", "")),
                meta={"node": node.id, "run": run_token, "seq": seq},
            )
            return fut.result()

        return invoke_chunk

    def _run_cluster_stream_node(
        self,
        node: Node,
        splan: StreamPlan,
        ctx: Context,
        outputs: Mapping[str, Any],
        out_ctx: Dict[str, Context],
        member_to_group: Mapping[str, str],
        stream_identity: Dict[str, Tuple[str, str]],
        stream_handles: Dict[str, StreamHandle],
        satisfy_stream_edges: Callable[[str], None],
        cancel: threading.Event,
        cv: threading.Condition,
        run_token: str,
        parent: Optional[Any] = None,
    ) -> Tuple[Any, Context, str]:
        """One gateway-side stream stage. Returns (value, ctx, status).

        The stage span wraps the uninstrumented body; a stage resolved
        entirely by replay discards its span (zero emission).
        """
        tracer = get_tracer()
        if not tracer.enabled:
            return self._run_cluster_stream_node_inner(
                node, splan, ctx, outputs, out_ctx, member_to_group,
                stream_identity, stream_handles, satisfy_stream_edges,
                cancel, cv, run_token,
            )
        span = tracer.start_span(
            node.id,
            parent=parent,
            kind="stream",
            attrs={"node": node.id, "ctx": ctx.digest(), "run": run_token},
        )
        try:
            value, out, status = self._run_cluster_stream_node_inner(
                node, splan, ctx, outputs, out_ctx, member_to_group,
                stream_identity, stream_handles, satisfy_stream_edges,
                cancel, cv, run_token,
            )
        except BaseException:
            tracer.end(span, status="error")
            raise
        if status == "replayed":
            tracer.discard(span)
        else:
            tracer.end(span, attrs={"status": status})
        return value, out, status

    def _run_cluster_stream_node_inner(
        self,
        node: Node,
        splan: StreamPlan,
        ctx: Context,
        outputs: Mapping[str, Any],
        out_ctx: Dict[str, Context],
        member_to_group: Mapping[str, str],
        stream_identity: Dict[str, Tuple[str, str]],
        stream_handles: Dict[str, StreamHandle],
        satisfy_stream_edges: Callable[[str], None],
        cancel: threading.Event,
        cv: threading.Condition,
        run_token: str,
    ) -> Tuple[Any, Context, str]:
        """The uninstrumented stage body (see ``_run_cluster_stream_node``)."""
        nid = node.id
        kind = splan.kinds[nid]
        fn_inputs, digest_inputs, stream_kwarg, sdep = self._stream_stage_inputs(
            node, splan, outputs, member_to_group, stream_identity
        )
        ctx_d = ctx.digest()
        in_d = payload_digest(digest_inputs)

        handle: Optional[StreamHandle] = None
        if kind in ("source", "map"):
            handle = StreamHandle(
                nid,
                splan.subscribers.get(nid, ()),
                capacity=self.channel_capacity,
            )
        with cv:
            # ctx/identity/handle are published before consumers unblock —
            # a stage's ξ is final at start (stages cannot emit facts)
            out_ctx[nid] = ctx
            stream_identity[nid] = (ctx_d, in_d)
            if handle is not None:
                stream_handles[nid] = handle
        satisfy_stream_edges(nid)

        upstream = stream_handles[sdep].subscribe(nid) if sdep else None

        if kind == "reduce":
            hit = self._lookup(nid, ctx_d, in_d)
            if hit is not None:
                upstream.abandon()
                if hit.facts:
                    ctx = ctx.with_data(hit.facts, origin=nid)
                return hit.value, ctx, "replayed"
            self._journal_stream_start(nid, kind, ctx_d, in_d, 0)
            chunk_iter = reduce_iter(upstream, cancel)
            if callable(node.fn):
                value = node.fn(ctx, **{stream_kwarg: chunk_iter}, **fn_inputs)
            else:
                # named reduce: the worker gets the materialized chunk list
                # (a registry task cannot consume a live cross-host iterator)
                fut = self.gateway.submit(
                    str(node.fn),
                    ctx,
                    {**fn_inputs, stream_kwarg: list(chunk_iter)},
                    meta={"node": nid, "run": run_token, "stream": "reduce"},
                )
                value = fut.result()
            facts = dict(value.facts) if isinstance(value, WithContext) else None
            if isinstance(value, WithContext):
                ctx = ctx.with_data(value.facts, origin=nid)
                value = value.output
            self._commit(
                nid, ctx_d, in_d, value, 0,
                meta={"facts": facts} if facts else None, deps=node.deps,
            )
            return value, ctx, "executed"

        log = ChunkLog(self.journal, self.replay, nid, ctx_d, in_d, deps=node.deps)
        if not log.eos:
            self._journal_stream_start(nid, kind, ctx_d, in_d, log.next_seq)
        if kind == "source":
            values, status = run_source_stage(
                nid,
                log,
                handle,
                self._source_invoker(node, ctx, fn_inputs, run_token),
                cancel,
                retries=max(node.retry_limit(0), self.stream_retries),
            )
        else:
            values, status = run_map_stage(
                nid,
                log,
                upstream,
                handle,
                self._map_invoker(node, ctx, fn_inputs, stream_kwarg, run_token),
                cancel,
                retries=node.retry_limit(0),
            )
        return values, ctx, status
