"""Executors: run a ContextGraph durably, locally or through a Gateway.

Execution semantics (the paper's logical flow, §4):
  1. contract SCCs → union nodes (DAG guarantee),
  2. propagate ξ per the union rules,
  3. execute nodes in dependency order with dependency-injected inputs,
  4. journal every commit; replay skips nodes whose (id, ξ-digest, input-digest)
     already committed — durable, effectively-once execution.

Union nodes execute their members as ONE atomic unit (single commit), in
deterministic member order, with intra-group outputs injected among members.

``LocalExecutor`` runs tasks on a thread pool with dependency-counted
readiness (maximum overlap). ``ClusterExecutor`` dispatches named tasks
through a Gateway to remote/in-proc workers with the same barrier-free
dependency-counted readiness, event-driven completion consumption, global
straggler speculation, and requeue-on-eviction fault tolerance (first
commit wins — duplicates are idempotent by replay). The full dispatch/
readiness/eviction/speculation state machine is specified in
docs/distributed-execution.md.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from .context import Context, EMPTY_CONTEXT
from .durable import Journal, JournalRecord, ReplayCache, payload_digest
from .failure import RetryPolicy, StragglerWatch
from .gateway import Gateway
from .graph import ContextGraph, Node, UnionNode

__all__ = ["WithContext", "ExecutionReport", "LocalExecutor", "ClusterExecutor"]

_INLINE_LIMIT = 1 << 20  # 1 MiB: larger outputs must go through the spill store

_RUN_TOKENS = itertools.count()  # distinguishes concurrent runs on one gateway


@dataclass
class WithContext:
    """Task return wrapper: ``return WithContext(out, {"fact": 1})`` emits facts."""

    output: Any
    facts: Mapping[str, Any]


@dataclass
class ExecutionReport:
    outputs: Dict[str, Any]
    contexts: Dict[str, Context]
    replayed: Tuple[str, ...]
    executed: Tuple[str, ...]
    wall_s: float


class _BaseExecutor:
    def __init__(self, journal: Optional[Journal] = None,
                 retry: Optional[RetryPolicy] = None,
                 spill_put: Optional[Callable[[str, Any], str]] = None,
                 spill_get: Optional[Callable[[str], Any]] = None):
        self.journal = journal
        self.retry = retry or RetryPolicy()
        self.replay = ReplayCache(journal) if journal is not None else ReplayCache()
        self._spill_put = spill_put
        self._spill_get = spill_get

    # -- durable commit machinery -------------------------------------------
    def _commit(self, node_id: str, ctx_digest: str, in_digest: str, output: Any,
                attempt: int, meta: Optional[dict] = None) -> None:
        payload, ref = output, ""
        if self._spill_put is not None:
            try:
                import sys

                approx = payload_digest(output)  # also probes serializability
                del approx
            except Exception:
                ref = self._spill_put(node_id, output)
                payload = None
        rec = JournalRecord(kind="NODE_COMMIT", node_id=node_id,
                            context_digest=ctx_digest, input_digest=in_digest,
                            output_digest=payload_digest(output) if ref == "" else ref,
                            payload=payload if ref == "" else None, ref=ref,
                            attempt=attempt, meta=meta or {})
        if self.journal is not None:
            self.journal.append(rec)
        self.replay.record(rec)

    @staticmethod
    def _readiness(exec_nodes: Mapping[str, Any],
                   member_to_group: Mapping[str, str]):
        """Dependency-counted scheduling state shared by both executors:
        (gdeps, deps_left, children)."""
        gdeps = ContextGraph.group_deps(exec_nodes, member_to_group)
        deps_left = {nid: len(gdeps[nid]) for nid in exec_nodes}
        children: Dict[str, List[str]] = {nid: [] for nid in exec_nodes}
        for nid in exec_nodes:
            for d in gdeps[nid]:
                children[d].append(nid)
        return gdeps, deps_left, children

    def _lookup(self, node_id: str, ctx_digest: str, in_digest: str
                ) -> "Optional[_Found]":
        rec = self.replay.lookup(node_id, ctx_digest, in_digest)
        if rec is None:
            return None
        facts = rec.meta.get("facts")
        if rec.ref:
            if self._spill_get is None:
                return None  # cannot resolve; re-execute
            return _Found(self._spill_get(rec.ref), facts)
        return _Found(rec.payload, facts)


@dataclass
class _Found:
    value: Any
    facts: Optional[Mapping[str, Any]] = None  # journaled WithContext facts


def _inject_inputs(node: Node, outputs: Mapping[str, Any],
                   member_to_group: Mapping[str, str]) -> Dict[str, Any]:
    """Dependency injection: map each dep's output to the node's kwarg."""
    inputs: Dict[str, Any] = {}
    for dep in node.deps:
        gid = member_to_group.get(dep, dep)
        out = outputs[gid]
        if gid != dep and isinstance(out, Mapping) and dep in out:
            out = out[dep]  # a specific member of a union node
        inputs[node.kwarg_for(dep)] = out
    return inputs


class LocalExecutor(_BaseExecutor):
    """In-process threaded executor with dependency-counted scheduling."""

    def __init__(self, max_workers: int = 8, **kw):
        super().__init__(**kw)
        self.max_workers = max_workers

    def run(self, graph: ContextGraph) -> ExecutionReport:
        t0 = time.time()
        levels, exec_nodes, member_to_group = graph.schedule()
        xi = graph.propagate_contexts(exec_nodes)
        outputs: Dict[str, Any] = {}
        out_ctx: Dict[str, Context] = {}
        replayed: List[str] = []
        executed: List[str] = []
        lock = threading.Lock()

        # dependency counting for maximal overlap (scheduling-level deps)
        gdeps, deps_left, children = self._readiness(exec_nodes, member_to_group)

        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_START", node_id=graph.name,
                                              meta={"nodes": len(exec_nodes)}))

        def effective_ctx(nid: str) -> Context:
            node = exec_nodes[nid]
            parents = [out_ctx[d] for d in gdeps[nid]]
            base = Context.union_all(parents) if parents else graph.origin_context
            if isinstance(node, UnionNode):
                for m in sorted(node.members, key=lambda n: n.id):
                    if m.data:
                        base = base.with_data(m.data, origin=m.id)
            elif node.data:
                base = base.with_data(node.data, origin=node.id)
            return base

        def run_node(nid: str) -> None:
            node = exec_nodes[nid]
            ctx = effective_ctx(nid)
            if isinstance(node, UnionNode):
                self._run_union(node, ctx, outputs, member_to_group,
                                replayed, executed, lock)
            else:
                inputs = _inject_inputs(node, outputs, member_to_group)
                value, was_replayed = self._run_atomic(node, ctx, inputs)
                with lock:
                    if isinstance(value, WithContext):
                        ctx = ctx.with_data(value.facts, origin=node.id)
                        value = value.output
                    outputs[nid] = value
                    (replayed if was_replayed else executed).append(nid)
            with lock:
                out_ctx[nid] = ctx

        frontier = [nid for nid, c in deps_left.items() if c == 0]
        with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
            futures: Dict[Future, str] = {}
            for nid in sorted(frontier):
                futures[pool.submit(run_node, nid)] = nid
            while futures:
                done, _ = wait(list(futures), return_when=FIRST_COMPLETED)
                for f in done:
                    nid = futures.pop(f)
                    f.result()  # re-raise task errors
                    for c in children[nid]:
                        with lock:
                            deps_left[c] -= 1
                            ready = deps_left[c] == 0
                        if ready:
                            futures[pool.submit(run_node, c)] = c

        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_END", node_id=graph.name))
            self.journal.flush()
        return ExecutionReport(outputs=outputs, contexts=out_ctx,
                               replayed=tuple(replayed), executed=tuple(executed),
                               wall_s=time.time() - t0)

    # -- atomic execution with retries ----------------------------------------
    def _run_atomic(self, node: Node, ctx: Context,
                    inputs: Mapping[str, Any]) -> Tuple[Any, bool]:
        ctx_d = ctx.digest()
        in_d = payload_digest(inputs)
        hit = self._lookup(node.id, ctx_d, in_d)
        if hit is not None:
            if hit.facts:
                # re-emit journaled context facts so downstream ξ digests
                # match the original run exactly (replay completeness)
                return WithContext(hit.value, hit.facts), True
            return hit.value, True
        if node.fn is None:
            raise ValueError(f"node {node.id!r} has no callable")
        attempt = 0
        while True:
            try:
                if self.journal is not None:
                    self.journal.append(JournalRecord(
                        kind="NODE_START", node_id=node.id, context_digest=ctx_d,
                        input_digest=in_d, attempt=attempt))
                value = node.fn(ctx, **inputs)
                break
            except Exception:
                attempt += 1
                if attempt > max(node.retries, self.retry.max_attempts - 1):
                    if self.journal is not None:
                        self.journal.append(JournalRecord(
                            kind="NODE_FAIL", node_id=node.id, context_digest=ctx_d,
                            input_digest=in_d, attempt=attempt))
                    raise
                time.sleep(self.retry.delay(attempt))
        commit_value = value.output if isinstance(value, WithContext) else value
        meta = {"facts": dict(value.facts)} if isinstance(value, WithContext) \
            else None
        self._commit(node.id, ctx_d, in_d, commit_value, attempt, meta=meta)
        return value, False

    def _run_union(self, group: UnionNode, ctx: Context, outputs: Dict[str, Any],
                   member_to_group: Mapping[str, str], replayed: List[str],
                   executed: List[str], lock: threading.Lock) -> None:
        """Union node = ONE atomic commit over deterministic member order."""
        ctx_d = ctx.digest()
        ext_inputs = {}
        with lock:
            for m in group.members:
                for d in m.deps:
                    gid = member_to_group.get(d, d)
                    if gid != group.id and gid in outputs:
                        ext_inputs[d] = outputs[gid]
        in_d = payload_digest(ext_inputs)
        hit = self._lookup(group.id, ctx_d, in_d)
        if hit is not None:
            with lock:
                outputs[group.id] = hit.value
                replayed.append(group.id)
            return
        member_out: Dict[str, Any] = {}
        # fixed-point style deterministic order: members sorted by id; a member
        # whose intra-group dep isn't ready yet sees the PREVIOUS iteration's
        # value (co-dependent semantics), seeded by its Ψ data or None.
        order = sorted(group.members, key=lambda n: n.id)
        seed = {m.id: dict(m.data).get("__seed__") for m in order}
        for m in order:
            inputs = {}
            for d in m.deps:
                gid = member_to_group.get(d, d)
                if gid == group.id:
                    inputs[m.kwarg_for(d)] = member_out.get(d, seed.get(d))
                else:
                    out = ext_inputs.get(d)
                    inputs[m.kwarg_for(d)] = out
            if m.fn is None:
                raise ValueError(f"union member {m.id!r} has no callable")
            v = m.fn(ctx, **inputs)
            member_out[m.id] = v.output if isinstance(v, WithContext) else v
        self._commit(group.id, ctx_d, in_d, member_out, 0,
                     meta={"members": [m.id for m in order]})
        with lock:
            outputs[group.id] = member_out
            executed.append(group.id)


@dataclass
class _Inflight:
    """Scheduler-side state of a node currently dispatched through the gateway."""

    node: Node
    ctx: Context
    ctx_digest: str
    input_digest: str
    inputs: Dict[str, Any]
    futures: List[Future] = field(default_factory=list)  # still-live attempts
    copies: int = 0    # total submissions ever made (speculation budget)
    attempts: int = 0  # gateway-level requeues observed (evictions, failures)


class ClusterExecutor(_BaseExecutor):
    """Gateway-dispatched executor: barrier-free dependency-counted dataflow.

    Node.fn may be a string (registry task name) — required for remote
    dispatch — or a callable (executed gateway-side, e.g. reductions).

    Scheduling is event-driven, not staged: a node is dispatched the moment
    its last dependency commits (no toposort-level barriers), and completions
    are consumed from a condition-variable pump fed by future callbacks — the
    scheduler blocks in ``Condition.wait``, never in a sleep-poll loop.

    Straggler speculation is global rather than per-level: on every
    ``speculation_tick_s`` wakeup without completions, any inflight node whose
    elapsed time exceeds ``straggler.threshold × median`` of same-task
    completions gets a duplicate on another worker, up to ``max_copies``.
    The first completion wins; duplicates are idempotent by durable replay.

    Fault tolerance: when the gateway evicts a dead worker (heartbeat lost or
    system-level failure), in-flight requests are requeued on survivors and
    each requeue is journaled as a ``NODE_REQUEUE`` record carrying the
    attempt count. See docs/distributed-execution.md for the state machine.
    """

    def __init__(self, gateway: Gateway, speculative: bool = True,
                 speculation_tick_s: float = 0.05, max_copies: int = 3, **kw):
        super().__init__(**kw)
        self.gateway = gateway
        self.speculative = speculative
        self.speculation_tick_s = speculation_tick_s
        self.max_copies = max_copies
        self.straggler = StragglerWatch()

    def run(self, graph: ContextGraph) -> ExecutionReport:
        t0 = time.time()
        _levels, exec_nodes, member_to_group = graph.schedule()  # validates DAG
        gdeps, deps_left, children = self._readiness(exec_nodes, member_to_group)
        run_token = f"{graph.name}#{next(_RUN_TOKENS)}"  # this run's requests

        outputs: Dict[str, Any] = {}
        out_ctx: Dict[str, Context] = {}
        replayed: List[str] = []
        executed: List[str] = []
        ready = deque(sorted(nid for nid, c in deps_left.items() if c == 0))
        cv = threading.Condition()
        completions: deque = deque()  # (nid, Future) pairs, fed by callbacks
        inflight: Dict[str, _Inflight] = {}

        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_START", node_id=graph.name,
                                              meta={"nodes": len(exec_nodes)}))

        def pump(nid: str, fut: Future) -> None:
            # runs on gateway threads: hand the completion to the scheduler
            with cv:
                completions.append((nid, fut))
                cv.notify()

        def on_requeue(req: Any, reason: str) -> None:
            # gateway requeued one of our requests (eviction / worker failure);
            # requests of other runs/clients sharing the gateway chain through
            if req.meta.get("run") != run_token:
                if prev_requeue is not None:
                    prev_requeue(req, reason)
                return
            nid = req.meta.get("node", "")
            with cv:
                st = inflight.get(nid)
                if st is not None:
                    st.attempts += 1
            if st is not None and self.journal is not None:
                self.journal.append(JournalRecord(
                    kind="NODE_REQUEUE", node_id=nid, attempt=req.attempts,
                    meta={"task": req.task_name, "reason": reason}))

        def finish(nid: str, value: Any, ctx: Context, was_replayed: bool) -> None:
            outputs[nid] = value
            out_ctx[nid] = ctx
            (replayed if was_replayed else executed).append(nid)
            for c in children[nid]:
                deps_left[c] -= 1
                if deps_left[c] == 0:
                    ready.append(c)

        def dispatch(nid: str) -> None:
            node = exec_nodes[nid]
            if isinstance(node, UnionNode):
                raise NotImplementedError(
                    "union nodes execute locally; contract before remote dispatch")
            parents = [out_ctx[d] for d in gdeps[nid]]
            ctx = Context.union_all(parents) if parents else graph.origin_context
            if node.data:
                ctx = ctx.with_data(node.data, origin=node.id)
            inputs = _inject_inputs(node, outputs, member_to_group)
            ctx_d, in_d = ctx.digest(), payload_digest(inputs)
            hit = self._lookup(nid, ctx_d, in_d)
            if hit is not None:
                if hit.facts:
                    # re-emit journaled context facts so downstream ξ digests
                    # match the original run exactly (replay completeness)
                    ctx = ctx.with_data(hit.facts, origin=nid)
                finish(nid, hit.value, ctx, True)
                return
            if self.journal is not None:
                self.journal.append(JournalRecord(
                    kind="NODE_START", node_id=nid,
                    context_digest=ctx_d, input_digest=in_d))
            if callable(node.fn):
                attempt = 0
                while True:  # immediate retries: never sleep in the scheduler
                    try:
                        value = node.fn(ctx, **inputs)
                        break
                    except Exception:
                        attempt += 1
                        if attempt > node.retries:
                            if self.journal is not None:
                                self.journal.append(JournalRecord(
                                    kind="NODE_FAIL", node_id=nid,
                                    context_digest=ctx_d, input_digest=in_d,
                                    attempt=attempt))
                                self.journal.flush()
                            raise
                meta = None
                if isinstance(value, WithContext):
                    meta = {"facts": dict(value.facts)}
                    ctx = ctx.with_data(value.facts, origin=nid)
                    value = value.output
                self._commit(nid, ctx_d, in_d, value, attempt, meta=meta)
                finish(nid, value, ctx, False)
                return
            # register BEFORE submit: a requeue can fire the instant the
            # gateway pops the request, and it must find the node inflight
            st = _Inflight(node, ctx, ctx_d, in_d, dict(inputs))
            with cv:
                inflight[nid] = st
            self.straggler.started(str(node.fn), nid)
            fut = self.gateway.submit(
                str(node.fn), ctx, inputs,
                affinity_key=str(node.resources.get("affinity", "")),
                meta={"node": nid, "run": run_token})
            with cv:
                st.futures.append(fut)
                st.copies += 1
            fut.add_done_callback(lambda f, _n=nid: pump(_n, f))

        def speculate() -> None:
            with cv:
                candidates = [(nid, st) for nid, st in inflight.items()
                              if st.copies < self.max_copies]
            for nid, st in candidates:
                if st.node.resources.get("affinity"):
                    # pinned to worker-held state: a copy elsewhere could be
                    # wrong, a copy on the holder is useless — don't race it
                    continue
                name = str(st.node.fn)
                if not self.straggler.should_speculate(name, nid, st.copies,
                                                       self.max_copies):
                    continue
                dup = self.gateway.submit(
                    name, st.ctx, dict(st.inputs),
                    meta={"node": nid, "run": run_token, "speculative": True})
                with cv:
                    st.futures.append(dup)
                    st.copies += 1
                dup.add_done_callback(lambda f, _n=nid: pump(_n, f))

        prev_requeue = self.gateway.on_requeue
        self.gateway.on_requeue = on_requeue
        try:
            total = len(exec_nodes)
            while len(replayed) + len(executed) < total:
                while ready:
                    dispatch(ready.popleft())
                if len(replayed) + len(executed) >= total:
                    break
                with cv:
                    if not completions:
                        if not inflight:
                            left = total - len(replayed) - len(executed)
                            raise RuntimeError(
                                f"scheduler stalled: {left} nodes unfinished "
                                "with nothing in flight")
                        cv.wait(self.speculation_tick_s if self.speculative
                                else None)
                    drained = []
                    while completions:
                        drained.append(completions.popleft())
                if not drained:
                    if self.speculative:
                        speculate()
                    continue
                for nid, fut in drained:
                    with cv:
                        st = inflight.get(nid)
                        stale = st is None or fut not in st.futures
                    if stale:
                        continue  # duplicate of an already-committed node
                    try:
                        value = fut.result()
                    except Exception:
                        with cv:
                            st.futures.remove(fut)
                            copies_left = len(st.futures)
                        if copies_left:
                            continue  # a speculative copy may still win
                        with cv:
                            del inflight[nid]
                        self.straggler.finished(str(st.node.fn), nid)
                        if self.journal is not None:
                            self.journal.append(JournalRecord(
                                kind="NODE_FAIL", node_id=nid,
                                context_digest=st.ctx_digest,
                                input_digest=st.input_digest, attempt=st.attempts))
                            self.journal.flush()
                        raise
                    with cv:
                        copies = st.copies
                        requeues = st.attempts
                        del inflight[nid]
                    self.straggler.finished(str(st.node.fn), nid)
                    self._commit(nid, st.ctx_digest, st.input_digest, value,
                                 requeues + copies - 1)
                    finish(nid, value, st.ctx, False)
            if self.journal is not None:
                self.journal.append(JournalRecord(kind="RUN_END", node_id=graph.name))
                self.journal.flush()
        finally:
            if self.gateway.on_requeue is on_requeue:  # don't clobber a later client
                self.gateway.on_requeue = prev_requeue
            with cv:
                inflight.clear()  # keep a dead chained handler's closure cheap
        return ExecutionReport(outputs=outputs, contexts=out_ctx,
                               replayed=tuple(replayed), executed=tuple(executed),
                               wall_s=time.time() - t0)
