"""Executors: run a ContextGraph durably, locally or through a Gateway.

Execution semantics (the paper's logical flow, §4):
  1. contract SCCs → union nodes (DAG guarantee),
  2. propagate ξ per the union rules,
  3. execute nodes in dependency order with dependency-injected inputs,
  4. journal every commit; replay skips nodes whose (id, ξ-digest, input-digest)
     already committed — durable, effectively-once execution.

Union nodes execute their members as ONE atomic unit (single commit), in
deterministic member order, with intra-group outputs injected among members.

``LocalExecutor`` runs tasks on a thread pool with dependency-counted
readiness (maximum overlap). ``ClusterExecutor`` dispatches named tasks
through a Gateway to remote/in-proc workers, with speculative re-execution
of stragglers (first commit wins — duplicates are idempotent by replay).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from .context import Context, EMPTY_CONTEXT
from .durable import Journal, JournalRecord, ReplayCache, payload_digest
from .failure import RetryPolicy, StragglerWatch
from .gateway import Gateway
from .graph import ContextGraph, Node, UnionNode

__all__ = ["WithContext", "ExecutionReport", "LocalExecutor", "ClusterExecutor"]

_INLINE_LIMIT = 1 << 20  # 1 MiB: larger outputs must go through the spill store


@dataclass
class WithContext:
    """Task return wrapper: ``return WithContext(out, {"fact": 1})`` emits facts."""

    output: Any
    facts: Mapping[str, Any]


@dataclass
class ExecutionReport:
    outputs: Dict[str, Any]
    contexts: Dict[str, Context]
    replayed: Tuple[str, ...]
    executed: Tuple[str, ...]
    wall_s: float


class _BaseExecutor:
    def __init__(self, journal: Optional[Journal] = None,
                 retry: Optional[RetryPolicy] = None,
                 spill_put: Optional[Callable[[str, Any], str]] = None,
                 spill_get: Optional[Callable[[str], Any]] = None):
        self.journal = journal
        self.retry = retry or RetryPolicy()
        self.replay = ReplayCache(journal) if journal is not None else ReplayCache()
        self._spill_put = spill_put
        self._spill_get = spill_get

    # -- durable commit machinery -------------------------------------------
    def _commit(self, node_id: str, ctx_digest: str, in_digest: str, output: Any,
                attempt: int, meta: Optional[dict] = None) -> None:
        payload, ref = output, ""
        if self._spill_put is not None:
            try:
                import sys

                approx = payload_digest(output)  # also probes serializability
                del approx
            except Exception:
                ref = self._spill_put(node_id, output)
                payload = None
        rec = JournalRecord(kind="NODE_COMMIT", node_id=node_id,
                            context_digest=ctx_digest, input_digest=in_digest,
                            output_digest=payload_digest(output) if ref == "" else ref,
                            payload=payload if ref == "" else None, ref=ref,
                            attempt=attempt, meta=meta or {})
        if self.journal is not None:
            self.journal.append(rec)
        self.replay.record(rec)

    def _lookup(self, node_id: str, ctx_digest: str, in_digest: str) -> Optional[Any]:
        rec = self.replay.lookup(node_id, ctx_digest, in_digest)
        if rec is None:
            return None
        if rec.ref:
            if self._spill_get is None:
                return None  # cannot resolve; re-execute
            return _Found(self._spill_get(rec.ref))
        return _Found(rec.payload)


@dataclass
class _Found:
    value: Any


def _inject_inputs(node: Node, outputs: Mapping[str, Any],
                   member_to_group: Mapping[str, str]) -> Dict[str, Any]:
    """Dependency injection: map each dep's output to the node's kwarg."""
    inputs: Dict[str, Any] = {}
    for dep in node.deps:
        gid = member_to_group.get(dep, dep)
        out = outputs[gid]
        if gid != dep and isinstance(out, Mapping) and dep in out:
            out = out[dep]  # a specific member of a union node
        inputs[node.kwarg_for(dep)] = out
    return inputs


class LocalExecutor(_BaseExecutor):
    """In-process threaded executor with dependency-counted scheduling."""

    def __init__(self, max_workers: int = 8, **kw):
        super().__init__(**kw)
        self.max_workers = max_workers

    def run(self, graph: ContextGraph) -> ExecutionReport:
        t0 = time.time()
        levels, exec_nodes, member_to_group = graph.schedule()
        xi = graph.propagate_contexts(exec_nodes)
        outputs: Dict[str, Any] = {}
        out_ctx: Dict[str, Context] = {}
        replayed: List[str] = []
        executed: List[str] = []
        lock = threading.Lock()

        # dependency counting for maximal overlap (scheduling-level deps)
        gdeps = ContextGraph.group_deps(exec_nodes, member_to_group)
        deps_left = {nid: len(gdeps[nid]) for nid in exec_nodes}
        children: Dict[str, List[str]] = {nid: [] for nid in exec_nodes}
        for nid in exec_nodes:
            for d in gdeps[nid]:
                children[d].append(nid)

        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_START", node_id=graph.name,
                                              meta={"nodes": len(exec_nodes)}))

        def effective_ctx(nid: str) -> Context:
            node = exec_nodes[nid]
            parents = [out_ctx[d] for d in gdeps[nid]]
            base = Context.union_all(parents) if parents else graph.origin_context
            if isinstance(node, UnionNode):
                for m in sorted(node.members, key=lambda n: n.id):
                    if m.data:
                        base = base.with_data(m.data, origin=m.id)
            elif node.data:
                base = base.with_data(node.data, origin=node.id)
            return base

        def run_node(nid: str) -> None:
            node = exec_nodes[nid]
            ctx = effective_ctx(nid)
            if isinstance(node, UnionNode):
                self._run_union(node, ctx, outputs, member_to_group,
                                replayed, executed, lock)
            else:
                inputs = _inject_inputs(node, outputs, member_to_group)
                value, was_replayed = self._run_atomic(node, ctx, inputs)
                with lock:
                    if isinstance(value, WithContext):
                        ctx = ctx.with_data(value.facts, origin=node.id)
                        value = value.output
                    outputs[nid] = value
                    (replayed if was_replayed else executed).append(nid)
            with lock:
                out_ctx[nid] = ctx

        frontier = [nid for nid, c in deps_left.items() if c == 0]
        with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
            futures: Dict[Future, str] = {}
            for nid in sorted(frontier):
                futures[pool.submit(run_node, nid)] = nid
            while futures:
                done, _ = wait(list(futures), return_when=FIRST_COMPLETED)
                for f in done:
                    nid = futures.pop(f)
                    f.result()  # re-raise task errors
                    for c in children[nid]:
                        with lock:
                            deps_left[c] -= 1
                            ready = deps_left[c] == 0
                        if ready:
                            futures[pool.submit(run_node, c)] = c

        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_END", node_id=graph.name))
            self.journal.flush()
        return ExecutionReport(outputs=outputs, contexts=out_ctx,
                               replayed=tuple(replayed), executed=tuple(executed),
                               wall_s=time.time() - t0)

    # -- atomic execution with retries ----------------------------------------
    def _run_atomic(self, node: Node, ctx: Context,
                    inputs: Mapping[str, Any]) -> Tuple[Any, bool]:
        ctx_d = ctx.digest()
        in_d = payload_digest(inputs)
        hit = self._lookup(node.id, ctx_d, in_d)
        if hit is not None:
            rec = self.replay.lookup(node.id, ctx_d, in_d)
            facts = rec.meta.get("facts") if rec is not None else None
            if facts:
                # re-emit journaled context facts so downstream ξ digests
                # match the original run exactly (replay completeness)
                return WithContext(hit.value, facts), True
            return hit.value, True
        if node.fn is None:
            raise ValueError(f"node {node.id!r} has no callable")
        attempt = 0
        while True:
            try:
                if self.journal is not None:
                    self.journal.append(JournalRecord(
                        kind="NODE_START", node_id=node.id, context_digest=ctx_d,
                        input_digest=in_d, attempt=attempt))
                value = node.fn(ctx, **inputs)
                break
            except Exception:
                attempt += 1
                if attempt > max(node.retries, self.retry.max_attempts - 1):
                    if self.journal is not None:
                        self.journal.append(JournalRecord(
                            kind="NODE_FAIL", node_id=node.id, context_digest=ctx_d,
                            input_digest=in_d, attempt=attempt))
                    raise
                time.sleep(self.retry.delay(attempt))
        commit_value = value.output if isinstance(value, WithContext) else value
        meta = {"facts": dict(value.facts)} if isinstance(value, WithContext) \
            else None
        self._commit(node.id, ctx_d, in_d, commit_value, attempt, meta=meta)
        return value, False

    def _run_union(self, group: UnionNode, ctx: Context, outputs: Dict[str, Any],
                   member_to_group: Mapping[str, str], replayed: List[str],
                   executed: List[str], lock: threading.Lock) -> None:
        """Union node = ONE atomic commit over deterministic member order."""
        ctx_d = ctx.digest()
        ext_inputs = {}
        with lock:
            for m in group.members:
                for d in m.deps:
                    gid = member_to_group.get(d, d)
                    if gid != group.id and gid in outputs:
                        ext_inputs[d] = outputs[gid]
        in_d = payload_digest(ext_inputs)
        hit = self._lookup(group.id, ctx_d, in_d)
        if hit is not None:
            with lock:
                outputs[group.id] = hit.value
                replayed.append(group.id)
            return
        member_out: Dict[str, Any] = {}
        # fixed-point style deterministic order: members sorted by id; a member
        # whose intra-group dep isn't ready yet sees the PREVIOUS iteration's
        # value (co-dependent semantics), seeded by its Ψ data or None.
        order = sorted(group.members, key=lambda n: n.id)
        seed = {m.id: dict(m.data).get("__seed__") for m in order}
        for m in order:
            inputs = {}
            for d in m.deps:
                gid = member_to_group.get(d, d)
                if gid == group.id:
                    inputs[m.kwarg_for(d)] = member_out.get(d, seed.get(d))
                else:
                    out = ext_inputs.get(d)
                    inputs[m.kwarg_for(d)] = out
            if m.fn is None:
                raise ValueError(f"union member {m.id!r} has no callable")
            v = m.fn(ctx, **inputs)
            member_out[m.id] = v.output if isinstance(v, WithContext) else v
        self._commit(group.id, ctx_d, in_d, member_out, 0,
                     meta={"members": [m.id for m in order]})
        with lock:
            outputs[group.id] = member_out
            executed.append(group.id)


class ClusterExecutor(_BaseExecutor):
    """Gateway-dispatched executor: nodes name registry tasks on workers.

    Node.fn may be a string (registry task name) — required for remote
    dispatch — or a callable (executed gateway-side, e.g. reductions).
    Stragglers get a speculative duplicate after ``straggler.threshold ×
    median`` elapsed; the first completion wins.
    """

    def __init__(self, gateway: Gateway, speculative: bool = True, **kw):
        super().__init__(**kw)
        self.gateway = gateway
        self.speculative = speculative
        self.straggler = StragglerWatch()

    def run(self, graph: ContextGraph) -> ExecutionReport:
        t0 = time.time()
        levels, exec_nodes, member_to_group = graph.schedule()
        outputs: Dict[str, Any] = {}
        out_ctx: Dict[str, Context] = {}
        replayed: List[str] = []
        executed: List[str] = []
        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_START", node_id=graph.name,
                                              meta={"nodes": len(exec_nodes)}))
        for level in levels:
            pending: Dict[str, Tuple[Node, Context, str, str, List[Future], float]] = {}
            for nid in level:
                node = exec_nodes[nid]
                if isinstance(node, UnionNode):
                    raise NotImplementedError(
                        "union nodes execute locally; contract before remote dispatch")
                parents = [out_ctx[member_to_group.get(d, d)] for d in node.deps]
                ctx = Context.union_all(parents) if parents else graph.origin_context
                if node.data:
                    ctx = ctx.with_data(node.data, origin=node.id)
                inputs = _inject_inputs(node, outputs, member_to_group)
                ctx_d, in_d = ctx.digest(), payload_digest(inputs)
                hit = self._lookup(nid, ctx_d, in_d)
                if hit is not None:
                    outputs[nid], out_ctx[nid] = hit.value, ctx
                    replayed.append(nid)
                    continue
                if callable(node.fn):
                    value = node.fn(ctx, **inputs)
                    if isinstance(value, WithContext):
                        ctx = ctx.with_data(value.facts, origin=nid)
                        value = value.output
                    self._commit(nid, ctx_d, in_d, value, 0)
                    outputs[nid], out_ctx[nid] = value, ctx
                    executed.append(nid)
                    continue
                fut = self.gateway.submit(str(node.fn), ctx, inputs,
                                          affinity_key=str(node.resources.get(
                                              "affinity", "")))
                self.straggler.started(str(node.fn), nid)
                pending[nid] = (node, ctx, ctx_d, in_d, [fut], time.time())
            # wait with straggler mitigation
            while pending:
                for nid in list(pending):
                    node, ctx, ctx_d, in_d, futs, started = pending[nid]
                    done = next((f for f in futs if f.done()), None)
                    if done is not None:
                        value = done.result()
                        self.straggler.finished(str(node.fn), nid)
                        self._commit(nid, ctx_d, in_d, value, len(futs) - 1)
                        outputs[nid], out_ctx[nid] = value, ctx
                        executed.append(nid)
                        del pending[nid]
                        continue
                    med = self.straggler.median(str(node.fn))
                    if (self.speculative and med is not None and len(futs) < 3
                            and time.time() - started > self.straggler.threshold * med):
                        futs.append(self.gateway.submit(str(node.fn), ctx,
                                                        dict(_inject_inputs(
                                                            node, outputs,
                                                            member_to_group))))
                if pending:
                    time.sleep(0.002)
        if self.journal is not None:
            self.journal.append(JournalRecord(kind="RUN_END", node_id=graph.name))
            self.journal.flush()
        return ExecutionReport(outputs=outputs, contexts=out_ctx,
                               replayed=tuple(replayed), executed=tuple(executed),
                               wall_s=time.time() - t0)
