"""Context: the ξ of SerPyTor §4.1.

A context is a *set of provenance-tagged facts*. The paper defines context
propagation as set union:

    ξ(R)  = ξ(∅) ∪ Ψ(R)                      (root)
    ξ(n)  = ⋃_{p ∈ origins(n)} ξ(p) ∪ Ψ(n)   (independent origins)
    ξ(A') = ξ(A) ∪ ξ(B) ∪ Ψ(A) ∪ Ψ(B)        (union node for co-dependent origins)

We realize the union semantics exactly: a Context is an immutable frozenset of
``ContextEntry`` facts keyed by (key, origin, lamport). Union never drops or
overwrites a fact; ``get`` resolves a key to the *latest* fact (max lamport,
ties broken by origin ordering) which gives deterministic reads on replay.

Every value must be canonically serializable (see repro.wire's normalization
rules — numpy/jax arrays, sets and bytes are handled) so that context digests
are stable across processes — the digest is what the durable journal records
to prove a replayed node saw the same ξ. Serialization is delegated to
``repro.wire``: canonical bytes are backend-stable, so the digest of a
context is the same whichever wire codec the host selected (stdlib json,
msgpack, or the optional fast backend).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Iterable, Iterator, Mapping, Optional, Tuple

from repro.wire import DIGEST_HEX_LEN, canonical_bytes, canonical_digest, from_canonical

__all__ = [
    "ContextEntry",
    "Context",
    "EMPTY_CONTEXT",
    "OBS_KEY_PREFIX",
    "canonical_digest",
]

#: Reserved key namespace for observability facts (trace identity etc.).
#: Facts under this prefix are *transport-only*: they ride the wire context
#: but are excluded from :meth:`Context.digest`, so tracing never perturbs
#: replay identity or cache keys. Injectors must stamp them with lamport 0
#: so ``max_lamport()`` — and hence every later real fact's lamport — is
#: unchanged between traced and untraced runs.
OBS_KEY_PREFIX = "obs."


@dataclass(frozen=True, order=True)
class ContextEntry:
    """A single provenance-tagged fact.

    ``lamport`` orders facts causally: a node writing a fact stamps it with
    1 + max(lamport of every inherited fact). ``origin`` is the id of the node
    (or external source) that produced the fact.

    ``value_json`` is the wire canonical form, computed once at construction —
    entries are immutable, so it doubles as a per-entry serialization cache;
    ``digest`` memoizes the per-entry hash the set digest is built from.
    """

    key: str
    origin: str
    lamport: int
    value_json: bytes  # canonical encoding — hashable, deterministic
    _digest: Optional[str] = field(default=None, compare=False, repr=False)

    @property
    def value(self) -> Any:
        return from_canonical(self.value_json)

    @property
    def digest(self) -> str:
        """Memoized per-entry digest (entries are frozen, so compute once)."""
        if self._digest is None:
            h = hashlib.sha256()
            h.update(self.key.encode())
            h.update(b"\x00")
            h.update(self.origin.encode())
            h.update(b"\x00")
            h.update(str(self.lamport).encode())
            h.update(b"\x00")
            h.update(self.value_json)
            object.__setattr__(self, "_digest", h.hexdigest()[:DIGEST_HEX_LEN])
        return self._digest

    @staticmethod
    def make(key: str, value: Any, origin: str, lamport: int = 0) -> "ContextEntry":
        return ContextEntry(
            key=key, origin=origin, lamport=lamport, value_json=canonical_bytes(value)
        )


class Context:
    """Immutable set of ContextEntry facts with ξ-union semantics."""

    __slots__ = ("_entries", "_digest")

    def __init__(self, entries: Iterable[ContextEntry] = ()):  # noqa: D401
        self._entries: frozenset[ContextEntry] = frozenset(entries)
        self._digest: Optional[str] = None

    # -- construction -----------------------------------------------------
    @staticmethod
    def origin(data: Mapping[str, Any], origin: str = "∅") -> "Context":
        """Origin context ξ(∅): environment supplied before computation starts."""
        return Context(ContextEntry.make(k, v, origin, 0) for k, v in data.items())

    def with_data(self, data: Mapping[str, Any], origin: str) -> "Context":
        """ξ ∪ Ψ(node): fold a node's own data Ψ into the context."""
        lam = self.max_lamport() + 1
        new = [ContextEntry.make(k, v, origin, lam) for k, v in data.items()]
        return Context(self._entries.union(new))

    # -- the paper's union operator ---------------------------------------
    def union(self, *others: "Context") -> "Context":
        entries = self._entries
        for o in others:
            entries = entries.union(o._entries)
        return Context(entries)

    __or__ = union

    @staticmethod
    def union_all(contexts: Iterable["Context"]) -> "Context":
        acc: frozenset[ContextEntry] = frozenset()
        for c in contexts:
            acc = acc.union(c._entries)
        return Context(acc)

    # -- reads -------------------------------------------------------------
    def get(self, key: str, default: Any = None) -> Any:
        """Deterministic resolution: latest lamport wins; ties by origin sort."""
        best: Optional[ContextEntry] = None
        for e in self._entries:
            if e.key != key:
                continue
            if best is None or (e.lamport, e.origin) > (best.lamport, best.origin):
                best = e
        return best.value if best is not None else default

    def get_all(self, key: str) -> Tuple[Any, ...]:
        """All facts for a key, causally ordered (provenance-preserving read)."""
        es = sorted(
            (e for e in self._entries if e.key == key),
            key=lambda e: (e.lamport, e.origin),
        )
        return tuple(e.value for e in es)

    def provenance(self, key: str) -> Tuple[str, ...]:
        es = sorted(
            (e for e in self._entries if e.key == key),
            key=lambda e: (e.lamport, e.origin),
        )
        return tuple(e.origin for e in es)

    def origins(self) -> frozenset:
        return frozenset(e.origin for e in self._entries)

    def keys(self) -> frozenset:
        return frozenset(e.key for e in self._entries)

    def max_lamport(self) -> int:
        return max((e.lamport for e in self._entries), default=0)

    def as_dict(self) -> dict:
        """Resolved view (latest fact per key)."""
        return {k: self.get(k) for k in self.keys()}

    # -- identity ----------------------------------------------------------
    def digest(self) -> str:
        """Stable digest of the full fact set (not just the resolved view).

        Combines the memoized per-entry digests in sorted order, so after a
        union only the 16-hex-char entry digests are hashed — no value is
        re-serialized (the context-union hot path; see benchmarks/wire_bench.py
        and docs/journal-format.md §4 for the exact algorithm). Facts under
        :data:`OBS_KEY_PREFIX` are transport-only metadata and are excluded,
        so replay identity is independent of tracing.
        """
        if self._digest is None:
            h = hashlib.sha256()
            for d in sorted(
                e.digest for e in self._entries if not e.key.startswith(OBS_KEY_PREFIX)
            ):
                h.update(d.encode())
                h.update(b"\n")
            self._digest = h.hexdigest()[:DIGEST_HEX_LEN]
        return self._digest

    # -- dunder ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __iter__(self) -> Iterator[ContextEntry]:
        return iter(sorted(self._entries, key=lambda e: (e.lamport, e.key, e.origin)))

    def __contains__(self, key: str) -> bool:
        return any(e.key == key for e in self._entries)

    def __eq__(self, other: object) -> bool:
        return isinstance(other, Context) and self._entries == other._entries

    def __hash__(self) -> int:
        return hash(self._entries)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Context({len(self._entries)} facts, digest={self.digest()})"

    # -- serialization (for the journal / cross-host transfer) -------------
    def to_wire(self) -> list:
        return [[e.key, e.origin, e.lamport, e.value_json.decode()] for e in self]

    @staticmethod
    def from_wire(wire: Iterable) -> "Context":
        return Context(
            ContextEntry(key=k, origin=o, lamport=int(l), value_json=v.encode())
            for k, o, l, v in wire
        )


EMPTY_CONTEXT = Context()
