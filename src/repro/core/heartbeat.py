"""HeartbeatServer (§3.1): per-node resource monitor on its own process/port.

A successful heartbeat response proves the *system* is up; the application
server answering on its own port proves the *application* is up. The liveness
detector in failure.py combines the two to implement the paper's
system-vs-application error split.

Two transports are provided:
  - ``HeartbeatServer``: real stdlib HTTP server on localhost (paper-faithful,
    separate thread standing in for the separate process; a ``spawn_process``
    flag runs it in a true subprocess for the integration test).
  - in-process polling via ``telemetry()`` for zero-port unit tests.
"""

from __future__ import annotations

import asyncio
import json
import os
import shutil
import threading
import time
import urllib.parse
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional

__all__ = ["telemetry", "HeartbeatServer", "check_heartbeat", "check_heartbeat_async"]

_START = time.monotonic()  # uptime is interval math: immune to clock steps


def _meminfo() -> Dict[str, float]:
    total = avail = 0.0
    try:
        with open("/proc/meminfo") as fh:
            for line in fh:
                if line.startswith("MemTotal:"):
                    total = float(line.split()[1]) * 1024
                elif line.startswith("MemAvailable:"):
                    avail = float(line.split()[1]) * 1024
    except OSError:
        pass
    return {
        "total_bytes": total,
        "available_bytes": avail,
        "used_frac": (1.0 - avail / total) if total else 0.0,
    }


def telemetry(extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The JSON resource report of §3.1: CPU/disk/memory/devices + liveness."""
    try:
        load1, load5, load15 = os.getloadavg()
    except OSError:  # pragma: no cover
        load1 = load5 = load15 = 0.0
    ncpu = os.cpu_count() or 1
    disk = shutil.disk_usage("/")
    report: Dict[str, Any] = {
        "ok": True,
        "time": time.time(),  # record timestamp: wall clock is correct here
        "uptime_s": time.monotonic() - _START,
        "cpu": {
            "load1": load1,
            "load5": load5,
            "load15": load15,
            "ncpu": ncpu,
            "used_frac": min(1.0, load1 / ncpu),
        },
        "memory": _meminfo(),
        "disk": {
            "total_bytes": disk.total,
            "free_bytes": disk.free,
            "used_frac": 1.0 - disk.free / disk.total,
        },
        "devices": _device_report(),
        "pid": os.getpid(),
    }
    if extra:
        report.update(extra)
    return report


def _device_report() -> Dict[str, Any]:
    """Accelerator report; cheap and import-safe if jax is initialized."""
    import sys

    jax = sys.modules.get("jax")
    if jax is None:  # don't force device init just for a heartbeat
        return {"backend": "uninitialized", "count": 0}
    try:
        devs = jax.local_devices()
        return {"backend": devs[0].platform if devs else "none", "count": len(devs)}
    except Exception:  # pragma: no cover
        return {"backend": "error", "count": 0}


class _Handler(BaseHTTPRequestHandler):
    server_version = "SerPyTorHeartbeat/1.0"

    def do_GET(self) -> None:  # noqa: N802
        if self.path.rstrip("/") in ("", "/heartbeat", "/health"):
            body = json.dumps(telemetry(self.server.extra)).encode()  # type: ignore[attr-defined]
            self.send_response(200)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        else:
            self.send_error(404)

    def log_message(self, *args) -> None:  # silence
        pass


class HeartbeatServer:
    """Separate-port heartbeat endpoint (assumption 1 of §3.2)."""

    def __init__(
        self,
        port: int = 0,
        host: str = "127.0.0.1",
        extra: Optional[Dict[str, Any]] = None,
    ):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.extra = extra or {}  # type: ignore[attr-defined]
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "HeartbeatServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name=f"heartbeat:{self.port}", daemon=True
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2)

    @property
    def address(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "HeartbeatServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def check_heartbeat(address: str, timeout: float = 1.0) -> Optional[Dict[str, Any]]:
    """Poll a heartbeat endpoint. None ⇒ system-level failure (§3.2).

    A successful probe is stamped with ``probe_latency_s`` (round-trip time
    as seen by the caller) so the gateway's cached telemetry carries a
    network-health signal alongside the worker's self-report. The RTT is
    measured on the monotonic clock — a wall-clock step mid-probe (NTP
    correction, manual adjustment) must not poison the latency signal.
    """
    t0 = time.monotonic()
    try:
        with urllib.request.urlopen(
            address.rstrip("/") + "/heartbeat", timeout=timeout
        ) as resp:
            report = json.loads(resp.read())
        report["probe_latency_s"] = time.monotonic() - t0
        return report
    except Exception:
        return None


async def check_heartbeat_async(
    address: str, timeout: float = 1.0
) -> Optional[Dict[str, Any]]:
    """Coroutine twin of :func:`check_heartbeat` for the asyncio gateway.

    The async control plane probes every worker *concurrently* (one
    ``gather`` per heartbeat tick instead of a serial walk), so a single
    slow or dead worker no longer stretches the whole probe cycle. Same
    contract: None ⇒ system-level failure, a successful report is stamped
    with a monotonic ``probe_latency_s``.
    """
    t0 = time.monotonic()
    try:
        parts = urllib.parse.urlsplit(address)
        host, port = parts.hostname or "127.0.0.1", parts.port or 80
        reader, writer = await asyncio.wait_for(
            asyncio.open_connection(host, port), timeout=timeout
        )
        try:
            writer.write(
                f"GET /heartbeat HTTP/1.1\r\nHost: {host}\r\n"
                "Connection: close\r\n\r\n".encode()
            )
            await writer.drain()
            raw = await asyncio.wait_for(reader.read(-1), timeout=timeout)
        finally:
            writer.close()
        head, _, body = raw.partition(b"\r\n\r\n")
        if not head.split(None, 2)[1].startswith(b"200"):
            return None
        report = json.loads(body)
        report["probe_latency_s"] = time.monotonic() - t0
        return report
    except Exception:
        return None
