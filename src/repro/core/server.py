"""Server (§3.2): the generic, weakly-opinionated compute worker.

A ``WorkerServer`` owns a registry of atomic tasks (every mapping is a function
that gets all its dependencies through DI) and executes requests either over a
real HTTP transport or in-process. Middleware hooks (auth, validation,
instrumentation) are pluggable, matching the paper's "users can extend it with
security check pipelines, authentication and authorization mechanisms".

The heartbeat endpoint is ALWAYS a separate server on a separate port
(assumption 1 of §3.2), so a crashed application leaves the heartbeat alive —
that asymmetry is what the failure detector reads.

A registry task that returns a *generator* is a streaming task: over HTTP
its chunks cross the wire incrementally as crc-checked frames in a chunked
response body (docs/streaming.md §5); in-process the generator itself is
handed to the caller. Either way the consumer sees chunks as they are
produced, never a materialized batch.

This module is also the *semantic* layer of the asyncio worker transport:
``repro.core.aio.server`` rebuilds only the HTTP plumbing on an event loop
and reuses ``_execute`` (middleware chain, DI, failure taxonomy, state
accounting) and ``_stream_values`` (frame decode + torn-stream detection)
from here — one execution contract, two transports.
"""

from __future__ import annotations

import inspect
import threading
import time
import traceback
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional

from repro.wire import (
    PayloadDecodeError,
    canonical_bytes,
    decode_payload,
    encode_frame,
    encode_payload,
    read_frames,
    unwrap_digested,
)

from repro.obs.trace import extract_trace, get_tracer

from .context import Context
from .durable import Interrupted, payload_digest
from .heartbeat import HeartbeatServer

__all__ = [
    "TaskRegistry",
    "WorkerServer",
    "WorkerClient",
    "InProcWorker",
    "FlakyWorker",
    "Middleware",
    "WorkerStreamError",
    "STREAM_CONTENT_TYPE",
]

Middleware = Callable[[str, Mapping[str, Any]], Optional[str]]
# middleware(task_name, meta) -> None (pass) or str (rejection reason)

STREAM_CONTENT_TYPE = "application/x-serpytor-stream"


class WorkerStreamError(RuntimeError):
    """A worker-side task failure reported mid-stream (via an error frame)."""


class TaskRegistry:
    """name → atomic task. Weakly opinionated: anything callable registers."""

    def __init__(self) -> None:
        self._tasks: Dict[str, Callable[..., Any]] = {}

    def register(self, name: str, fn: Callable[..., Any]) -> None:
        self._tasks[name] = fn

    def task(self, name: str):
        def wrap(fn: Callable[..., Any]) -> Callable[..., Any]:
            self.register(name, fn)
            return fn

        return wrap

    def get(self, name: str) -> Callable[..., Any]:
        if name not in self._tasks:
            raise KeyError(f"unknown task {name!r}")
        return self._tasks[name]

    def names(self) -> List[str]:
        return sorted(self._tasks)


class _WorkerState:
    def __init__(self) -> None:
        self.busy = 0
        self.completed = 0
        self.failed = 0
        self.lock = threading.Lock()


def _execute(
    registry: TaskRegistry,
    middleware: List[Middleware],
    state: _WorkerState,
    task_name: str,
    ctx: Context,
    inputs: Mapping[str, Any],
    fail_injector: Optional[Callable[[str], None]] = None,
) -> Dict[str, Any]:
    # the one worker-side execution contract, shared by every transport
    # (in-proc, threaded HTTP, asyncio) — which is also why the task span
    # is opened here and nowhere transport-specific. Parent identity rides
    # the submitted context as an obs.* fact (see repro.obs.trace).
    tracer = get_tracer()
    if not tracer.enabled:
        return _execute_inner(
            registry, middleware, state, task_name, ctx, inputs, fail_injector
        )
    parent = extract_trace(ctx)
    span = tracer.start_span(
        f"task:{task_name}",
        trace_id=parent[0] if parent else "",
        parent_id=parent[1] if parent else "",
        kind="task",
        attrs={"task": task_name},
    )
    result = _execute_inner(
        registry, middleware, state, task_name, ctx, inputs, fail_injector
    )
    tracer.end(
        span,
        status=str(result.get("status", "error")),
        attrs={"wall_s": result.get("wall_s", 0.0)},
    )
    return result


def _execute_inner(
    registry: TaskRegistry,
    middleware: List[Middleware],
    state: _WorkerState,
    task_name: str,
    ctx: Context,
    inputs: Mapping[str, Any],
    fail_injector: Optional[Callable[[str], None]] = None,
) -> Dict[str, Any]:
    for mw in middleware:
        reason = mw(task_name, {"inputs": sorted(inputs)})
        if reason is not None:
            return {"status": "rejected", "reason": reason}
    with state.lock:
        state.busy += 1
    t0 = time.monotonic()  # wall_s is a duration: clock steps must not skew it
    try:
        if fail_injector is not None:
            fail_injector(task_name)  # test hook: raise to simulate app error
        fn = registry.get(task_name)
        # tensor-bearing tasks may arrive with Digested digest-hint wrappers
        # when invoked directly (the gateway strips them at submit); the
        # registry surface always hands task functions plain payload values
        out = fn(ctx, **unwrap_digested(dict(inputs)))
        if inspect.isgenerator(out):
            # a stream-source task: the body has not run yet — chunks are
            # produced as the caller (transport) iterates, so accounting
            # (completed/failed) is settled by the transport at stream end,
            # not here. The chunk seq numbering starts at the durable-resume
            # offset the caller sent.
            return {
                "status": "stream",
                "stream": out,
                "start": int(dict(inputs).get("start", 0) or 0),
                "wall_s": time.monotonic() - t0,
            }
        with state.lock:
            state.completed += 1
        # normalize results at the worker boundary: an HTTP transport strips
        # Digested wrappers as a side effect of encoding, so the zero-copy
        # in-proc path must strip them too — otherwise the same task output
        # would journal under transport-dependent digests
        return {
            "status": "ok",
            "output": unwrap_digested(out),
            "wall_s": time.monotonic() - t0,
        }
    except Interrupted as exc:
        # a named interrupt point: NOT a failure — the submitter suspends.
        # Unserializable payloads degrade to repr so the status crosses
        # any transport.
        payload = exc.payload
        if payload is not None:
            try:
                payload_digest(payload)  # probes serializability
            except Exception:
                payload = repr(payload)
        return {
            "status": "interrupt",
            "name": exc.name,
            "payload": payload,
            "wall_s": time.monotonic() - t0,
        }
    except Exception as exc:  # application-level failure: report, stay alive
        with state.lock:
            state.failed += 1
        return {
            "status": "error",
            "error": f"{type(exc).__name__}: {exc}",
            "traceback": traceback.format_exc(),
            "wall_s": time.monotonic() - t0,
        }
    finally:
        with state.lock:
            state.busy -= 1


class InProcWorker:
    """Zero-transport worker — the unit-test and single-process fast path.

    ``max_concurrency`` models the worker's real execution capacity: a
    worker standing in for one accelerator host processes one tensor task
    at a time (``max_concurrency=1``), even though the gateway's dispatch
    pool may hand it several requests concurrently. ``None`` (default)
    keeps the historical unlimited-overlap behaviour for pure-Python tasks.
    """

    def __init__(
        self,
        name: str,
        registry: TaskRegistry,
        middleware: Optional[List[Middleware]] = None,
        max_concurrency: Optional[int] = None,
    ):
        self.name = name
        self.registry = registry
        self.middleware = list(middleware or [])
        self.state = _WorkerState()
        self.alive = True  # system liveness (simulated)
        self.app_alive = True  # application liveness (simulated)
        self.latency_s = 0.0  # injected slowness for straggler tests
        self.fail_injector: Optional[Callable[[str], None]] = None
        self._slots = (
            threading.BoundedSemaphore(max_concurrency) if max_concurrency else None
        )

    # same surface as WorkerClient ------------------------------------------
    def heartbeat(self) -> Optional[Dict[str, Any]]:
        if not self.alive:
            return None
        from .heartbeat import telemetry

        with self.state.lock:
            busy = self.state.busy
        return telemetry(
            {"worker": self.name, "busy": busy, "completed": self.state.completed}
        )

    def run_task(
        self, task_name: str, ctx: Context, inputs: Mapping[str, Any]
    ) -> Dict[str, Any]:
        if not self.alive:
            raise ConnectionError(f"worker {self.name} is down (system-level)")
        if not self.app_alive:
            raise TimeoutError(f"worker {self.name} application not responding")
        if self._slots is None:
            return self._run_task_inner(task_name, ctx, inputs)
        with self._slots:  # capacity-bound execution (one accelerator's worth)
            return self._run_task_inner(task_name, ctx, inputs)

    def _run_task_inner(
        self, task_name: str, ctx: Context, inputs: Mapping[str, Any]
    ) -> Dict[str, Any]:
        if self.latency_s:
            time.sleep(self.latency_s)
        result = _execute(
            self.registry, self.middleware, self.state, task_name, ctx, inputs,
            self.fail_injector,
        )
        if result.get("status") == "stream":
            # zero-transport: the generator body runs on the CONSUMER's
            # thread, so settle completed/failed accounting at stream end
            result["stream"] = self._track_stream(result["stream"])
        return result

    def _track_stream(self, gen: Any):
        try:
            yield from gen
        except Exception:
            with self.state.lock:
                self.state.failed += 1
            raise
        else:
            with self.state.lock:
                self.state.completed += 1


class FlakyWorker(InProcWorker):
    """Deterministic fault injection: an in-proc worker you can kill mid-graph.

    The kill switch flips *system* liveness off — exactly the §3.2 failure the
    heartbeat detector exists for: ``heartbeat()`` returns None and every
    ``run_task`` raises ConnectionError. Two death modes:

      - ``"drop"``  (default): in-flight and new calls fail fast with
        ConnectionError — a clean crash the dispatch path detects itself.
      - ``"hang"``: in-flight calls block (until :meth:`release` or
        ``hang_timeout_s``) before failing — a silent partition; only the
        gateway's heartbeat eviction can recover work stuck on this worker.

    ``kill_after_starts=N`` arms the switch so the Nth task *start* triggers
    it: the worker dies mid-flight with work accepted but never finished,
    which is the scenario requeue-on-eviction must survive.
    """

    def __init__(
        self,
        name: str,
        registry: TaskRegistry,
        *,
        kill_after_starts: Optional[int] = None,
        mode: str = "drop",
        hang_timeout_s: float = 30.0,
        **kw,
    ):
        assert mode in ("drop", "hang")
        super().__init__(name, registry, **kw)
        self.kill_after_starts = kill_after_starts
        self.mode = mode
        self.hang_timeout_s = hang_timeout_s
        self.starts = 0
        self._released = threading.Event()

    def kill(self) -> None:
        """Flip the switch: heartbeat goes dark, tasks fail per ``mode``."""
        self.alive = False

    def release(self) -> None:
        """Unblock any calls parked by ``hang`` mode (test teardown hook)."""
        self._released.set()

    def run_task(
        self, task_name: str, ctx: Context, inputs: Mapping[str, Any]
    ) -> Dict[str, Any]:
        with self.state.lock:
            self.starts += 1
            armed = (
                self.kill_after_starts is not None
                and self.starts >= self.kill_after_starts
            )
        if armed:
            self.kill()
        if not self.alive:
            if self.mode == "hang":
                self._released.wait(self.hang_timeout_s)
            raise ConnectionError(f"worker {self.name} died mid-task ({task_name})")
        return super().run_task(task_name, ctx, inputs)


class _AppHandler(BaseHTTPRequestHandler):
    server_version = "SerPyTorWorker/1.0"

    def do_POST(self) -> None:  # noqa: N802
        if self.path.rstrip("/") != "/task":
            self.send_error(404)
            return
        length = int(self.headers.get("Content-Length", "0"))
        body = self.rfile.read(length)
        try:
            req = decode_payload(body)
            ctx = Context.from_wire(req["context"])
            result = _execute(
                self.server.registry,  # type: ignore[attr-defined]
                self.server.middleware,  # type: ignore[attr-defined]
                self.server.state,  # type: ignore[attr-defined]
                req["task"],
                ctx,
                req["inputs"],
            )
        except Exception as exc:  # malformed request
            result = {"status": "error", "error": str(exc)}
        if result.get("status") == "stream":
            self._send_stream(result)
            return
        out = encode_payload(result)
        self.send_response(200)
        self.send_header("Content-Type", "application/x-msgpack-zstd")
        self.send_header("Content-Length", str(len(out)))
        self.end_headers()
        self.wfile.write(out)

    def _send_stream(self, result: Dict[str, Any]) -> None:
        """Incremental chunk transport: one wire frame per produced chunk.

        HTTP/1.1 chunked transfer-encoding carries self-delimiting frames
        (docs/streaming.md §5): ``{"s": seq, "c": chunk}`` per chunk, a
        terminal ``{"eos": n}``, or ``{"err": msg}`` if the task body fails
        mid-stream — the consumer sees a typed failure, never a silent
        truncation (a torn connection is detected by the missing EOS frame).
        """
        self.send_response(200)
        self.send_header("Content-Type", STREAM_CONTENT_TYPE)
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()

        def emit(frame: bytes) -> None:
            self.wfile.write(f"{len(frame):X}\r\n".encode() + frame + b"\r\n")
            self.wfile.flush()

        seq = int(result.get("start", 0) or 0)
        state = self.server.state  # type: ignore[attr-defined]
        with state.lock:
            state.busy += 1  # the task body runs HERE, not in _execute
        try:
            for chunk in result["stream"]:
                emit(encode_frame({"s": seq, "c": chunk}))
                seq += 1
            emit(encode_frame({"eos": seq}))
            with state.lock:
                state.completed += 1
        except Exception as exc:  # mid-stream task failure: typed error frame
            with state.lock:
                state.failed += 1
            try:
                emit(encode_frame({"err": f"{type(exc).__name__}: {exc}"}))
            except Exception:
                pass  # consumer already gone; nothing left to tell it
        finally:
            with state.lock:
                state.busy -= 1
        try:
            self.wfile.write(b"0\r\n\r\n")  # terminate the chunked body
        except Exception:
            pass

    def do_GET(self) -> None:  # noqa: N802
        if self.path.rstrip("/") == "/tasks":
            body = canonical_bytes(self.server.registry.names())  # type: ignore[attr-defined]
            self.send_response(200)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        else:
            self.send_error(404)

    def log_message(self, *args) -> None:
        pass


class WorkerServer:
    """Application server + separate heartbeat server (two ports, §3.2)."""

    def __init__(
        self,
        name: str,
        registry: TaskRegistry,
        host: str = "127.0.0.1",
        port: int = 0,
        middleware: Optional[List[Middleware]] = None,
    ):
        self.name = name
        self.registry = registry
        self.state = _WorkerState()
        self._httpd = ThreadingHTTPServer((host, port), _AppHandler)
        self._httpd.registry = registry  # type: ignore[attr-defined]
        self._httpd.middleware = list(middleware or [])  # type: ignore[attr-defined]
        self._httpd.state = self.state  # type: ignore[attr-defined]
        self.host = host
        self.port = self._httpd.server_address[1]
        self.heartbeat_server = HeartbeatServer(host=host, extra={"worker": name})
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "WorkerServer":
        self.heartbeat_server.start()
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name=f"worker:{self.name}", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, stop_heartbeat: bool = True) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=2)
        if stop_heartbeat:
            self.heartbeat_server.stop()

    def crash_application(self) -> None:
        """Kill ONLY the app server — heartbeat stays up (application-level)."""
        self.stop(stop_heartbeat=False)

    @property
    def address(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "WorkerServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


class WorkerClient:
    """HTTP client with the same surface as InProcWorker."""

    def __init__(
        self, name: str, address: str, heartbeat_address: str, timeout: float = 30.0
    ):
        self.name = name
        self.address = address
        self.heartbeat_address = heartbeat_address
        self.timeout = timeout

    def heartbeat(self) -> Optional[Dict[str, Any]]:
        from .heartbeat import check_heartbeat

        return check_heartbeat(self.heartbeat_address, timeout=min(2.0, self.timeout))

    def run_task(
        self, task_name: str, ctx: Context, inputs: Mapping[str, Any]
    ) -> Dict[str, Any]:
        body = encode_payload(
            {"task": task_name, "context": ctx.to_wire(), "inputs": dict(inputs)}
        )
        req = urllib.request.Request(
            self.address.rstrip("/") + "/task", data=body, method="POST"
        )
        try:
            resp = urllib.request.urlopen(req, timeout=self.timeout)
        except Exception as exc:
            raise TimeoutError(f"worker {self.name} application not responding: {exc}") from exc
        if resp.headers.get("Content-Type", "") == STREAM_CONTENT_TYPE:
            # incremental chunk stream: hand back a live frame iterator —
            # the response stays open and is closed when the stream ends
            return {"status": "stream", "stream": _stream_values(resp, self.name)}
        try:
            raw = resp.read()
        except Exception as exc:
            raise TimeoutError(f"worker {self.name} application not responding: {exc}") from exc
        finally:
            resp.close()
        # a transport that answered but with undecodable bytes is a TYPED
        # failure (PayloadDecodeError) — the gateway retries it elsewhere
        return decode_payload(raw)


def _stream_values(resp: Any, worker_name: str) -> Iterator[Any]:
    """Decode chunk frames off an open HTTP response, yielding chunk values.

    Ends at the EOS frame; a worker-side failure frame raises
    :class:`WorkerStreamError`; a connection that dies between frames
    raises :class:`~repro.wire.PayloadDecodeError` (torn stream) so the
    consumer can resume from its last committed offset.
    """
    try:
        for frame in read_frames(resp):
            if "err" in frame:
                raise WorkerStreamError(
                    f"worker {worker_name} failed mid-stream: {frame['err']}"
                )
            if "eos" in frame:
                return
            yield frame["c"]
        raise PayloadDecodeError(
            f"stream from worker {worker_name} ended without an EOS frame"
        )
    finally:
        resp.close()
