"""ContextGraph: the context-aware computational graph of SerPyTor §4.1.

Nodes are atomic tasks (dependency-injected callables) carrying data Ψ.
Edges are dependencies. Co-dependent nodes (strongly connected components —
the paper's "union nodes" A') are contracted before scheduling so the
executable graph is a DAG, per §4.1.1.

Context propagation follows the paper exactly:
  - the root inherits the origin context ξ(∅) plus its own Ψ,
  - a node with independent origins inherits the union of its parents' ξ,
  - a union node's ξ is the union of the ξ and Ψ of every member.
"""

from __future__ import annotations

import hashlib
import itertools
import os
import warnings
from dataclasses import dataclass, field
from types import CodeType, ModuleType
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.wire import DIGEST_HEX_LEN, canonical_bytes

from .context import Context, EMPTY_CONTEXT

__all__ = [
    "Node",
    "UnionNode",
    "ContextGraph",
    "CycleError",
    "fn_digest",
    "toposort_levels",
]

# Closure cells holding values that are neither callable nor canonically
# serializable get a process-unique marker: such functions simply never hit
# the result cache (a miss, never a stale value from mutated captured state).
_OPAQUE_CELLS = itertools.count()


def _feed_code(h: "hashlib._Hash", code: CodeType, seen: set) -> None:
    """Hash a code object structurally — never via repr, which embeds
    memory addresses for nested code objects (lambdas, comprehensions) and
    would fork the digest on every process."""
    h.update(code.co_code)
    h.update(repr(code.co_names).encode())
    for const in code.co_consts:
        if isinstance(const, CodeType):
            h.update(b"<code>")
            _feed_code(h, const, seen)
        else:
            h.update(repr(const).encode())


def _feed_value(h: "hashlib._Hash", value: Any, seen: set) -> None:
    """Hash a captured value: callables recurse, modules hash by name,
    serializable values hash by content, anything else is opaque (unique
    marker — defeats caching)."""
    if isinstance(value, ModuleType):  # locally-imported modules are common cells
        h.update(b"mod:" + value.__name__.encode())
        return
    if callable(value):
        h.update(b"fn:")
        _feed_fn(h, value, seen)
        return
    try:
        h.update(b"val:" + canonical_bytes(value))
    except TypeError:
        h.update(f"opaque:{next(_OPAQUE_CELLS)}".encode())


def _feed_fn(h: "hashlib._Hash", fn: Any, seen: set) -> None:
    if id(fn) in seen:  # mutually-recursive closures terminate deterministically
        h.update(b"cycle:")
        return
    seen.add(id(fn))
    target = fn
    while hasattr(target, "__wrapped__"):
        target = target.__wrapped__
    seen.add(id(target))
    code = getattr(target, "__code__", None)
    if code is None:
        name = getattr(target, "__qualname__", None) or type(target).__qualname__
        mod = getattr(target, "__module__", None) or type(target).__module__
        h.update(f"obj:{mod}:{name}".encode())
        return
    h.update(b"code:")
    h.update(getattr(target, "__qualname__", "").encode())
    _feed_code(h, code, seen)
    for default in getattr(target, "__defaults__", None) or ():
        h.update(b"default:")
        _feed_value(h, default, seen)
    for cell in getattr(target, "__closure__", None) or ():
        try:
            captured = cell.cell_contents
        except ValueError:  # empty cell (still being defined)
            h.update(b"cell:empty")
            continue
        h.update(b"cell:")
        _feed_value(h, captured, seen)


def fn_digest(fn: "Callable[..., Any] | str | None") -> str:
    """Deterministic identity of a task implementation — the cache key's first leg.

    Registry task names (string ``fn``) digest by name: the deployment owns
    versioning of named tasks (bump the name, or fold a version fact into the
    context, when semantics change). Python callables digest by *code*:
    qualname, bytecode, names, consts (nested code objects hashed
    structurally, so lambdas/comprehensions stay process-stable), defaults,
    and closure cells — captured callables recurse (cycle-safe), captured
    serializable values hash by canonical content, and anything opaque gets
    a unique marker so the function never hits the cache rather than risking
    a stale hit on mutated captured state. Callables without a code object
    (builtins, callable instances) digest by module-qualified name only —
    instance state is NOT captured; see docs/result-cache.md §3.
    """
    h = hashlib.sha256()
    if fn is None:
        h.update(b"none:")
    elif isinstance(fn, str):
        h.update(b"task:" + fn.encode())
    else:
        _feed_fn(h, fn, set())
    return h.hexdigest()[:DIGEST_HEX_LEN]


class CycleError(ValueError):
    """Raised when a cycle survives contraction (contract=False paths)."""


STREAM_KINDS = ("", "source", "map", "reduce")
INTERRUPT_TIMEOUT_POLICIES = ("", "default", "escalate")

_UNSET = object()  # distinguishes "no default given" from an explicit None


@dataclass
class Node:
    """An atomic task.

    ``fn`` receives its inputs purely by injection: ``fn(ctx, **inputs)`` where
    ``inputs`` maps each dependency's node id (or alias) to that node's output.
    ``data`` is Ψ(node): static facts folded into the node's context.

    ``stream`` declares participation in the streaming dataflow subsystem
    (docs/streaming.md): ``"source"`` — ``fn`` is a generator yielding chunks;
    ``"map"`` — ``fn`` runs once per upstream chunk; ``"reduce"`` — ``fn``
    consumes the upstream chunk iterator and returns one value. ``""`` is a
    plain batch node (runs after every dep fully commits).

    ``volatile`` marks a node whose output is large transient data (gradient
    pytrees, synced parameters): its commit records only the output *digest*
    (``payload=None``), it is never replay-skipped (re-execution is the
    recovery path — the value is a pure function of its inputs), and a
    re-execution that disagrees with the journaled digest is a hard
    non-determinism error. Volatile nodes never use the cross-run result
    cache. See docs/training.md §3.

    ``retries`` is the per-node retry budget: ``None`` (default) defers to
    the executor's :class:`~repro.core.failure.RetryPolicy`; an explicit
    integer — including 0 — is exact. Stateful tasks whose inputs are
    consumed by execution (donated device buffers) must set ``retries=0``.

    ``interrupt`` declares a *named interrupt point*: the node's fn may call
    :func:`repro.core.interrupt` with that name to suspend the run until
    ``resume(workflow_id, inputs={name: ...})`` supplies an answer
    (docs/durable-workflows.md). Declaration is advisory for plain
    executors (any node may raise ``Interrupted``) but validated here:
    interrupt names must be unique per graph and are rejected on stream and
    volatile nodes, whose commit protocols cannot suspend mid-unit.

    ``interrupt_timeout_s`` bounds how long a suspension may sit unanswered:
    the deadline is journaled in the ``SUSPEND`` record (absolute wall time,
    so replay is deterministic), and a ``resume()`` arriving after it applies
    the ``interrupt_on_timeout`` policy — ``"default"`` auto-answers with
    ``interrupt_default`` (journaled as an auto-``RESUME``), ``"escalate"``
    refuses to resume and marks the workflow escalated. Explicit inputs
    supplied by the caller always win over the timeout policy.
    """

    id: str
    fn: Optional[Callable[..., Any]] = None
    deps: Tuple[str, ...] = ()
    data: Mapping[str, Any] = field(default_factory=dict)
    aliases: Mapping[str, str] = field(default_factory=dict)  # dep id -> kwarg name
    resources: Mapping[str, float] = field(default_factory=dict)  # scheduling hints
    retries: Optional[int] = None  # None ⇒ executor policy; explicit int is exact
    timeout_s: Optional[float] = None
    stream: str = ""  # "" | "source" | "map" | "reduce"
    volatile: bool = False  # digest-only commits, re-execute-and-verify replay
    interrupt: str = ""  # named interrupt point this node may suspend at
    interrupt_timeout_s: Optional[float] = None  # unanswered-suspension bound
    interrupt_default: Any = None  # auto-answer under the "default" policy
    interrupt_on_timeout: str = ""  # "" | "default" | "escalate"

    def kwarg_for(self, dep_id: str) -> str:
        """Kwarg name a dependency's output is injected under (alias-aware)."""
        return self.aliases.get(dep_id, dep_id)

    def retry_limit(self, default: int = 0) -> int:
        """Effective retry budget: the node's explicit one, else ``default``."""
        return self.retries if self.retries is not None else default

    def fn_digest(self) -> str:
        """Memoized :func:`fn_digest` of this node's callable / task name."""
        d = getattr(self, "_fn_digest", None)
        if d is None:
            d = fn_digest(self.fn)
            self._fn_digest = d
        return d


@dataclass
class UnionNode:
    """A contracted SCC — the paper's A' union node."""

    id: str
    members: Tuple[Node, ...]
    deps: Tuple[str, ...] = ()

    @property
    def data(self) -> Dict[str, Any]:
        """Merged Ψ of all members (deterministic member-id order)."""
        merged: Dict[str, Any] = {}
        for m in sorted(self.members, key=lambda n: n.id):
            merged.update(m.data)
        return merged

    def fn_digest(self) -> str:
        """Combined fn digest: members' (id, fn) pairs in deterministic order."""
        d = getattr(self, "_fn_digest", None)
        if d is None:
            h = hashlib.sha256()
            for m in sorted(self.members, key=lambda n: n.id):
                h.update(m.id.encode())
                h.update(b"\x00")
                h.update(m.fn_digest().encode())
                h.update(b"\n")
            d = h.hexdigest()[:DIGEST_HEX_LEN]
            self._fn_digest = d
        return d


def _tarjan_scc(ids: Sequence[str], deps_of: Mapping[str, Sequence[str]]) -> List[List[str]]:
    """Iterative Tarjan SCC (no recursion limit issues on big graphs)."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Dict[str, bool] = {}
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]

    for root in ids:
        if root in index:
            continue
        work: List[Tuple[str, int]] = [(root, 0)]
        while work:
            v, pi = work.pop()
            if pi == 0:
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack[v] = True
            recurse = False
            children = [d for d in deps_of.get(v, ()) if d in deps_of or d in index]
            for i in range(pi, len(children)):
                w = children[i]
                if w not in index:
                    work.append((v, i + 1))
                    work.append((w, 0))
                    recurse = True
                    break
                elif on_stack.get(w, False):
                    low[v] = min(low[v], index[w])
            if recurse:
                continue
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    scc.append(w)
                    if w == v:
                        break
                sccs.append(sorted(scc))
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])
    return sccs


def toposort_levels(
    ids: Sequence[str], deps_of: Mapping[str, Sequence[str]]
) -> List[List[str]]:
    """Kahn levels: each level's nodes are mutually independent (parallelizable)."""
    indeg = {i: 0 for i in ids}
    children: Dict[str, List[str]] = {i: [] for i in ids}
    for i in ids:
        for d in deps_of.get(i, ()):
            if d in indeg:
                indeg[i] += 1
                children[d].append(i)
    frontier = sorted(i for i, d in indeg.items() if d == 0)
    levels: List[List[str]] = []
    seen = 0
    while frontier:
        levels.append(frontier)
        nxt: List[str] = []
        for i in frontier:
            seen += 1
            for c in children[i]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    nxt.append(c)
        frontier = sorted(nxt)
    if seen != len(list(ids)):
        raise CycleError("graph has a cycle that was not contracted")
    return levels


class ContextGraph:
    """A context-aware computational graph (builds, contracts, schedules)."""

    def __init__(self, origin: Context = EMPTY_CONTEXT, name: str = "graph"):
        self.name = name
        self.origin_context = origin
        self.nodes: Dict[str, Node] = {}

    # -- building ----------------------------------------------------------
    def add(
        self,
        id: str,
        fn: Optional[Callable[..., Any]] = None,
        *,
        deps: Iterable[str] = (),
        data: Optional[Mapping[str, Any]] = None,
        aliases: Optional[Mapping[str, str]] = None,
        resources: Optional[Mapping[str, float]] = None,
        retries: Optional[int] = None,
        timeout_s: Optional[float] = None,
        stream: str = "",
        volatile: bool = False,
        interrupt: str = "",
        interrupt_timeout_s: Optional[float] = None,
        interrupt_default: Any = _UNSET,
        interrupt_on_timeout: str = "",
        check: Optional[str] = None,
    ) -> Node:
        if id in self.nodes:
            raise ValueError(f"duplicate node id {id!r}")
        # registration-time replay-safety lint (docs/static-analysis.md §2):
        # ``check`` overrides the REPRO_LINT env default per node
        check_mode = check if check is not None else os.environ.get("REPRO_LINT", "off")
        if check_mode not in ("off", "warn", "error"):
            raise ValueError(
                f"node {id!r}: check must be 'off', 'warn', or 'error', "
                f"not {check_mode!r}"
            )
        if check_mode != "off" and callable(fn):
            self._lint_task(id, fn, check_mode)
        if stream not in STREAM_KINDS:
            raise ValueError(f"node {id!r}: stream must be one of {STREAM_KINDS}")
        if volatile and stream:
            raise ValueError(f"node {id!r}: stream stages commit at chunk "
                             "granularity and cannot be volatile")
        if interrupt and (stream or volatile):
            raise ValueError(
                f"node {id!r}: interrupt points are only valid on plain batch "
                "nodes — stream and volatile commit protocols cannot suspend"
            )
        if interrupt_on_timeout not in INTERRUPT_TIMEOUT_POLICIES:
            raise ValueError(
                f"node {id!r}: interrupt_on_timeout must be one of "
                f"{INTERRUPT_TIMEOUT_POLICIES}"
            )
        has_timeout_cfg = (
            interrupt_timeout_s is not None
            or interrupt_default is not _UNSET
            or bool(interrupt_on_timeout)
        )
        if has_timeout_cfg and not interrupt:
            raise ValueError(
                f"node {id!r}: interrupt timeout settings require an "
                "interrupt point"
            )
        if interrupt_on_timeout and interrupt_timeout_s is None:
            raise ValueError(
                f"node {id!r}: interrupt_on_timeout needs interrupt_timeout_s"
            )
        if interrupt_on_timeout == "default" and interrupt_default is _UNSET:
            raise ValueError(
                f"node {id!r}: the 'default' timeout policy needs an "
                "explicit interrupt_default answer"
            )
        if interrupt_timeout_s is not None and not interrupt_on_timeout:
            # policy inference: a declared default answer means auto-answer;
            # a bare timeout means somebody must be told — escalate
            interrupt_on_timeout = (
                "default" if interrupt_default is not _UNSET else "escalate"
            )
        node = Node(
            id=id,
            fn=fn,
            deps=tuple(deps),
            data=dict(data or {}),
            aliases=dict(aliases or {}),
            resources=dict(resources or {}),
            retries=retries,
            timeout_s=timeout_s,
            stream=stream,
            volatile=volatile,
            interrupt=interrupt,
            interrupt_timeout_s=interrupt_timeout_s,
            interrupt_default=(
                None if interrupt_default is _UNSET else interrupt_default
            ),
            interrupt_on_timeout=interrupt_on_timeout,
        )
        self.nodes[id] = node
        return node

    def _lint_task(self, id: str, fn: Callable[..., Any], mode: str) -> None:
        """Run the replay-safety checker on ``fn`` at registration time.

        ``mode="warn"`` emits one :class:`~repro.analysis.ReplayUnsafeWarning`
        per finding; ``mode="error"`` raises
        :class:`~repro.analysis.ReplayUnsafeError` carrying the findings.
        Lazy import: the analysis package is pure stdlib but optional at
        runtime — graph construction must not require it unless asked to.
        """
        from repro.analysis import ReplayUnsafeError, ReplayUnsafeWarning, check_callable

        findings = check_callable(fn, name=f"{id}:{getattr(fn, '__name__', 'fn')}")
        if not findings:
            return
        summary = "; ".join(f.render() for f in findings)
        if mode == "error":
            raise ReplayUnsafeError(
                f"node {id!r}: task function failed the replay-safety check "
                f"({len(findings)} finding(s)): {summary}",
                findings,
            )
        warnings.warn(
            f"node {id!r}: replay-safety finding(s): {summary}",
            ReplayUnsafeWarning,
            stacklevel=3,
        )

    def add_stream(self, id: str, fn: Optional[Callable[..., Any]] = None, **kw) -> Node:
        """Declare a stream *producer*: ``fn(ctx, *, start=0, **inputs)`` is a
        generator yielding chunks, beginning at chunk index ``start`` (the
        durable-resume offset — see docs/streaming.md §4)."""
        return self.add(id, fn, stream="source", **kw)

    def task(self, id: str, *, deps: Iterable[str] = (), **kw):
        """Decorator form: ``@graph.task("loss", deps=["fwd"])``."""

        def wrap(fn: Callable[..., Any]) -> Callable[..., Any]:
            self.add(id, fn, deps=deps, **kw)
            return fn

        return wrap

    def stream_dep_of(self, node: Node) -> Optional[str]:
        """The single stream-stage dependency of a map/reduce node, if any."""
        stream_deps = [d for d in node.deps if self.nodes[d].stream in ("source", "map")]
        if node.stream in ("map", "reduce"):
            if len(stream_deps) != 1:
                raise ValueError(
                    f"stream {node.stream} node {node.id!r} needs exactly one "
                    f"stream-stage dependency, has {len(stream_deps)}"
                )
            return stream_deps[0]
        return None

    def validate(self) -> None:
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise KeyError(f"node {n.id!r} depends on unknown node {d!r}")
            self.stream_dep_of(n)  # raises on malformed stream topology
        self._check_stream_wait_cycles()
        self.interrupt_points()  # raises on duplicate interrupt names

    def interrupt_points(self) -> Dict[str, str]:
        """Declared interrupt points: ``{interrupt name: node id}``.

        Names must be unique — ``resume(inputs={name: ...})`` addresses an
        interrupt by name alone, so two nodes sharing one would make the
        injection ambiguous.
        """
        points: Dict[str, str] = {}
        for n in self.nodes.values():
            if not n.interrupt:
                continue
            other = points.get(n.interrupt)
            if other is not None:
                raise ValueError(
                    f"duplicate interrupt point {n.interrupt!r}: declared by "
                    f"both {other!r} and {n.id!r}"
                )
            points[n.interrupt] = n.id
        return points

    def _check_stream_wait_cycles(self) -> None:
        """Reject topologies that would deadlock at runtime.

        A stream consumer's *batch* dependency must not (transitively)
        depend on any stage of the consumer's own upstream pipeline: the
        stage would block on backpressure into the consumer's channel, the
        consumer cannot launch until the batch dep commits, and the batch
        dep waits for the stage's EOS — a wait cycle the DAG check cannot
        see (it only appears once the stream exceeds channel capacity).
        """
        for n in self.nodes.values():
            if n.stream not in ("map", "reduce"):
                continue
            # the consumer's upstream stage chain (map* back to the source)
            chain = set()
            cur = self.stream_dep_of(n)
            while cur is not None:
                chain.add(cur)
                cur_node = self.nodes[cur]
                cur = self.stream_dep_of(cur_node) if cur_node.stream == "map" else None
            direct = self.stream_dep_of(n)
            for dep in n.deps:
                if dep == direct:
                    continue
                # DFS: does this batch dep transitively reach the chain?
                stack, seen = [dep], set()
                while stack:
                    d = stack.pop()
                    if d in seen:
                        continue
                    seen.add(d)
                    if d in chain:
                        raise ValueError(
                            f"batch dependency {dep!r} of stream {n.stream} node "
                            f"{n.id!r} depends on its own pipeline stage {d!r}; "
                            "this deadlocks once the stream exceeds channel "
                            "capacity — make it a stream stage or move it out "
                            "of the pipeline"
                        )
                    stack.extend(self.nodes[d].deps)

    # -- contraction (§4.1 union nodes) -------------------------------------
    def contract(self) -> Tuple[Dict[str, "UnionNode | Node"], Dict[str, str]]:
        """Contract SCCs into union nodes.

        Returns (exec_nodes, member_to_group): exec_nodes is a DAG keyed by
        group id; member_to_group maps original ids to their group id.
        """
        self.validate()
        deps_of = {i: n.deps for i, n in self.nodes.items()}
        sccs = _tarjan_scc(sorted(self.nodes), deps_of)
        member_to_group: Dict[str, str] = {}
        exec_nodes: Dict[str, UnionNode | Node] = {}
        for scc in sccs:
            if len(scc) == 1 and scc[0] not in self.nodes[scc[0]].deps:
                member_to_group[scc[0]] = scc[0]
            else:
                gid = "∪(" + "+".join(scc) + ")"
                for m in scc:
                    if self.nodes[m].stream:
                        raise CycleError(
                            f"stream node {m!r} is part of a cycle {scc}; "
                            "stream stages must be acyclic"
                        )
                    member_to_group[m] = gid
        for scc in sccs:
            gid = member_to_group[scc[0]]
            ext = sorted(
                {
                    member_to_group[d]
                    for m in scc
                    for d in self.nodes[m].deps
                    if member_to_group[d] != gid
                }
            )
            if gid == scc[0] and len(scc) == 1:
                # keep the ORIGINAL node (original deps are needed for
                # dependency injection of specific union-node members)
                exec_nodes[gid] = self.nodes[scc[0]]
            else:
                exec_nodes[gid] = UnionNode(
                    id=gid, members=tuple(self.nodes[m] for m in scc), deps=tuple(ext)
                )
        return exec_nodes, member_to_group

    @staticmethod
    def group_deps(
        exec_nodes: Mapping[str, "UnionNode | Node"],
        member_to_group: Mapping[str, str],
    ) -> Dict[str, Tuple[str, ...]]:
        """Scheduling-level deps: original deps mapped through contraction."""
        out: Dict[str, Tuple[str, ...]] = {}
        for gid, node in exec_nodes.items():
            if isinstance(node, UnionNode):
                out[gid] = node.deps  # already external group ids
            else:
                out[gid] = tuple(
                    sorted(
                        {
                            member_to_group.get(d, d)
                            for d in node.deps
                            if member_to_group.get(d, d) != gid
                        }
                    )
                )
        return out

    # -- context propagation -------------------------------------------------
    def propagate_contexts(
        self,
        exec_nodes: Optional[Mapping[str, "UnionNode | Node"]] = None,
    ) -> Dict[str, Context]:
        """Compute ξ for every exec node per the §4.1 rules (no execution)."""
        if exec_nodes is None:
            exec_nodes, member_to_group = self.contract()
        else:
            _, member_to_group = self.contract()
        deps_of = self.group_deps(exec_nodes, member_to_group)
        levels = toposort_levels(sorted(exec_nodes), deps_of)
        xi: Dict[str, Context] = {}
        for level in levels:
            for nid in level:
                node = exec_nodes[nid]
                parents = [xi[d] for d in deps_of[nid]]
                if parents:
                    inherited = Context.union_all(parents)
                else:
                    inherited = self.origin_context  # ξ(∅)
                if isinstance(node, UnionNode):
                    # ξ(A') = ⋃ ξ(member-parents) ∪ ⋃ Ψ(member)
                    ctx = inherited
                    for m in sorted(node.members, key=lambda n: n.id):
                        ctx = ctx.with_data(m.data, origin=m.id) if m.data else ctx
                else:
                    ctx = (
                        inherited.with_data(node.data, origin=node.id)
                        if node.data
                        else inherited
                    )
                xi[nid] = ctx
        return xi

    def schedule(self) -> Tuple[List[List[str]], Dict[str, "UnionNode | Node"], Dict[str, str]]:
        """(levels, exec_nodes, member_to_group) — ready for an executor."""
        exec_nodes, member_to_group = self.contract()
        deps_of = self.group_deps(exec_nodes, member_to_group)
        levels = toposort_levels(sorted(exec_nodes), deps_of)
        return levels, exec_nodes, member_to_group

    def __len__(self) -> int:
        return len(self.nodes)
