"""ContextGraph: the context-aware computational graph of SerPyTor §4.1.

Nodes are atomic tasks (dependency-injected callables) carrying data Ψ.
Edges are dependencies. Co-dependent nodes (strongly connected components —
the paper's "union nodes" A') are contracted before scheduling so the
executable graph is a DAG, per §4.1.1.

Context propagation follows the paper exactly:
  - the root inherits the origin context ξ(∅) plus its own Ψ,
  - a node with independent origins inherits the union of its parents' ξ,
  - a union node's ξ is the union of the ξ and Ψ of every member.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from .context import Context, EMPTY_CONTEXT

__all__ = ["Node", "UnionNode", "ContextGraph", "CycleError", "toposort_levels"]


class CycleError(ValueError):
    """Raised when a cycle survives contraction (contract=False paths)."""


@dataclass
class Node:
    """An atomic task.

    ``fn`` receives its inputs purely by injection: ``fn(ctx, **inputs)`` where
    ``inputs`` maps each dependency's node id (or alias) to that node's output.
    ``data`` is Ψ(node): static facts folded into the node's context.
    """

    id: str
    fn: Optional[Callable[..., Any]] = None
    deps: Tuple[str, ...] = ()
    data: Mapping[str, Any] = field(default_factory=dict)
    aliases: Mapping[str, str] = field(default_factory=dict)  # dep id -> kwarg name
    resources: Mapping[str, float] = field(default_factory=dict)  # scheduling hints
    retries: int = 0
    timeout_s: Optional[float] = None

    def kwarg_for(self, dep_id: str) -> str:
        return self.aliases.get(dep_id, dep_id)


@dataclass
class UnionNode:
    """A contracted SCC — the paper's A' union node."""

    id: str
    members: Tuple[Node, ...]
    deps: Tuple[str, ...] = ()

    @property
    def data(self) -> Dict[str, Any]:
        merged: Dict[str, Any] = {}
        for m in sorted(self.members, key=lambda n: n.id):
            merged.update(m.data)
        return merged


def _tarjan_scc(ids: Sequence[str], deps_of: Mapping[str, Sequence[str]]) -> List[List[str]]:
    """Iterative Tarjan SCC (no recursion limit issues on big graphs)."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Dict[str, bool] = {}
    stack: List[str] = []
    sccs: List[List[str]] = []
    counter = [0]

    for root in ids:
        if root in index:
            continue
        work: List[Tuple[str, int]] = [(root, 0)]
        while work:
            v, pi = work.pop()
            if pi == 0:
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack[v] = True
            recurse = False
            children = [d for d in deps_of.get(v, ()) if d in deps_of or d in index]
            for i in range(pi, len(children)):
                w = children[i]
                if w not in index:
                    work.append((v, i + 1))
                    work.append((w, 0))
                    recurse = True
                    break
                elif on_stack.get(w, False):
                    low[v] = min(low[v], index[w])
            if recurse:
                continue
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    scc.append(w)
                    if w == v:
                        break
                sccs.append(sorted(scc))
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])
    return sccs


def toposort_levels(ids: Sequence[str], deps_of: Mapping[str, Sequence[str]]) -> List[List[str]]:
    """Kahn levels: each level's nodes are mutually independent (parallelizable)."""
    indeg = {i: 0 for i in ids}
    children: Dict[str, List[str]] = {i: [] for i in ids}
    for i in ids:
        for d in deps_of.get(i, ()):
            if d in indeg:
                indeg[i] += 1
                children[d].append(i)
    frontier = sorted(i for i, d in indeg.items() if d == 0)
    levels: List[List[str]] = []
    seen = 0
    while frontier:
        levels.append(frontier)
        nxt: List[str] = []
        for i in frontier:
            seen += 1
            for c in children[i]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    nxt.append(c)
        frontier = sorted(nxt)
    if seen != len(list(ids)):
        raise CycleError("graph has a cycle that was not contracted")
    return levels


class ContextGraph:
    """A context-aware computational graph (builds, contracts, schedules)."""

    def __init__(self, origin: Context = EMPTY_CONTEXT, name: str = "graph"):
        self.name = name
        self.origin_context = origin
        self.nodes: Dict[str, Node] = {}

    # -- building ----------------------------------------------------------
    def add(self, id: str, fn: Optional[Callable[..., Any]] = None, *,
            deps: Iterable[str] = (), data: Optional[Mapping[str, Any]] = None,
            aliases: Optional[Mapping[str, str]] = None,
            resources: Optional[Mapping[str, float]] = None,
            retries: int = 0, timeout_s: Optional[float] = None) -> Node:
        if id in self.nodes:
            raise ValueError(f"duplicate node id {id!r}")
        node = Node(id=id, fn=fn, deps=tuple(deps), data=dict(data or {}),
                    aliases=dict(aliases or {}), resources=dict(resources or {}),
                    retries=retries, timeout_s=timeout_s)
        self.nodes[id] = node
        return node

    def task(self, id: str, *, deps: Iterable[str] = (), **kw):
        """Decorator form: ``@graph.task("loss", deps=["fwd"])``."""

        def wrap(fn: Callable[..., Any]) -> Callable[..., Any]:
            self.add(id, fn, deps=deps, **kw)
            return fn

        return wrap

    def validate(self) -> None:
        for n in self.nodes.values():
            for d in n.deps:
                if d not in self.nodes:
                    raise KeyError(f"node {n.id!r} depends on unknown node {d!r}")

    # -- contraction (§4.1 union nodes) -------------------------------------
    def contract(self) -> Tuple[Dict[str, "UnionNode | Node"], Dict[str, str]]:
        """Contract SCCs into union nodes.

        Returns (exec_nodes, member_to_group): exec_nodes is a DAG keyed by
        group id; member_to_group maps original ids to their group id.
        """
        self.validate()
        deps_of = {i: n.deps for i, n in self.nodes.items()}
        sccs = _tarjan_scc(sorted(self.nodes), deps_of)
        member_to_group: Dict[str, str] = {}
        exec_nodes: Dict[str, UnionNode | Node] = {}
        for scc in sccs:
            if len(scc) == 1 and scc[0] not in self.nodes[scc[0]].deps:
                member_to_group[scc[0]] = scc[0]
            else:
                gid = "∪(" + "+".join(scc) + ")"
                for m in scc:
                    member_to_group[m] = gid
        for scc in sccs:
            gid = member_to_group[scc[0]]
            ext = sorted({member_to_group[d] for m in scc for d in self.nodes[m].deps
                          if member_to_group[d] != gid})
            if gid == scc[0] and len(scc) == 1:
                # keep the ORIGINAL node (original deps are needed for
                # dependency injection of specific union-node members)
                exec_nodes[gid] = self.nodes[scc[0]]
            else:
                exec_nodes[gid] = UnionNode(
                    id=gid, members=tuple(self.nodes[m] for m in scc), deps=tuple(ext))
        return exec_nodes, member_to_group

    @staticmethod
    def group_deps(exec_nodes: Mapping[str, "UnionNode | Node"],
                   member_to_group: Mapping[str, str]) -> Dict[str, Tuple[str, ...]]:
        """Scheduling-level deps: original deps mapped through contraction."""
        out: Dict[str, Tuple[str, ...]] = {}
        for gid, node in exec_nodes.items():
            if isinstance(node, UnionNode):
                out[gid] = node.deps  # already external group ids
            else:
                out[gid] = tuple(sorted({member_to_group.get(d, d) for d in node.deps
                                         if member_to_group.get(d, d) != gid}))
        return out

    # -- context propagation -------------------------------------------------
    def propagate_contexts(
        self,
        exec_nodes: Optional[Mapping[str, "UnionNode | Node"]] = None,
    ) -> Dict[str, Context]:
        """Compute ξ for every exec node per the §4.1 rules (no execution)."""
        if exec_nodes is None:
            exec_nodes, member_to_group = self.contract()
        else:
            _, member_to_group = self.contract()
        deps_of = self.group_deps(exec_nodes, member_to_group)
        levels = toposort_levels(sorted(exec_nodes), deps_of)
        xi: Dict[str, Context] = {}
        for level in levels:
            for nid in level:
                node = exec_nodes[nid]
                parents = [xi[d] for d in deps_of[nid]]
                if parents:
                    inherited = Context.union_all(parents)
                else:
                    inherited = self.origin_context  # ξ(∅)
                if isinstance(node, UnionNode):
                    # ξ(A') = ⋃ ξ(member-parents) ∪ ⋃ Ψ(member)
                    ctx = inherited
                    for m in sorted(node.members, key=lambda n: n.id):
                        ctx = ctx.with_data(m.data, origin=m.id) if m.data else ctx
                else:
                    ctx = inherited.with_data(node.data, origin=node.id) if node.data \
                        else inherited
                xi[nid] = ctx
        return xi

    def schedule(self) -> Tuple[List[List[str]], Dict[str, "UnionNode | Node"], Dict[str, str]]:
        """(levels, exec_nodes, member_to_group) — ready for an executor."""
        exec_nodes, member_to_group = self.contract()
        deps_of = self.group_deps(exec_nodes, member_to_group)
        levels = toposort_levels(sorted(exec_nodes), deps_of)
        return levels, exec_nodes, member_to_group

    def __len__(self) -> int:
        return len(self.nodes)
