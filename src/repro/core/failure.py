"""Failure detection & straggler mitigation.

Implements the paper's §3.2 error taxonomy as an executable detector:

  - heartbeat dead                        → SYSTEM-level failure
  - heartbeat alive, app dead / timeout   → APPLICATION-level failure
  - both alive, latency ≫ fleet median    → STRAGGLER (speculative re-exec)

plus the retry policies used by the executor. Speculative re-execution is
safe because tasks are atomic + deterministic (durable-execution contract):
the first commit wins in the journal; duplicates are idempotent no-ops.
"""

from __future__ import annotations

import statistics
import threading
import time
from dataclasses import dataclass
from enum import Enum
from typing import Any, Callable, Dict, List, Optional

__all__ = ["FailureKind", "Verdict", "LivenessDetector", "RetryPolicy", "StragglerWatch"]


class FailureKind(Enum):
    HEALTHY = "healthy"
    SYSTEM = "system"  # heartbeat down ⇒ node/hardware failure
    APPLICATION = "application"  # heartbeat up, app down ⇒ software failure
    STRAGGLER = "straggler"  # alive but anomalously slow


@dataclass
class Verdict:
    kind: FailureKind
    worker: str
    detail: str = ""


class LivenessDetector:
    """Combines heartbeat + application probes into the paper's taxonomy."""

    def __init__(
        self,
        heartbeat_probe: Callable[[str], Optional[dict]],
        app_probe: Callable[[str], bool],
        suspect_after_s: float = 2.0,
    ):
        self._hb = heartbeat_probe
        self._app = app_probe
        self.suspect_after_s = suspect_after_s
        self._last_ok: Dict[str, float] = {}

    def check(self, worker: str) -> Verdict:
        hb = self._hb(worker)
        now = time.monotonic()
        if hb is None:
            # allow a grace window before declaring system death
            last = self._last_ok.get(worker, 0.0)
            if now - last > self.suspect_after_s:
                return Verdict(
                    FailureKind.SYSTEM, worker, "heartbeat unreachable past grace window"
                )
            return Verdict(FailureKind.HEALTHY, worker, "heartbeat missed (grace)")
        self._last_ok[worker] = now
        if not self._app(worker):
            return Verdict(
                FailureKind.APPLICATION, worker, "heartbeat OK but application not responding"
            )
        return Verdict(FailureKind.HEALTHY, worker)


@dataclass
class RetryPolicy:
    max_attempts: int = 3
    base_delay_s: float = 0.05
    multiplier: float = 2.0
    max_delay_s: float = 5.0
    retry_on: tuple = (FailureKind.SYSTEM, FailureKind.APPLICATION, FailureKind.STRAGGLER)

    def delay(self, attempt: int) -> float:
        return min(self.max_delay_s, self.base_delay_s * self.multiplier**attempt)

    def should_retry(self, kind: FailureKind, attempt: int) -> bool:
        return attempt < self.max_attempts and kind in self.retry_on


class StragglerWatch:
    """Detects stragglers from completed-task latency statistics.

    A running task becomes a straggler candidate when its elapsed time exceeds
    ``threshold × median(completed latencies of the same task name)`` with at
    least ``min_samples`` completions observed. The trainer uses this to issue
    a speculative duplicate to another worker (first journal commit wins).
    """

    def __init__(self, threshold: float = 2.0, min_samples: int = 3):
        self.threshold = threshold
        self.min_samples = min_samples
        self._done: Dict[str, List[float]] = {}
        self._running: Dict[tuple, float] = {}
        self._lock = threading.Lock()

    def started(self, task_name: str, token: Any) -> None:
        with self._lock:
            self._running[(task_name, token)] = time.monotonic()

    def finished(self, task_name: str, token: Any) -> None:
        with self._lock:
            t0 = self._running.pop((task_name, token), None)
            if t0 is not None:
                self._done.setdefault(task_name, []).append(time.monotonic() - t0)
                # bound memory: keep the trailing window
                if len(self._done[task_name]) > 256:
                    self._done[task_name] = self._done[task_name][-128:]

    def median(self, task_name: str) -> Optional[float]:
        with self._lock:
            xs = self._done.get(task_name, [])
            return statistics.median(xs) if len(xs) >= self.min_samples else None

    def should_speculate(
        self, task_name: str, token: Any, copies: int, max_copies: int = 3
    ) -> bool:
        """True when (task_name, token) is a straggler and a copy is allowed.

        The global-speculation decision used by the dataflow executor: the
        running attempt has been out longer than ``threshold × median`` of
        completed same-name tasks, and fewer than ``max_copies`` attempts
        (original + duplicates) exist.
        """
        if copies >= max_copies:
            return False
        with self._lock:
            xs = self._done.get(task_name, [])
            if len(xs) < self.min_samples:
                return False
            t0 = self._running.get((task_name, token))
            if t0 is None:
                return False
            return time.monotonic() - t0 > self.threshold * statistics.median(xs)

    def stragglers(self) -> List[tuple]:
        """[(task_name, token, elapsed, median), ...] currently suspect."""
        now = time.monotonic()
        out = []
        with self._lock:
            for (name, token), t0 in self._running.items():
                xs = self._done.get(name, [])
                if len(xs) < self.min_samples:
                    continue
                med = statistics.median(xs)
                if now - t0 > self.threshold * med:
                    out.append((name, token, now - t0, med))
        return out
