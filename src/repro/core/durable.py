"""Durable execution (§4.2): write-ahead journal, deterministic replay, DI.

A run of a ContextGraph is journaled as an append-only event log (the same
event-sourcing shape Temporal uses). Each committed node records:

    (node_id, context_digest, input_digest, output_digest, payload-or-ref)

Replaying a run re-executes the graph but *skips* any node whose
(context_digest, input_digest) matches a committed entry, re-injecting the
recorded output — effectively-once semantics on top of at-least-once retries.
Large payloads (model/optimizer state) are stored by reference: the journal
holds a ``ref`` string resolved by the checkpoint store, never raw tensors.

The journal format is length-prefixed msgpack records with a crc32 per record
and tagged-compression payload bodies (zstd when available, zlib fallback) —
see docs/journal-format.md for the full spec. Torn tails (a crash mid-append)
are detected and truncated on open — an explicit durability requirement.

Stream nodes commit at *chunk* granularity (``CHUNK_COMMIT`` /
``STREAM_EOS``, docs/streaming.md §4); the ``ReplayCache`` indexes those
records too, so a killed stream resumes from its last committed offset.

The payload codec lives in ``repro.wire.payload``; ``encode_payload``,
``decode_payload`` and ``payload_digest`` are re-exported here for
compatibility with seed-era call sites.
"""

from __future__ import annotations

import binascii
import os
import struct
import threading
import time
import warnings
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Mapping, Optional, Tuple

from repro.wire import decode_payload, encode_payload, payload_digest

from .context import Context

__all__ = [
    "Journal",
    "JournalRecord",
    "ReplayCache",
    "Interrupted",
    "interrupt",
    "KNOWN_KINDS",
    "REPLAY_IGNORED_KINDS",
    "SNAPSHOT_VERSION",
    "encode_payload",
    "decode_payload",
    "payload_digest",
    "atomic_task",
]

_HEADER = struct.Struct("<II")  # (length, crc32)

#: Layout version of the SNAPSHOT record this reader understands
#: (docs/journal-format.md §2.6). A SNAPSHOT stamped with a HIGHER version
#: was folded by a newer writer whose state layout this reader cannot
#: interpret; ``records()`` skips it with a RuntimeWarning instead of
#: mis-applying a half-understood state bundle.
SNAPSHOT_VERSION = 1

#: Every record kind this reader version interprets. Kinds outside this set
#: are *tolerated* (docs/journal-format.md §5): ``records()`` yields them
#: untouched and interpreting readers (ReplayCache, executors) ignore them,
#: so a journal written by a newer writer stays readable.
KNOWN_KINDS = frozenset(
    {
        "RUN_START",
        "NODE_START",
        "NODE_COMMIT",
        "NODE_REQUEUE",
        "CHUNK_COMMIT",
        "STREAM_EOS",
        "CACHE_HIT",
        "CACHE_STORE",
        "NODE_FAIL",
        "RUN_END",
        "CKPT",
        "SUSPEND",
        "RESUME",
        "FORK",
        "LINEAGE",
        "GW_HANDOFF",
        "SNAPSHOT",
    }
)

#: Kinds :class:`ReplayCache` deliberately does NOT index: they carry run
#: activity or annotations, never replayable output state. Kept in sync
#: with the scan in ``ReplayCache.__init__`` — ``python -m repro lint``
#: (INV101) diffs ``handled ∪ ignored`` against ``KNOWN_KINDS``, so adding
#: a kind without classifying it here or handling it there fails the gate.
REPLAY_IGNORED_KINDS = frozenset(
    {
        "RUN_START",
        "RUN_END",
        "NODE_START",
        "NODE_FAIL",
        "NODE_REQUEUE",
        "CACHE_HIT",
        "CACHE_STORE",
        "CKPT",
        "SUSPEND",
        "RESUME",
        "FORK",
        "LINEAGE",
        "GW_HANDOFF",
        "SNAPSHOT",
    }
)


class Interrupted(Exception):
    """A task reached a named interrupt point without an answer in its ξ.

    Raised by :func:`interrupt`; executors treat it as a *suspension
    request*, not a failure: in-flight work drains to commit, the pending
    frontier is journaled as a ``SUSPEND`` record, and the run returns a
    report with ``suspended=True`` (docs/durable-workflows.md §2).
    """

    def __init__(self, name: str, payload: Any = None):
        super().__init__(name)
        self.name = name
        self.payload = payload


_MISSING = object()


def interrupt(ctx: Context, name: str, payload: Any = None) -> Any:
    """Named interrupt point — call from inside a task function.

    If the context carries a fact under ``name`` (injected by
    ``resume(workflow_id, inputs={name: ...})``), its value is returned and
    the task proceeds. Otherwise the run suspends by raising
    :class:`Interrupted`; ``payload`` rides along in the ``SUSPEND`` record
    for the operator who will answer it.
    """
    value = ctx.get(name, _MISSING)
    if value is _MISSING:
        raise Interrupted(name, payload)
    return value


# --------------------------------------------------------------------------
# journal
# --------------------------------------------------------------------------


@dataclass
class JournalRecord:
    """One journal event — see docs/journal-format.md §2 for the field contract."""

    kind: str  # RUN_START | NODE_START | NODE_COMMIT | NODE_REQUEUE
    #          # | CHUNK_COMMIT | STREAM_EOS (chunk-granular streams)
    #          # | CACHE_HIT | CACHE_STORE | NODE_FAIL | RUN_END | CKPT
    #          # | SUSPEND | RESUME | FORK | LINEAGE (durable workflows)
    node_id: str = ""
    context_digest: str = ""
    input_digest: str = ""
    output_digest: str = ""
    payload: Any = None  # inline output (small) — mutually exclusive with ref
    ref: str = ""  # checkpoint-store reference for large outputs
    wall_time: float = 0.0
    attempt: int = 0
    meta: Dict[str, Any] = field(default_factory=dict)

    def to_obj(self) -> dict:
        return {
            "k": self.kind,
            "n": self.node_id,
            "c": self.context_digest,
            "i": self.input_digest,
            "o": self.output_digest,
            "p": self.payload,
            "r": self.ref,
            "t": self.wall_time,
            "a": self.attempt,
            "m": self.meta,
        }

    @staticmethod
    def from_obj(o: Mapping) -> "JournalRecord":
        """Decode one record object — forward-compatibly.

        Missing fields default (a future writer may drop one) and unknown
        keys are ignored (a future writer may add one), so a pre-upgrade
        reader never raises on records written by a newer version — the
        forward-compat contract of docs/journal-format.md §5.
        """
        return JournalRecord(
            kind=str(o.get("k", "")),
            node_id=o.get("n", ""),
            context_digest=o.get("c", ""),
            input_digest=o.get("i", ""),
            output_digest=o.get("o", ""),
            payload=o.get("p"),
            ref=o.get("r", ""),
            wall_time=o.get("t", 0.0),
            attempt=o.get("a", 0),
            meta=dict(o.get("m") or {}),
        )


class Journal:
    """Append-only, crash-safe event log. Thread-safe appends.

    ``sync`` policy: "always" fsyncs per commit (paper-faithful durable mode),
    "batch" fsyncs on flush()/close() (the beyond-paper async mode measured in
    benchmarks), "never" for in-memory tests.
    """

    def __init__(
        self,
        path: str,
        sync: str = "always",
        lineage: Optional[Mapping[str, Any]] = None,
    ):
        assert sync in ("always", "batch", "never")
        self.path = path
        self.sync = sync
        self._lock = threading.Lock()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._recover_tail()
        empty = not os.path.exists(path) or os.path.getsize(path) == 0
        self._fh = open(path, "ab")
        if lineage is not None and empty:
            # lineage header: the FIRST record of a fresh journal names the
            # durable identity the file belongs to (workflow_id, parent,
            # fork point) — see docs/journal-format.md §2.5
            self.append(JournalRecord(kind="LINEAGE", meta=dict(lineage)))

    # -- crash recovery ------------------------------------------------------
    def _recover_tail(self) -> None:
        """Truncate a torn tail record (partial append at crash time)."""
        if not os.path.exists(self.path):
            return
        good = 0
        with open(self.path, "rb") as fh:
            data = fh.read()
        off = 0
        while off + _HEADER.size <= len(data):
            length, crc = _HEADER.unpack_from(data, off)
            body = data[off + _HEADER.size : off + _HEADER.size + length]
            if len(body) < length or binascii.crc32(body) != crc:
                break
            off += _HEADER.size + length
            good = off
        if good != len(data):
            with open(self.path, "r+b") as fh:
                fh.truncate(good)

    # -- append ----------------------------------------------------------------
    def append(self, rec: JournalRecord) -> None:
        rec.wall_time = rec.wall_time or time.time()  # record timestamp
        body = encode_payload(rec.to_obj())
        frame = _HEADER.pack(len(body), binascii.crc32(body)) + body
        with self._lock:
            self._fh.write(frame)
            if self.sync == "always":
                self._fh.flush()
                os.fsync(self._fh.fileno())

    def flush(self) -> None:
        with self._lock:
            self._fh.flush()
            if self.sync != "never":
                os.fsync(self._fh.fileno())

    def close(self) -> None:
        self.flush()
        self._fh.close()

    # -- read -----------------------------------------------------------------
    def kinds(self) -> Dict[str, int]:
        """Histogram of record kinds — cheap integrity/debug view of a run.

        E.g. a fault-tolerant cluster run reads as RUN_START=1, NODE_START=n,
        NODE_REQUEUE=k (worker evictions), NODE_COMMIT=n, RUN_END=1; a
        cache-accelerated run additionally shows CACHE_HIT=h and
        CACHE_STORE=n-h (every hit still commits, so NODE_COMMIT stays n);
        a streaming run adds CHUNK_COMMIT=Σchunks and one STREAM_EOS per
        stream stage.
        """
        return dict(Counter(rec.kind for rec in self.records()))

    def records(self, expand: bool = True) -> Iterator[JournalRecord]:
        """Yield every committed record, in append order.

        A checksum-valid frame whose body nonetheless fails to decode (e.g.
        written by an incompatible future version) is skipped with a
        warning, never raised — interpreting readers must stay usable on
        journals that carry record shapes they predate (format §5).

        A ``SNAPSHOT`` record (journal compaction, format §2.6) is yielded
        and then — with ``expand=True``, the default — *expanded*: the live
        records it folded stream out after it, exactly as the pre-compaction
        journal carried them, so every interpreting reader (replay oracle,
        workflow runner, lineage index) sees an identical history. A
        snapshot stamped with a layout version NEWER than
        :data:`SNAPSHOT_VERSION` is skipped whole with a RuntimeWarning —
        mis-applying a half-understood state bundle would corrupt replay.
        ``expand=False`` yields the raw physical frames (compaction tooling).
        """
        with open(self.path, "rb") as fh:
            data = fh.read()
        off = 0
        while off + _HEADER.size <= len(data):
            length, crc = _HEADER.unpack_from(data, off)
            body = data[off + _HEADER.size : off + _HEADER.size + length]
            if len(body) < length or binascii.crc32(body) != crc:
                break
            off += _HEADER.size + length
            try:
                rec = JournalRecord.from_obj(decode_payload(body))
            except Exception as exc:
                warnings.warn(
                    f"journal {self.path}: skipping undecodable record at "
                    f"offset {off - _HEADER.size - length} ({exc})",
                    RuntimeWarning,
                    stacklevel=2,
                )
                continue
            if rec.kind not in KNOWN_KINDS:
                # forward-compat (format §5): a newer writer may introduce
                # record kinds this reader predates — skip, never raise, so
                # replay of the records we DO understand stays available
                warnings.warn(
                    f"journal {self.path}: skipping record of unknown kind "
                    f"{rec.kind!r} at offset {off - _HEADER.size - length}",
                    RuntimeWarning,
                    stacklevel=2,
                )
                continue
            if rec.kind == "SNAPSHOT":
                version = int(rec.meta.get("version") or 0)
                if version > SNAPSHOT_VERSION:
                    # the version gate (format §2.6): a well-formed SNAPSHOT
                    # from a newer layout version must NOT be applied — its
                    # state layout may have changed meaning under this reader
                    warnings.warn(
                        f"journal {self.path}: skipping SNAPSHOT of newer "
                        f"layout version {version} (reader understands "
                        f"<= {SNAPSHOT_VERSION}) at offset "
                        f"{off - _HEADER.size - length}; compacted history "
                        "is unavailable to this reader",
                        RuntimeWarning,
                        stacklevel=2,
                    )
                    continue
                yield rec
                if not expand:
                    continue
                for obj in rec.meta.get("records") or ():
                    try:
                        sub = JournalRecord.from_obj(obj)
                    except Exception as exc:
                        warnings.warn(
                            f"journal {self.path}: skipping undecodable "
                            f"snapshot state record ({exc})",
                            RuntimeWarning,
                            stacklevel=2,
                        )
                        continue
                    if sub.kind not in KNOWN_KINDS or sub.kind == "SNAPSHOT":
                        warnings.warn(
                            f"journal {self.path}: skipping snapshot state "
                            f"record of unknown kind {sub.kind!r}",
                            RuntimeWarning,
                            stacklevel=2,
                        )
                        continue
                    yield sub
                continue
            yield rec

    # -- compaction bookkeeping (docs/journal-format.md §2.6) ----------------
    def snapshot(self) -> Optional[JournalRecord]:
        """The journal's SNAPSHOT record (always the first frame), or None."""
        for rec in self.records(expand=False):
            if rec.kind == "SNAPSHOT":
                return rec
            return None
        return None

    def base_seq(self) -> int:
        """First logical record seq still individually addressable.

        An uncompacted journal starts at 0. A compacted journal's SNAPSHOT
        folded the original records ``0 .. base_seq-1``; those seqs are no
        longer addressable (e.g. as a ``fork(at=...)`` point) — only the
        folded *live state* survives, not per-record identity.
        """
        snap = self.snapshot()
        return int(snap.meta.get("base_seq") or 0) if snap is not None else 0

    def end_seq(self) -> int:
        """One past the last logical record seq (``base_seq + raw suffix``)."""
        seq = 0
        for rec in self.records(expand=False):
            if rec.kind == "SNAPSHOT":
                seq = int(rec.meta.get("base_seq") or 0)
            else:
                seq += 1
        return seq

    def indexed_records(
        self,
    ) -> Iterator[Tuple[Optional[int], JournalRecord]]:
        """Yield ``(logical_seq, record)`` pairs, expanding snapshots.

        Records folded into a SNAPSHOT carry ``None`` — their individual
        seqs were retired by compaction (only live state survives); physical
        suffix records carry their stable logical seq, which addressing
        operations (``fork(at=...)``) keep honouring across compactions.
        """
        seq = 0
        for rec in self.records(expand=False):
            if rec.kind != "SNAPSHOT":
                yield seq, rec
                seq += 1
                continue
            seq = int(rec.meta.get("base_seq") or 0)
            for obj in rec.meta.get("records") or ():
                try:
                    sub = JournalRecord.from_obj(obj)
                except Exception:
                    continue
                if sub.kind in KNOWN_KINDS and sub.kind != "SNAPSHOT":
                    yield None, sub

    def lineage(self) -> Optional[Dict[str, Any]]:
        """The lineage header (first record, if it is a ``LINEAGE``), or None.

        Compaction-transparent: a compacted journal leads with its SNAPSHOT
        record, whose expansion re-yields the original LINEAGE header first.
        """
        for rec in self.records():
            if rec.kind == "SNAPSHOT":
                continue
            if rec.kind == "LINEAGE":
                return dict(rec.meta)
            return None
        return None

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ReplayCache:
    """Index of committed node outputs from a journal — the replay oracle.

    Also indexes the *chunk-granular* stream records (``CHUNK_COMMIT`` /
    ``STREAM_EOS``, docs/streaming.md §4): for a stream identity
    ``(node, ξ-digest, input-digest)`` it answers which chunk sequence
    numbers are already durable, the digest chain head, and whether the
    stream reached EOS — the facts a resumed producer needs to skip every
    committed chunk and continue from its last committed offset.
    """

    def __init__(self, journal: Optional[Journal] = None):
        self._committed: Dict[Tuple[str, str, str], JournalRecord] = {}
        self._chunks: Dict[Tuple[str, str, str], Dict[int, JournalRecord]] = {}
        self._eos: Dict[Tuple[str, str, str], JournalRecord] = {}
        # ``scanned`` counts the records this oracle had to walk to build
        # itself — the observable replay cost a compaction is meant to cut
        # from O(history) to O(live state) (docs/journal-lifecycle.md §1)
        self.stats = {"commits": 0, "replayed": 0, "chunks": 0, "scanned": 0}
        if journal is not None and os.path.exists(journal.path):
            for rec in journal.records():
                self.stats["scanned"] += 1
                if rec.kind == "NODE_COMMIT":
                    key = (rec.node_id, rec.context_digest, rec.input_digest)
                    self._committed[key] = rec
                    self.stats["commits"] += 1
                elif rec.kind == "CHUNK_COMMIT":
                    self.record_chunk(rec)
                elif rec.kind == "STREAM_EOS":
                    key = (rec.node_id, rec.context_digest, rec.input_digest)
                    self._eos[key] = rec

    def lookup(
        self, node_id: str, context_digest: str, input_digest: str
    ) -> Optional[JournalRecord]:
        rec = self._committed.get((node_id, context_digest, input_digest))
        if rec is not None:
            self.stats["replayed"] += 1
        return rec

    def record(self, rec: JournalRecord) -> None:
        self._committed[(rec.node_id, rec.context_digest, rec.input_digest)] = rec

    # -- chunk-granular stream state (docs/streaming.md §4) ------------------
    def record_chunk(self, rec: JournalRecord) -> None:
        """Index one ``CHUNK_COMMIT`` (keyed by stream identity + seq)."""
        key = (rec.node_id, rec.context_digest, rec.input_digest)
        self._chunks.setdefault(key, {})[int(rec.meta.get("seq", 0))] = rec
        self.stats["chunks"] += 1

    def record_eos(self, rec: JournalRecord) -> None:
        """Index one ``STREAM_EOS`` marker."""
        self._eos[(rec.node_id, rec.context_digest, rec.input_digest)] = rec

    def stream_progress(
        self, node_id: str, context_digest: str, input_digest: str
    ) -> Tuple[int, str, bool]:
        """Durable state of a stream: ``(next_seq, chain, eos_reached)``.

        ``next_seq`` is the first sequence number with no committed chunk
        (committed chunks form a contiguous prefix 0..next_seq-1 by
        construction — a chunk only commits after its predecessor);
        ``chain`` is the digest-chain head after the last committed chunk.
        """
        by_seq = self._chunks.get((node_id, context_digest, input_digest), {})
        next_seq = 0
        chain = ""
        while next_seq in by_seq:
            chain = str(by_seq[next_seq].meta.get("chain", ""))
            next_seq += 1
        eos = (node_id, context_digest, input_digest) in self._eos
        return next_seq, chain, eos

    def stream_chunks(
        self, node_id: str, context_digest: str, input_digest: str
    ) -> "list[JournalRecord]":
        """Committed chunk records, in sequence order (contiguous prefix)."""
        by_seq = self._chunks.get((node_id, context_digest, input_digest), {})
        out = []
        seq = 0
        while seq in by_seq:
            out.append(by_seq[seq])
            seq += 1
        return out


# --------------------------------------------------------------------------
# atomic task decorator — dependency injection contract (§3.2 assumption 2)
# --------------------------------------------------------------------------


def atomic_task(fn: Callable[..., Any]) -> Callable[..., Any]:
    """Mark ``fn`` as an atomic durable task.

    The contract: fn(ctx: Context, **injected_inputs) -> output. The wrapper
    rejects ambient-state smuggling (positional args) and stamps metadata the
    executor uses for digesting.
    """

    def wrapper(ctx: Context, **inputs: Any) -> Any:
        return fn(ctx, **inputs)

    wrapper.__name__ = getattr(fn, "__name__", "task")
    wrapper.__atomic_task__ = True  # type: ignore[attr-defined]
    wrapper.__wrapped__ = fn  # type: ignore[attr-defined]
    return wrapper
