"""orjson codec — optional fast JSON backend (``pip install repro[fast]``).

Importing this module raises ImportError when orjson is absent; the registry
in ``repro.wire`` gates on that, so the rest of the system never needs orjson.

orjson accelerates *transport* encode/decode only. ``canonical_bytes`` is
deliberately NOT overridden: orjson's Rust float writer formats scientific
notation differently from Python's repr (``1e-5`` vs ``1e-05``) and rejects
ints outside 64 bits, so reusing it for the hashing form would break the
backend-stability guarantee on exactly the hosts that install the fast
extra. The canonical form is produced by one encoder everywhere — see
``Codec.canonical_bytes`` in base.py and docs/journal-format.md §3.
"""

from __future__ import annotations

from typing import Any

import orjson

from .base import Codec, normalize

__all__ = ["OrjsonCodec"]


class OrjsonCodec(Codec):
    """orjson backend: fast transport JSON, canonical form inherited."""

    name = "orjson"

    def encode(self, obj: Any) -> bytes:
        """Fast (non-canonical) JSON transport bytes of the normalized tree."""
        return orjson.dumps(normalize(obj))

    def decode(self, data: bytes) -> Any:
        """Parse JSON transport bytes back to a value tree."""
        return orjson.loads(data)
