"""Binary msgpack codec — the fast transport backend for cross-host RPC.

Transport encoding preserves numpy/jax arrays losslessly via ExtType frames
(dtype, shape, raw buffer) instead of flattening them to lists — this is what
the worker HTTP transport and the durable journal ship. Canonical bytes are
inherited from :class:`Codec`: msgpack maps have no canonical key order, so
the hashing form stays the shared canonical JSON — digests computed on a
msgpack-transport host match digests computed anywhere else.
"""

from __future__ import annotations

from typing import Any

import msgpack

from .base import Codec

__all__ = ["MsgpackCodec", "EXT_NDARRAY", "EXT_COMPLEX", "pack_default", "unpack_ext"]

EXT_NDARRAY = 1
EXT_COMPLEX = 2


def pack_default(obj: Any) -> Any:
    """msgpack ``default`` hook: arrays/complex/sets → ExtType or list frames."""
    if hasattr(obj, "__array__"):  # np/jax arrays and scalars
        import numpy as np

        arr = np.asarray(obj)
        return msgpack.ExtType(
            EXT_NDARRAY,
            msgpack.packb((arr.dtype.str, arr.shape, arr.tobytes()), use_bin_type=True),
        )
    if isinstance(obj, complex):
        return msgpack.ExtType(EXT_COMPLEX, msgpack.packb((obj.real, obj.imag)))
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    raise TypeError(f"unpackable type {type(obj)!r}")


def unpack_ext(code: int, data: bytes) -> Any:
    """msgpack ``ext_hook``: reconstruct arrays/complex from ExtType frames."""
    if code == EXT_NDARRAY:
        import numpy as np

        dtype, shape, raw = msgpack.unpackb(data, raw=False)
        return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)
    if code == EXT_COMPLEX:
        re_, im = msgpack.unpackb(data)
        return complex(re_, im)
    return msgpack.ExtType(code, data)


class MsgpackCodec(Codec):
    """Binary msgpack backend with lossless ndarray/complex extensions."""

    name = "msgpack"

    def encode(self, obj: Any) -> bytes:
        """Binary transport bytes (arrays preserved via ExtType frames)."""
        return msgpack.packb(obj, default=pack_default, use_bin_type=True)

    def decode(self, data: bytes) -> Any:
        """Inverse of :meth:`encode` (ExtType frames → arrays/complex)."""
        return msgpack.unpackb(data, ext_hook=unpack_ext, raw=False, strict_map_key=False)
