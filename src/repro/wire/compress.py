"""Tagged-frame compression with graceful zstd fallback.

The seed hard-imported ``zstandard``; this module makes compression pluggable
the same way codecs are. Every compressed frame is prefixed with a one-byte
tag so the decompressor is self-describing:

    0x00  raw (no compression)
    0x01  zlib (stdlib — always available)
    0x02  zstd (when the optional ``zstandard`` package is installed)

``compress`` picks the best available scheme (zstd > zlib); ``decompress``
dispatches on the tag, so a journal written on a zstd host replays on a
zlib-only host as long as the frames it contains are zlib/raw — and a frame
that *requires* zstd fails with an actionable error instead of a crash.
Legacy untagged zstd frames from seed journals (magic ``0x28 B5 2F FD``) are
detected and decompressed when zstd is available.
"""

from __future__ import annotations

import zlib

__all__ = ["compress", "decompress", "zstd_available", "TAG_RAW", "TAG_ZLIB", "TAG_ZSTD"]

TAG_RAW = 0x00
TAG_ZLIB = 0x01
TAG_ZSTD = 0x02

_ZSTD_MAGIC_BYTE = 0x28  # first byte of the zstd frame magic 0x28B52FFD

try:
    import zstandard as _zstd
except ImportError:  # optional: repro[compression]
    _zstd = None


def zstd_available() -> bool:
    """True iff the optional ``zstandard`` package is importable."""
    return _zstd is not None


def compress(data: bytes, level: int = 3) -> bytes:
    """Compress with the best available scheme, prefixed with its tag byte."""
    if _zstd is not None:
        return bytes([TAG_ZSTD]) + _zstd.ZstdCompressor(level=level).compress(data)
    return bytes([TAG_ZLIB]) + zlib.compress(data, min(level * 2, 9))


def decompress(frame: bytes) -> bytes:
    """Decompress a tagged frame, dispatching on its self-describing tag byte."""
    if not frame:
        raise ValueError("empty compression frame")
    tag = frame[0]
    body = frame[1:]
    if tag == TAG_RAW:
        return body
    if tag == TAG_ZLIB:
        return zlib.decompress(body)
    if tag == TAG_ZSTD:
        if _zstd is None:
            raise ImportError(
                "frame is zstd-compressed but 'zstandard' is not installed; "
                "pip install zstandard (the repro[compression] extra)"
            )
        return _zstd.ZstdDecompressor().decompress(body)
    if tag == _ZSTD_MAGIC_BYTE:  # legacy seed-era frame: untagged raw zstd
        if _zstd is None:
            raise ImportError(
                "frame looks like a legacy untagged zstd frame but "
                "'zstandard' is not installed; pip install zstandard "
                "(the repro[compression] extra) to read it"
            )
        return _zstd.ZstdDecompressor().decompress(frame)
    raise ValueError(f"unknown compression tag 0x{tag:02x}")
