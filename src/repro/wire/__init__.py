"""repro.wire — pluggable canonical-serialization subsystem.

One import point for everything that turns values into bytes:

  - ``Codec`` backends: ``json`` (stdlib, zero-dependency default),
    ``msgpack`` (binary transport, array-preserving), ``orjson`` (optional
    fast JSON, auto-selected when importable — the ``repro[fast]`` extra);
  - ``canonical_bytes`` / ``canonical_digest``: the backend-stable hashing
    form (identical bytes under every codec — see docs/journal-format.md);
  - ``encode_payload`` / ``decode_payload`` / ``payload_digest``: the
    compressed msgpack pytree codec used by the journal and worker RPC;
  - ``compress`` / ``decompress``: tagged-frame compression (zstd → zlib
    fallback).

Backend selection: ``REPRO_WIRE_CODEC`` env var (``json`` | ``msgpack`` |
``orjson``) wins, else orjson when importable, else stdlib json. Override at
runtime with :func:`set_default_codec`.
"""

from __future__ import annotations

import json as _json
import os
from typing import Any, Callable, Dict, List, Optional

from .base import Codec, DIGEST_HEX_LEN, normalize, stdlib_canonical
from .compress import compress, decompress, zstd_available
from .json_codec import JsonCodec
from .msgpack_codec import MsgpackCodec
from .payload import (
    Digested,
    PayloadDecodeError,
    decode_payload,
    encode_frame,
    encode_payload,
    payload_digest,
    read_frames,
    unwrap_digested,
)

__all__ = [
    "Codec",
    "JsonCodec",
    "MsgpackCodec",
    "DIGEST_HEX_LEN",
    "normalize",
    "stdlib_canonical",
    "available_codecs",
    "get_codec",
    "default_codec",
    "set_default_codec",
    "canonical_bytes",
    "canonical_digest",
    "from_canonical",
    "PayloadDecodeError",
    "Digested",
    "unwrap_digested",
    "encode_payload",
    "decode_payload",
    "payload_digest",
    "encode_frame",
    "read_frames",
    "compress",
    "decompress",
    "zstd_available",
]

ENV_VAR = "REPRO_WIRE_CODEC"


def _make_orjson() -> Codec:
    from .orjson_codec import OrjsonCodec  # ImportError if orjson absent

    return OrjsonCodec()


_FACTORIES: Dict[str, Callable[[], Codec]] = {
    "json": JsonCodec,
    "msgpack": MsgpackCodec,
    "orjson": _make_orjson,
}
_instances: Dict[str, Codec] = {}
_default: Optional[Codec] = None


def available_codecs() -> List[str]:
    """Names of codecs importable in this environment."""
    out = []
    for name in _FACTORIES:
        try:
            get_codec(name)
            out.append(name)
        except ImportError:
            pass
    return out


def get_codec(name: str) -> Codec:
    """Return the (memoized) codec registered under ``name``."""
    if name not in _FACTORIES:
        raise KeyError(f"unknown wire codec {name!r}; choose from {sorted(_FACTORIES)}")
    if name not in _instances:
        _instances[name] = _FACTORIES[name]()
    return _instances[name]


def default_codec() -> Codec:
    """The active codec: $REPRO_WIRE_CODEC > orjson-if-available > json."""
    global _default
    if _default is None:
        forced = os.environ.get(ENV_VAR, "").strip()
        if forced:
            _default = get_codec(forced)
        else:
            try:
                _default = get_codec("orjson")
            except ImportError:
                _default = get_codec("json")
    return _default


def set_default_codec(name: Optional[str]) -> Codec:
    """Force the process-wide default codec (None re-runs auto-selection)."""
    global _default
    _default = None if name is None else get_codec(name)
    return default_codec()


# -- canonical form (backend-stable: same bytes whatever the codec) ----------


def canonical_bytes(value: Any) -> bytes:
    """Backend-stable hashing bytes of ``value`` (identical under any codec)."""
    return default_codec().canonical_bytes(value)


def canonical_digest(value: Any) -> str:
    """Truncated sha256 of :func:`canonical_bytes` — the journal id form."""
    return default_codec().canonical_digest(value)


try:
    from orjson import loads as _canonical_loads  # fastest JSON parser present
except ImportError:
    _canonical_loads = _json.loads


def from_canonical(data: bytes) -> Any:
    """Parse canonical bytes. Canonical form is always JSON, so this is
    codec-independent — a msgpack-transport host still parses digest bytes."""
    return _canonical_loads(data)
