"""Codec protocol + canonicalization rules shared by every wire backend.

The wire subsystem separates two concerns that the seed conflated:

  1. **Transport encoding** (``Codec.encode`` / ``Codec.decode``) — how a
     value travels between processes. Backends are free to pick any
     self-describing byte format (JSON text, msgpack binary, ...).
  2. **Canonical bytes** (``Codec.canonical_bytes``) — the *hashing* form.
     This is defined once, independent of the transport backend: UTF-8 JSON
     of the normalized value tree, sorted keys, compact separators. Every
     codec MUST produce byte-identical canonical bytes for the same value —
     that is the backend-stability guarantee the durable journal relies on
     (a digest recorded under orjson replays under stdlib json and vice
     versa). See docs/journal-format.md §3.

Normalization rules (applied before canonical encoding):
  - mappings     → dict, keys sorted lexicographically (non-``str`` keys are
    a ``TypeError`` — coercion would collide distinct values on one digest)
  - list / tuple → list
  - set / frozenset → sorted list
  - bytes / bytearray → lowercase hex string
  - objects with ``__array__`` (numpy / jax arrays and scalars) → nested
    lists of native scalars via ``np.asarray(x).tolist()``
  - NaN / ±Inf floats → ``None`` (matches orjson's observable behaviour,
    which the seed's digests inherited)
  - str / int / float / bool / None pass through
Anything else raises ``TypeError``.
"""

from __future__ import annotations

import hashlib
import json
import math
from abc import ABC, abstractmethod
from typing import Any, Mapping

__all__ = ["Codec", "normalize", "stdlib_canonical", "DIGEST_HEX_LEN"]

DIGEST_HEX_LEN = 16  # sha256 truncated to 64 bits of hex — the journal id width


def normalize(value: Any) -> Any:
    """Reduce ``value`` to a JSON-native tree with deterministic ordering."""
    if value is None or isinstance(value, (str, bool, int)):
        return value
    if isinstance(value, float):
        return value if math.isfinite(value) else None
    if isinstance(value, Mapping):
        for k in value:
            if not isinstance(k, str):
                # coercing with str(k) would let {1: 'a'} and {'1': 'a'}
                # collide on one digest — reject, as the seed encoder did
                raise TypeError(
                    f"mapping keys must be str for canonical encoding, "
                    f"got {type(k).__name__!r}"
                )
        return {k: normalize(value[k]) for k in sorted(value)}
    if isinstance(value, (list, tuple)):
        return [normalize(v) for v in value]
    if isinstance(value, (set, frozenset)):
        return [normalize(v) for v in sorted(value)]
    if isinstance(value, (bytes, bytearray)):
        return bytes(value).hex()
    if hasattr(value, "__array__"):
        import numpy as np

        return normalize(np.asarray(value).tolist())
    raise TypeError(f"wire value of type {type(value)!r} is not serializable")


def stdlib_canonical(tree: Any) -> bytes:
    """Canonical JSON bytes of an already-normalized tree (stdlib encoder)."""
    return json.dumps(tree, ensure_ascii=False, allow_nan=False, separators=(",", ":")).encode(
        "utf-8"
    )


class Codec(ABC):
    """A wire backend: transport encoding + the shared canonical form."""

    name: str = "abstract"

    @abstractmethod
    def encode(self, obj: Any) -> bytes:
        """Transport encoding — need not be canonical, must round-trip."""

    @abstractmethod
    def decode(self, data: bytes) -> Any:
        """Inverse of :meth:`encode`."""

    def canonical_bytes(self, value: Any) -> bytes:
        """Backend-stable hashing form: canonical JSON of the normalized tree.

        Produced by the stdlib encoder for EVERY backend. Transport codecs
        must not substitute their own JSON writer here — e.g. orjson formats
        ``1e-05`` as ``1e-5`` and rejects >64-bit ints, which would fork
        digests across hosts (byte-identity enforced by tests/test_wire.py).
        """
        return stdlib_canonical(normalize(value))

    def canonical_digest(self, value: Any) -> str:
        """Truncated sha256 of :meth:`canonical_bytes` — the journal id form."""
        return hashlib.sha256(self.canonical_bytes(value)).hexdigest()[:DIGEST_HEX_LEN]

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{type(self).__name__} name={self.name!r}>"
