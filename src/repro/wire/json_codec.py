"""Zero-dependency stdlib JSON codec — the default wire backend.

Transport encoding IS the canonical form (sorted keys, compact, UTF-8), so
``encode(x) == canonical_bytes(x)`` here. ``pretty=True`` produces the
indented human-readable variant used for on-disk manifests.
"""

from __future__ import annotations

import json
from typing import Any

from .base import Codec, normalize, stdlib_canonical

__all__ = ["JsonCodec"]


class JsonCodec(Codec):
    """Stdlib JSON backend: transport bytes ARE the canonical bytes."""

    name = "json"

    def encode(self, obj: Any, pretty: bool = False) -> bytes:
        """Canonical JSON bytes; ``pretty=True`` indents for manifests."""
        tree = normalize(obj)
        if pretty:
            return json.dumps(tree, ensure_ascii=False, allow_nan=False, indent=1).encode("utf-8")
        return stdlib_canonical(tree)

    def decode(self, data: bytes) -> Any:
        """Parse JSON transport bytes back to a value tree."""
        return json.loads(data)
