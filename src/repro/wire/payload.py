"""Payload codec: arbitrary pytrees of np/jax arrays + python scalars.

This is the journal/RPC *body* format (moved here from ``core.durable`` so
every layer shares one implementation): msgpack with ExtType array frames,
wrapped in a tagged compression frame (see :mod:`repro.wire.compress`).

``payload_digest`` is the deterministic identity of a payload pytree — it
feeds sha256 directly from array buffers (no serialization round-trip), so
it is compression- and codec-independent by construction.
"""

from __future__ import annotations

import binascii
import hashlib
import struct
from typing import Any, BinaryIO, Iterator, Mapping

import msgpack

from .base import DIGEST_HEX_LEN
from .compress import compress, decompress
from .msgpack_codec import pack_default, unpack_ext

__all__ = [
    "PayloadDecodeError",
    "Digested",
    "unwrap_digested",
    "encode_payload",
    "decode_payload",
    "payload_digest",
    "encode_frame",
    "read_frames",
    "FRAME_HEADER",
]


class Digested:
    """A payload value carrying its precomputed :func:`payload_digest`.

    Tensor-bearing task graphs (the distributed trainer's params-sync path)
    hash the same large pytree at several layers: the producing node's output
    digest, every consumer's input digest, and the journal commit. Wrapping
    the value once — ``Digested.wrap(params)`` — makes every subsequent
    :func:`payload_digest` over it O(1): the digest is folded in as a
    fixed-size token instead of re-feeding the raw buffers.

    ``Digested`` is a *scheduling-layer* hint, never a wire type: executors
    and the gateway unwrap it (:func:`unwrap_digested`) before a task function
    or transport sees the inputs, :func:`encode_payload` strips any wrapper
    left in an encoded tree, and workers strip wrappers from task *results*
    so journal digests are transport-independent. Use it only on values that
    stay executor-side; the wrapper owner is responsible for the digest
    actually matching the value.
    """

    __slots__ = ("value", "digest")

    def __init__(self, value: Any, digest: str):
        self.value = value
        self.digest = digest

    @staticmethod
    def wrap(value: Any) -> "Digested":
        """Wrap ``value`` with its freshly computed payload digest."""
        return Digested(value, payload_digest(value))

    def __repr__(self) -> str:  # keep tensor pytrees out of logs/errors
        return f"Digested({self.digest})"


def unwrap_digested(obj: Any) -> Any:
    """Strip :class:`Digested` wrappers from a payload pytree.

    Copy-on-write: containers are rebuilt only along paths that actually
    contain a wrapper, so the common wrapper-free case is a cheap identity
    walk with no allocation.
    """
    if isinstance(obj, Digested):
        return unwrap_digested(obj.value)
    if isinstance(obj, dict):
        out = {k: unwrap_digested(v) for k, v in obj.items()}
        return obj if all(out[k] is obj[k] for k in out) else out
    if isinstance(obj, (list, tuple)):
        vals = [unwrap_digested(v) for v in obj]
        if all(a is b for a, b in zip(vals, obj, strict=True)):
            return obj
        if isinstance(obj, tuple) and hasattr(obj, "_fields"):
            return type(obj)(*vals)  # NamedTuple: positional reconstruction
        return type(obj)(vals)
    return obj


class PayloadDecodeError(ValueError):
    """A payload frame that cannot be decoded (corrupt or incompatible bytes).

    Raised by :func:`decode_payload` instead of leaking backend-specific
    exceptions, so callers holding untrusted bytes — the result cache's
    corrupted-blob fallback, journal tail recovery — can catch one type.
    """


def encode_payload(obj: Any, level: int = 3) -> bytes:
    """Encode a pytree as a tagged-compressed msgpack frame (journal body).

    :class:`Digested` wrappers are stripped first — the digest hint is
    process-local scheduling state, never part of the wire format.
    """
    body = msgpack.packb(unwrap_digested(obj), default=pack_default, use_bin_type=True)
    return compress(body, level=level)


def decode_payload(buf: bytes) -> Any:
    """Inverse of :func:`encode_payload`; malformed bytes raise PayloadDecodeError."""
    try:
        body = decompress(buf)
        return msgpack.unpackb(body, ext_hook=unpack_ext, raw=False, strict_map_key=False)
    except ImportError:
        raise  # actionable "install zstandard" from repro.wire.compress
    except Exception as exc:
        raise PayloadDecodeError(f"undecodable payload frame: {exc}") from exc


# -- chunk framing (streaming transport) ------------------------------------
#
# A *frame* is one length-prefixed, checksummed payload on a byte stream —
# the same ``(length: u32, crc32: u32, body)`` layout the journal uses
# (docs/journal-format.md §1), so a stream of frames is torn-tail-safe at
# frame granularity. Frames carry stream-protocol objects (chunk / EOS /
# error maps); the framing itself is payload-agnostic.

FRAME_HEADER = struct.Struct("<II")  # (length, crc32) — journal-identical


def encode_frame(obj: Any) -> bytes:
    """One self-delimiting frame: header + tagged-compressed payload body."""
    body = encode_payload(obj)
    return FRAME_HEADER.pack(len(body), binascii.crc32(body)) + body


def read_frames(fp: BinaryIO) -> Iterator[Any]:
    """Decode frames off a blocking byte stream until EOF.

    A short read mid-frame (the producer died between frames being flushed)
    or a crc mismatch raises :class:`PayloadDecodeError` — a torn stream is
    *detected*, never silently truncated, because the consumer must know
    the difference between EOS and a lost producer.
    """
    while True:
        header = fp.read(FRAME_HEADER.size)
        if not header:
            return
        if len(header) < FRAME_HEADER.size:
            raise PayloadDecodeError("torn stream: partial frame header")
        length, crc = FRAME_HEADER.unpack(header)
        body = b""
        while len(body) < length:
            piece = fp.read(length - len(body))
            if not piece:
                raise PayloadDecodeError("torn stream: partial frame body")
            body += piece
        if binascii.crc32(body) != crc:
            raise PayloadDecodeError("corrupt stream frame (crc mismatch)")
        yield decode_payload(body)


def payload_digest(obj: Any) -> str:
    """Digest of a payload pytree — used as the deterministic input/output id."""
    import numpy as np

    h = hashlib.sha256()

    def _feed(x: Any) -> None:
        if isinstance(x, Digested):  # precomputed: fold the token, not the value
            h.update(b"digested:")
            h.update(x.digest.encode())
        elif isinstance(x, Mapping):
            for k in sorted(x, key=str):
                h.update(str(k).encode())
                _feed(x[k])
        elif isinstance(x, (list, tuple)):
            h.update(b"[")
            for v in x:
                _feed(v)
            h.update(b"]")
        elif hasattr(x, "__array__"):
            arr = np.asarray(x)
            h.update(arr.dtype.str.encode())
            h.update(str(arr.shape).encode())
            h.update(np.ascontiguousarray(arr).tobytes())
        else:
            h.update(repr(x).encode())

    _feed(obj)
    return h.hexdigest()[:DIGEST_HEX_LEN]
