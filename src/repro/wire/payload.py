"""Payload codec: arbitrary pytrees of np/jax arrays + python scalars.

This is the journal/RPC *body* format (moved here from ``core.durable`` so
every layer shares one implementation): msgpack with ExtType array frames,
wrapped in a tagged compression frame (see :mod:`repro.wire.compress`).

``payload_digest`` is the deterministic identity of a payload pytree — it
feeds sha256 directly from array buffers (no serialization round-trip), so
it is compression- and codec-independent by construction.
"""
from __future__ import annotations

import hashlib
from typing import Any, Mapping

import msgpack

from .base import DIGEST_HEX_LEN
from .compress import compress, decompress
from .msgpack_codec import pack_default, unpack_ext

__all__ = ["encode_payload", "decode_payload", "payload_digest"]


def encode_payload(obj: Any, level: int = 3) -> bytes:
    body = msgpack.packb(obj, default=pack_default, use_bin_type=True)
    return compress(body, level=level)


def decode_payload(buf: bytes) -> Any:
    body = decompress(buf)
    return msgpack.unpackb(body, ext_hook=unpack_ext, raw=False,
                           strict_map_key=False)


def payload_digest(obj: Any) -> str:
    """Digest of a payload pytree — used as the deterministic input/output id."""
    import numpy as np

    h = hashlib.sha256()

    def feed(x: Any) -> None:
        if isinstance(x, Mapping):
            for k in sorted(x, key=str):
                h.update(str(k).encode())
                feed(x[k])
        elif isinstance(x, (list, tuple)):
            h.update(b"[")
            for v in x:
                feed(v)
            h.update(b"]")
        elif hasattr(x, "__array__"):
            arr = np.asarray(x)
            h.update(arr.dtype.str.encode())
            h.update(str(arr.shape).encode())
            h.update(np.ascontiguousarray(arr).tobytes())
        else:
            h.update(repr(x).encode())

    feed(obj)
    return h.hexdigest()[:DIGEST_HEX_LEN]
