"""One unified entry point: ``repro.Client``.

Historically the repo grew four parallel front doors — ``LocalExecutor``,
``ClusterExecutor`` (+ hand-built ``Gateway``), ``WorkflowRunner``, and the
trainers — each wiring its own journal, cache, and run directory. ``Client``
consolidates that construction in one place::

    import repro

    with repro.Client("./state") as client:
        report = client.run(graph)                  # durable local run
        report = client.stream(stream_graph)        # chunked dataflow run

    workers = [InProcWorker(f"w{i}", registry) for i in range(4)]
    with repro.Client("./state", cluster=workers, shards=2) as client:
        report = client.run(graph)                  # sharded gateway dispatch
        wf = client.workflow("order")
        res = wf.run({"region": "eu"})
        res = wf.resume(res.workflow_id, inputs={"approve": True})

Layout under ``base_dir``::

    runs/<run_id>/journal.wal    one durable journal per .run()/.stream() id
    workflows/                   the WorkflowStore (journals + meta.json)
    .cache/                      content-addressed ResultCache shared by all

Re-running the same ``run_id`` resumes from its journal (replay, then
continue) — that is the durability contract, not an error. ``cluster``
accepts a list of workers (the client builds and owns a :class:`Gateway`,
or a :class:`ShardedGateway` when ``shards > 1``) or a prebuilt
gateway-like object (caller keeps ownership). ``REPRO_RUNTIME=async``
transparently selects the asyncio control plane underneath either form.
"""

from __future__ import annotations

import os
from typing import Any, Dict, List, Mapping, Optional, Sequence, Union

from repro.cache import ResultCache
from repro.core.durable import Journal
from repro.core.executor import ClusterExecutor, ExecutionReport, LocalExecutor
from repro.core.gateway import Gateway
from repro.core.graph import ContextGraph
from repro.journal import CompactionStats, LineageIndex, compact_journal
from repro.obs.metrics import MetricsRegistry, cache_collector, gateway_collector
from repro.obs.metrics import metrics as _global_metrics
from repro.obs.sinks import JsonlSink
from repro.obs.trace import get_tracer
from repro.workflow import WorkflowRegistry, WorkflowRunner
from repro.workflow.api import WorkflowResult

__all__ = ["Client", "WorkflowHandle"]


class WorkflowHandle:
    """``client.workflow(name)``: the named workflow's run/resume/fork/status."""

    def __init__(self, runner: WorkflowRunner, workflow: str):
        self._runner = runner
        self.workflow = workflow

    def run(
        self,
        args: Optional[Mapping[str, Any]] = None,
        workflow_id: Optional[str] = None,
    ) -> WorkflowResult:
        """Start a new durable incarnation of this workflow."""
        return self._runner.run(self.workflow, args=args, workflow_id=workflow_id)

    def resume(
        self,
        workflow_id: str,
        inputs: Optional[Mapping[str, Any]] = None,
    ) -> WorkflowResult:
        """Answer the pending interrupt (or just re-run) a suspended id."""
        return self._runner.resume(workflow_id, inputs=inputs)

    def fork(self, workflow_id: str, **kw: Any) -> WorkflowResult:
        """Branch a child from a committed prefix; see WorkflowRunner.fork."""
        return self._runner.fork(workflow_id, **kw)

    def status(self, workflow_id: str) -> Dict[str, Any]:
        """Store meta plus pending-interrupt detail for one id."""
        return self._runner.status(workflow_id)

    def lineage(self, workflow_id: str) -> LineageIndex:
        """Provenance projection over one workflow id's journal."""
        with Journal(
            self._runner.store.journal_path(workflow_id), sync="never"
        ) as j:
            return LineageIndex.build(j)

    def compact(
        self, workflow_id: str, keep_since: Optional[int] = None
    ) -> CompactionStats:
        """Compact one workflow id's journal (offline; see compact_journal)."""
        return compact_journal(
            self._runner.store.journal_path(workflow_id), keep_since=keep_since
        )


class Client:
    """Unified façade over local, cluster, workflow, and training execution.

    Parameters
    ----------
    base_dir:
        Root of all durable state (journals, workflow store, result cache).
    cluster:
        ``None`` for in-process execution; a sequence of workers to have the
        client build and own a gateway; or a prebuilt gateway-like object
        (anything with ``submit``/``start``/``stop``) the caller owns.
    shards:
        With a worker list and ``shards > 1``, build a
        :class:`~repro.core.aio.ShardedGateway` with that many replicas.
    workflows:
        The :class:`WorkflowRegistry` naming graph factories for
        :meth:`workflow`; an empty registry is created when omitted so
        callers can ``client.workflows.register(...)`` directly.
    cache:
        ``True`` (default) shares one content-addressed ResultCache across
        every run and workflow under ``base_dir/.cache``.
    remote_cache:
        Optional shared filesystem path: chains the local cache to a
        :class:`~repro.cache.TieredCacheBackend` remote tier so a fleet of
        clients on different hosts deduplicates work across hosts (reads
        promote remote hits into the local tier; remote publishes are
        best-effort). Requires ``cache=True``.
    trace:
        ``True`` enables distributed tracing for every :meth:`run` /
        :meth:`stream`, writing a span log to ``runs/<run_id>/spans.jsonl``
        (the input ``python -m repro trace`` merges with the journal).
        ``None`` (default) defers to the ``REPRO_TRACE`` environment
        variable (``1``/``true``/``on`` enable).
    """

    def __init__(
        self,
        base_dir: str,
        *,
        cluster: Union[None, Sequence[Any], Any] = None,
        shards: int = 1,
        workflows: Optional[WorkflowRegistry] = None,
        cache: bool = True,
        remote_cache: Optional[str] = None,
        trace: Optional[bool] = None,
        journal_sync: str = "always",
        max_workers: int = 8,
        gateway_options: Optional[Mapping[str, Any]] = None,
    ):
        self.base_dir = base_dir
        os.makedirs(base_dir, exist_ok=True)
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.shards = shards
        self.journal_sync = journal_sync
        self.max_workers = max_workers
        self.workflows = workflows if workflows is not None else WorkflowRegistry()
        if trace is None:
            trace = os.environ.get("REPRO_TRACE", "").lower() in ("1", "true", "on")
        self.trace = bool(trace)
        self._collectors: List[str] = []
        if cache:
            self.cache: Optional[ResultCache] = ResultCache(
                os.path.join(base_dir, ".cache"), remote_root=remote_cache
            )
            self._bind_collector("cache", cache_collector(self.cache))
        elif remote_cache is not None:
            raise ValueError("remote_cache requires cache=True")
        else:
            self.cache = None
        self._gateway_options = dict(gateway_options or {})
        self._gateway: Optional[Any] = None
        self._owns_gateway = False
        self._workers: Optional[List[Any]] = None
        self._runner: Optional[WorkflowRunner] = None
        self._closed = False
        if cluster is None:
            pass
        elif isinstance(cluster, (list, tuple)):
            self._workers = list(cluster)
        elif hasattr(cluster, "submit"):
            self._gateway = cluster  # prebuilt; caller owns its lifecycle
        else:
            raise TypeError(
                "cluster must be None, a sequence of workers, or a "
                f"gateway-like object; got {type(cluster).__name__}"
            )

    # -- execution -----------------------------------------------------------
    def run(
        self,
        graph: ContextGraph,
        run_id: Optional[str] = None,
        run_meta: Optional[Mapping[str, Any]] = None,
    ) -> ExecutionReport:
        """Execute ``graph`` durably; local or cluster per the constructor.

        The journal lives at ``runs/<run_id>/journal.wal`` (``run_id``
        defaults to the graph's name); re-running the same id replays the
        committed prefix and continues — the crash-recovery path and the
        happy path are the same call.
        """
        self._check_open()
        rid = run_id or graph.name or "run"
        run_dir = os.path.join(self.base_dir, "runs", rid)
        os.makedirs(run_dir, exist_ok=True)
        with Journal(
            os.path.join(run_dir, "journal.wal"), sync=self.journal_sync
        ) as journal:
            ex = self._executor(journal)
            meta = dict(run_meta) if run_meta else None
            if not self.trace:
                return ex.run(graph, run_meta=meta)
            sink = JsonlSink(os.path.join(run_dir, "spans.jsonl"))
            with sink, get_tracer().attached(sink):
                return ex.run(graph, run_meta=meta)

    def stream(
        self,
        graph: ContextGraph,
        run_id: Optional[str] = None,
        run_meta: Optional[Mapping[str, Any]] = None,
    ) -> ExecutionReport:
        """Run a chunked-dataflow graph (requires at least one stream stage).

        Same durability contract as :meth:`run` — chunk-granular
        ``CHUNK_COMMIT`` records, resumable mid-stream — with an explicit
        guard so a batch graph routed here fails loudly instead of silently
        degrading to batch semantics.
        """
        if not any(n.stream for n in graph.nodes.values()):
            raise ValueError(
                f"graph {graph.name!r} declares no stream stages; use .run()"
            )
        return self.run(graph, run_id=run_id, run_meta=run_meta)

    def workflow(self, name: str) -> WorkflowHandle:
        """A handle on the named workflow (must be in ``self.workflows``)."""
        self._check_open()
        self.workflows.get(name)  # fail fast on unknown names
        return WorkflowHandle(self._workflow_runner(), name)

    def train(self, trainer: Any) -> Dict[str, Any]:
        """Run a (Distributed)Trainer's durable loop to completion.

        Trainers own their run directory and journal (``TrainConfig.run_dir``)
        — the client just drives the loop, so recovery/replay semantics are
        exactly those of ``trainer.train()``.
        """
        self._check_open()
        if not hasattr(trainer, "train"):
            raise TypeError(
                f"train() expects a trainer with a .train() loop; "
                f"got {type(trainer).__name__}"
            )
        return trainer.train()

    # -- journal lifecycle (docs/journal-lifecycle.md) -----------------------
    def journal_path(self, run_id: str) -> str:
        """The durable journal path behind one ``run_id``."""
        return os.path.join(self.base_dir, "runs", run_id, "journal.wal")

    def lineage(self, run_id: str) -> LineageIndex:
        """Provenance projection over one run's journal.

        Derived and disposable — rebuilt from the journal (compacted or not)
        on every call; answers ``provenance``/``consumers``/``produced``
        queries with bounded traversals. Raises ``FileNotFoundError`` for an
        unknown ``run_id``.
        """
        self._check_open()
        path = self.journal_path(run_id)
        if not os.path.exists(path):
            raise FileNotFoundError(f"no journal for run_id {run_id!r} at {path}")
        with Journal(path, sync="never") as j:
            return LineageIndex.build(j)

    def compact(
        self, run_id: str, keep_since: Optional[int] = None
    ) -> CompactionStats:
        """Fold one run's committed journal prefix into a SNAPSHOT record.

        Offline operation: call it between runs, never while the run is
        executing. ``keep_since`` retains logical seqs >= that value as
        addressable suffix records (e.g. fork points); ``None`` folds all.
        """
        self._check_open()
        return compact_journal(self.journal_path(run_id), keep_since=keep_since)

    def metrics(self) -> MetricsRegistry:
        """The process-global metrics registry with this client's collectors.

        The cache collector is bound at construction; the gateway collector
        on first gateway use. ``metrics().snapshot()`` /
        ``metrics().to_prometheus()`` then report identical shapes under
        both ``REPRO_RUNTIME`` control planes.
        """
        return _global_metrics()

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Stop the client-owned gateway (idempotent; prebuilt ones are not)."""
        self._closed = True
        for name in self._collectors:
            _global_metrics().unregister_collector(name)
        self._collectors.clear()
        if self._owns_gateway and self._gateway is not None:
            self._gateway.stop()
            self._gateway = None
            self._owns_gateway = False

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- internals -----------------------------------------------------------
    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("Client is closed")

    def _bind_collector(self, kind: str, fn: Any) -> None:
        """Register ``fn`` under a name unique to this client instance."""
        name = f"client{id(self)}.{kind}"
        _global_metrics().register_collector(name, fn)
        self._collectors.append(name)

    def gateway(self) -> Optional[Any]:
        """The live gateway (started on first use); None for local clients."""
        if self._gateway is None and self._workers is not None:
            if self.shards > 1:
                from repro.core.aio import ShardedGateway

                self._gateway = ShardedGateway(
                    self._workers, shards=self.shards, **self._gateway_options
                )
            else:
                self._gateway = Gateway(self._workers, **self._gateway_options)
            self._gateway.start()
            self._owns_gateway = True
            if hasattr(self._gateway, "stats"):
                self._bind_collector("gateway", gateway_collector(self._gateway))
        return self._gateway

    def _executor(self, journal: Journal) -> Any:
        gw = self.gateway()
        if gw is not None:
            return ClusterExecutor(gw, journal=journal, cache=self.cache)
        return LocalExecutor(
            max_workers=self.max_workers, journal=journal, cache=self.cache
        )

    def _workflow_runner(self) -> WorkflowRunner:
        if self._runner is None:
            factory = None
            if self._workers is not None or self._gateway is not None:

                def factory(**kw: Any) -> ClusterExecutor:
                    return ClusterExecutor(self.gateway(), **kw)

            self._runner = WorkflowRunner(
                self.workflows,
                os.path.join(self.base_dir, "workflows"),
                executor_factory=factory,
                journal_sync=self.journal_sync,
                max_workers=self.max_workers,
                cache=self.cache,
            )
        return self._runner
