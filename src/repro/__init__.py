"""SerPyTor reproduction: context-aware durable graph execution on JAX.

Kept intentionally light: importing ``repro`` must not pull in jax or any
optional dependency (tests/test_wire.py asserts the import works on a bare
stdlib+msgpack environment). Heavy subsystems load on attribute access.
"""
from importlib import import_module
from typing import Any

__version__ = "0.2.0"

_SUBMODULES = ("core", "wire", "checkpoint", "data", "serve", "models",
               "kernels", "train", "configs", "launch", "optim", "sharding")

__all__ = ["__version__", *_SUBMODULES]


def __getattr__(name: str) -> Any:
    if name in _SUBMODULES:
        return import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
