"""SerPyTor reproduction: context-aware durable graph execution on JAX.

Kept intentionally light: importing ``repro`` must not pull in jax or any
optional dependency (tests/test_wire.py asserts the import works on a bare
stdlib+msgpack environment). Heavy subsystems load on attribute access.

The supported entry point is :class:`repro.Client` (see docs/migration-v2.md);
the historical constructors remain importable from their subpackages, and the
top-level aliases below resolve but emit ``DeprecationWarning``.
"""
import warnings
from importlib import import_module
from typing import Any

__version__ = "0.3.0"

_SUBMODULES = ("core", "wire", "checkpoint", "data", "serve", "models",
               "kernels", "train", "configs", "launch", "optim", "sharding",
               "cache", "stream", "workflow", "obs")

#: lazily-resolved first-class exports: attr -> (module, attr)
_EXPORTS = {
    "Client": ("repro.client", "Client"),
    "WorkflowHandle": ("repro.client", "WorkflowHandle"),
}

#: pre-Client entry points kept as aliases: attr -> (module, attr, hint)
_DEPRECATED = {
    "DurableExecutor": ("repro.core.executor", "LocalExecutor",
                        "repro.Client(base_dir).run(graph)"),
    "LocalExecutor": ("repro.core.executor", "LocalExecutor",
                      "repro.Client(base_dir).run(graph)"),
    "ClusterExecutor": ("repro.core.executor", "ClusterExecutor",
                        "repro.Client(base_dir, cluster=workers).run(graph)"),
    "WorkflowRunner": ("repro.workflow.api", "WorkflowRunner",
                       "repro.Client(base_dir).workflow(name)"),
    "Trainer": ("repro.train.trainer", "Trainer",
                "repro.Client(base_dir).train(trainer)"),
    "DistributedTrainer": ("repro.train.distributed", "DistributedTrainer",
                           "repro.Client(base_dir).train(trainer)"),
}

__all__ = ["__version__", "Client", "WorkflowHandle", *_SUBMODULES]


def __getattr__(name: str) -> Any:
    if name in _SUBMODULES:
        return import_module(f"{__name__}.{name}")
    if name in _EXPORTS:
        module, attr = _EXPORTS[name]
        return getattr(import_module(module), attr)
    if name in _DEPRECATED:
        module, attr, hint = _DEPRECATED[name]
        warnings.warn(
            f"repro.{name} is deprecated; use {hint} (docs/migration-v2.md)",
            DeprecationWarning,
            stacklevel=2,
        )
        return getattr(import_module(module), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
