"""Distributed data-parallel training on the cluster substrate.

Every training step expands into a small cluster graph routed through the
Gateway (the SparkNet shape: deep-network training AS distributed dataflow):

    apply@s-1 ──► sync@s ──► grad@s#0 ─┐
                      │      grad@s#1 ─┼──► reduce@s ──► apply@s ──► ...
                      │      ...       │
                      └────► grad@s#N ─┘           └──► ckpt@e (round end)

  - ``sync@s``   publishes the current params (digest-precomputed via
                 :class:`~repro.wire.Digested` so N consumers hash O(1));
  - ``grad@s#k`` is a *named registry task* (``"grad_shard"``) dispatched to
                 a gateway worker: it regenerates shard k of the global batch
                 deterministically (batch = f(seed, step, shard)) and returns
                 that shard's gradients;
  - ``reduce@s`` folds the shard gradients into their mean, in fixed shard
                 order (bit-deterministic regardless of which worker computed
                 which shard);
  - ``apply@s``  runs the optimizer update, verifies the step's metric digest
                 against the journal BEFORE committing the mutated state, and
                 journals the step metrics (the replay oracle).

Durability is the trainer contract (docs/training.md): tensor-bearing nodes
(sync/grad/reduce) are *volatile* — their commits carry only digests, never
tensors, and recovery re-executes them from the restored snapshot. Fault
tolerance is inherited from the substrate:

  - a worker evicted mid-round (heartbeat loss, transport failure) has its
    in-flight shard tasks requeued on survivors by the gateway — the round
    completes with identical gradients because ``grad_shard`` is a pure
    function of (params, step, shard), not of the worker;
  - a killed *run* resumes from journal + snapshot: restore the newest
    complete checkpoint pair, re-execute the steps after it, and verify each
    re-executed step's digest against the journal (hard error on divergence).

In this container the workers are in-process (``InProcWorker``); on real
hardware each worker is a ``WorkerServer`` on its own host/accelerator and
the same graph routes over HTTP — the wire codec ships ndarray payloads
losslessly (msgpack ExtType frames).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ClusterExecutor, ContextGraph, Gateway, InProcWorker, TaskRegistry
from repro.data.pipeline import DataConfig, TokenSource
from repro.optim.adamw import adamw_update
from repro.wire import Digested, payload_digest

from .trainer import TrainConfig, Trainer

__all__ = ["DistTrainConfig", "DistributedTrainer", "build_grad_registry"]


@dataclass
class DistTrainConfig(TrainConfig):
    """Trainer config plus the data-parallel topology knobs."""

    num_shards: int = 4  # gradient shards per step (global_batch must divide)
    num_workers: int = 4  # default in-proc worker pool size
    heartbeat_interval_s: float = 0.1  # gateway probe cadence (eviction speed)
    speculative: bool = False  # straggler duplicates are off for uniform shards


def build_grad_registry(model: Any, data_cfg: DataConfig) -> TaskRegistry:
    """Registry exposing the tensor-bearing ``grad_shard`` task.

    The task contract: inputs carry ``sync = {"step", "params"}`` (injected
    from the round graph's sync node); the *context* carries Ψ facts
    ``shard`` / ``num_shards`` — the shard identity is context, not payload,
    so the same submitted request is cheap to requeue on any worker. The
    shard batch is regenerated locally from (seed, step, shard): workers
    never ship training data, only gradients.

    A real deployment calls this on each worker host to register the task
    with its :class:`~repro.core.WorkerServer`; in-proc workers share one
    registry instance (and its jit cache).
    """
    registry = TaskRegistry()

    def grad_fn(params, batch):
        (loss, _metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        return loss, grads

    jgrad = jax.jit(grad_fn)
    sources: Dict[Tuple[int, int], TokenSource] = {}
    lock = threading.Lock()

    @registry.task("grad_shard")
    def grad_shard(ctx, sync):
        shard = int(ctx.get("shard"))
        num_shards = int(ctx.get("num_shards"))
        step = int(sync["step"])
        with lock:
            src = sources.get((num_shards, shard))
            if src is None:
                src = TokenSource(
                    dataclasses.replace(
                        data_cfg, num_hosts=num_shards, host_index=shard
                    )
                )
                sources[(num_shards, shard)] = src
        batch = src.batch_at(step)  # deterministic: f(seed, step, shard)
        jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, grads = jgrad(sync["params"], jbatch)
        # plain tensors, no Digested wrapper: worker results must journal
        # under transport-independent digests, and an HTTP transport would
        # strip the wrapper anyway (a digest hint only helps on values that
        # stay executor-side — the sync/reduce nodes)
        return {
            "shard": shard,
            "loss": float(loss),
            "grads": jax.device_get(grads),
        }

    return registry


def _mean_pytrees(trees: Sequence[Any]) -> Any:
    """Leaf-wise mean in *list order* — bit-deterministic shard aggregation."""
    n = len(trees)

    def mean_leaf(*leaves):
        acc = np.asarray(leaves[0], dtype=np.float32).copy()
        for leaf in leaves[1:]:
            acc += np.asarray(leaf, dtype=np.float32)
        return (acc / n).astype(np.asarray(leaves[0]).dtype)

    return jax.tree.map(mean_leaf, *trees)


class DistributedTrainer(Trainer):
    """Data-parallel :class:`Trainer` running rounds through the Gateway.

    Inherits the whole durable-round machinery (journal scan, recovery from
    the newest complete checkpoint pair, metric collection, summary) and
    overrides exactly two seams: the round graph (data-parallel expansion)
    and the executor scope (a gateway-backed :class:`ClusterExecutor`).
    """

    step_node_prefix = "apply@"

    def __init__(
        self,
        cfg: Any,
        tc: DistTrainConfig,
        workers: Optional[List[Any]] = None,
    ):
        super().__init__(cfg, tc)
        if tc.global_batch % tc.num_shards:
            raise ValueError(
                f"global_batch={tc.global_batch} must divide across "
                f"num_shards={tc.num_shards}"
            )
        self.registry = build_grad_registry(self.model, self.data_cfg)
        # each default worker models ONE accelerator host: capacity 1 —
        # the gateway may hand it several shard requests, it executes them
        # one at a time (parallelism comes from more workers, not threads)
        self.workers = workers if workers is not None else [
            InProcWorker(f"w{i}", self.registry, max_concurrency=1)
            for i in range(tc.num_workers)
        ]
        self.gateway: Optional[Gateway] = None  # live only inside train()
        self._japply = jax.jit(
            lambda params, opt, grads: adamw_update(params, grads, opt, tc.opt)
        )

    # -- executor seam ------------------------------------------------------
    @contextlib.contextmanager
    def _executor_scope(self) -> Iterator[Any]:
        """Start the gateway for the run; yield a cluster executor on it."""
        tc: DistTrainConfig = self.tc
        self.gateway = Gateway(
            self.workers,
            heartbeat_interval_s=tc.heartbeat_interval_s,
            name="train-gateway",
        )
        self.gateway.start()
        try:
            yield ClusterExecutor(
                self.gateway,
                journal=self.journal,
                speculative=tc.speculative,
            )
        finally:
            self.gateway.stop()
            self.gateway = None

    # -- the data-parallel round graph --------------------------------------
    def _round_graph(
        self,
        start: int,
        end: int,
        state: Dict[str, Any],
        replay_digests: Dict[int, str],
        incarnation: int = 0,
    ) -> ContextGraph:
        """K steps, each fanned out over ``num_shards`` gradient tasks.

        Volatile nodes (sync/grad/reduce) re-execute on recovery; the apply
        node is the stateful one — it carries the incarnation nonce in Ψ
        (same contract as the local trainer's step nodes), verifies its
        metric digest against the journal, and only then swaps the state.
        """
        g = ContextGraph(origin=self.run_context(), name=f"round{start}")
        num_shards: int = self.tc.num_shards
        prev_apply = None
        for s in range(start, end):
            sync_id, reduce_id = f"sync@{s}", f"reduce@{s}"
            apply_id = f"apply@{s}"

            def sync(ctx, _s=s, **deps):
                # publish the live params once per step; Digested makes the
                # N shard consumers (and the commit) hash it in O(1)
                return {
                    "step": _s,
                    "params": Digested.wrap(jax.device_get(state["params"])),
                }

            g.add(
                sync_id,
                sync,
                deps=[prev_apply] if prev_apply else [],
                volatile=True,
                retries=0,
            )

            grad_ids = []
            for k in range(num_shards):
                gid = f"grad@{s}#{k}"
                g.add(
                    gid,
                    "grad_shard",
                    deps=[sync_id],
                    aliases={sync_id: "sync"},
                    data={"shard": k, "num_shards": num_shards},
                    volatile=True,
                )
                grad_ids.append(gid)

            shard_order = tuple(grad_ids)

            def reduce_(ctx, _ids=shard_order, **deps):
                shards = [deps[i] for i in _ids]  # fixed shard order
                grads = _mean_pytrees([sh["grads"] for sh in shards])
                loss = float(sum(sh["loss"] for sh in shards) / len(shards))
                return {"grads": Digested.wrap(grads), "loss": loss}

            g.add(reduce_id, reduce_, deps=grad_ids, volatile=True, retries=0)

            def apply_(ctx, _s=s, _rid=reduce_id, **deps):
                red = deps[_rid]
                want = replay_digests.get(_s)
                # compute-then-verify-then-swap: the optimizer update is
                # non-donating, so a digest mismatch leaves the restored
                # state exactly as the snapshot left it
                new_params, new_opt, metrics = self._japply(
                    state["params"], state["opt"], red["grads"]
                )
                out = {
                    "step": _s,
                    "loss": red["loss"],
                    "grad_norm": float(metrics["grad_norm"]),
                    "lr": float(metrics["lr"]),
                }
                got = payload_digest(out)
                if want is not None and want != got:
                    raise RuntimeError(
                        f"non-deterministic replay at step {_s}: "
                        f"journal={want} recomputed={got}"
                    )
                state["params"], state["opt"] = new_params, new_opt
                return out

            g.add(
                apply_id,
                apply_,
                deps=[reduce_id],
                data={"incarnation": incarnation},
                retries=0,
            )
            prev_apply = apply_id

        self._add_checkpoint_node(g, state, prev_apply, end)
        return g
