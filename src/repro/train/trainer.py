"""Trainer: the training loop AS a SerPyTor durable context-graph.

Every training round (K steps + checkpoint) is a ContextGraph of atomic
tasks — data_fetch → train_step → metrics, with a checkpoint node closing
the round. The run context ξ carries (run_id, config digest, mesh digest,
data-shard cursor, RNG lineage); every node commit lands in the journal.

Durability semantics (event sourcing + snapshots, §4.2):
  - the journal is the event history; the CheckpointStore holds snapshots,
    referenced from CKPT records (never tensors in the journal);
  - recovery = restore latest snapshot, then REPLAY the steps after it:
    deterministic data (batch = f(seed, step)) + explicit RNG lineage make
    re-execution bit-identical, and committed step records let the trainer
    VERIFY determinism (digest equality) while replaying;
  - a replayed step whose digest disagrees with the journal is surfaced as
    a hard error — silent divergence is the failure mode durable execution
    exists to kill.

Fault tolerance beyond restart: heartbeat server (system/application error
split for external monitors), straggler watch on host-side tasks, elastic
re-mesh on device-count change at recovery time.
"""

from __future__ import annotations

import contextlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import ModelConfig
from repro.core import (
    Context,
    ContextGraph,
    HeartbeatServer,
    Journal,
    JournalRecord,
    LocalExecutor,
    StragglerWatch,
    WithContext,
)
from repro.obs.metrics import metrics as obs_metrics
from repro.wire import canonical_digest, payload_digest
from repro.data.pipeline import DataConfig, TokenSource
from repro.models import build
from repro.optim.adamw import AdamWConfig
from repro.sharding.specs import ShardingOptions, ShardingRules
from .steps import make_train_step

__all__ = ["TrainConfig", "Trainer"]


@dataclass
class TrainConfig:
    run_dir: str
    num_steps: int = 100
    checkpoint_every: int = 25
    log_every: int = 10
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 256
    journal_sync: str = "batch"  # always (paper-strict) | batch | never
    async_checkpoint: bool = True
    heartbeat: bool = True
    mesh_model_axis: int = 1
    opt: AdamWConfig = field(default_factory=AdamWConfig)


class Trainer:
    #: node-id prefix of the per-step metric commits this trainer journals;
    #: the replay-digest scan and the metrics collector both key off it
    step_node_prefix = "step@"

    def __init__(self, cfg: ModelConfig, tc: TrainConfig):
        self.cfg = cfg
        self.tc = tc
        os.makedirs(tc.run_dir, exist_ok=True)
        self.model = build(cfg)
        self.store = CheckpointStore(os.path.join(tc.run_dir, "ckpt"))
        self.journal = Journal(os.path.join(tc.run_dir, "journal.wal"), sync=tc.journal_sync)
        self.heartbeat = HeartbeatServer(extra={"worker": "trainer"}) if tc.heartbeat else None
        self.stragglers = StragglerWatch()
        self.data_cfg = DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=tc.seq_len,
            global_batch=tc.global_batch,
            seed=tc.seed,
        )
        self.source = TokenSource(self.data_cfg)
        # elastic mesh: data axis = current device count / model axis
        n = len(jax.devices())
        model_ax = min(tc.mesh_model_axis, n)
        self.mesh = jax.make_mesh((max(1, n // model_ax), model_ax), ("data", "model"))
        self.rules = ShardingRules(cfg, self.mesh, ShardingOptions())
        # The fresh-execution step donates params/opt buffers (in-place
        # update memory profile). The VERIFY twin does not: a replayed step
        # must be able to fail its digest check and leave the restored state
        # untouched — donation would have already consumed it.
        self._train_step = jax.jit(make_train_step(self.model, tc.opt), donate_argnums=(0, 1))
        self._train_step_verify = jax.jit(make_train_step(self.model, tc.opt))
        # steps whose device buffers were donated this incarnation: a second
        # execution would read freed buffers, so it is refused outright
        self._donated_steps: set = set()
        self.metrics_log: list = []

    # -- run identity --------------------------------------------------------
    def run_context(self) -> Context:
        mesh_desc = {
            a: int(s) for a, s in zip(self.mesh.axis_names, self.mesh.devices.shape, strict=True)
        }
        return Context.origin(
            {
                "run_id": canonical_digest({"cfg": self.cfg.name, "seed": self.tc.seed}),
                "config_digest": canonical_digest(repr(self.cfg)),
                "mesh": mesh_desc,
                "data_seed": self.tc.seed,
            },
            origin="trainer",
        )

    # -- recovery ------------------------------------------------------------
    def recover(self) -> Tuple[int, Any, Any]:
        """(start_step, params, opt_state) — from snapshot or fresh init.

        Only *complete* checkpoint pairs count: the params save is sync but
        the ``-opt`` companion may be async, so a crash can publish the base
        tag without its optimizer shard. Recovery falls back to the newest
        pair whose companion exists instead of failing on the missing shard.

        Both shards restore through the digest-verified ``resolve()`` path:
        on-disk corruption or tampering that preserves shapes aborts
        recovery loudly instead of silently training onward from bad state.
        """
        tag = self.store.latest(companions=("-opt",))
        params, axes = None, None
        if tag is not None:
            man = self.store.manifest(tag)
            start = int(man["meta"]["next_step"])
            like_p = jax.eval_shape(lambda r: self.model.init(r)[0], jax.random.key(self.tc.seed))
            like_p = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), like_p)
            params = self.store.resolve(f"{tag}@{man['digest']}", like_p)
            params = jax.tree.map(jnp.asarray, params)
            from repro.optim.adamw import adamw_init

            like_o = adamw_init(params, self.tc.opt)
            man_o = self.store.manifest(tag + "-opt")
            opt_state = self.store.resolve(f"{tag}-opt@{man_o['digest']}", like_o)
            opt_state = jax.tree.map(jnp.asarray, opt_state)
            return start, params, opt_state
        params, _ = self.model.init(jax.random.key(self.tc.seed))
        from repro.optim.adamw import adamw_init

        opt_state = adamw_init(params, self.tc.opt)
        return 0, params, opt_state

    # -- one durable round (K steps + checkpoint) ------------------------------
    def _round_graph(
        self,
        start: int,
        end: int,
        state: Dict[str, Any],
        replay_digests: Dict[int, str],
        incarnation: int = 0,
    ) -> ContextGraph:
        """Step nodes are STATEFUL (they advance params held by reference),
        so they must never be replay-SKIPPED across process incarnations —
        the state side effect would be lost. Their Ψ therefore carries the
        incarnation nonce: recovery re-executes them from the restored
        snapshot and VERIFIES the journal digests instead (event sourcing
        with snapshots). Pure nodes (data fetch) replay normally."""
        g = ContextGraph(origin=self.run_context(), name=f"round{start}")
        prev = None
        for s in range(start, end):
            fetch_id, step_id = f"data@{s}", f"step@{s}"

            def fetch(ctx, _s=s):
                self.stragglers.started("data_fetch", _s)
                batch = self.source.batch_at(_s)
                self.stragglers.finished("data_fetch", _s)
                return {"step": _s, "digest": payload_digest(batch)}

            g.add(fetch_id, fetch, data={"step": s})

            def run_step(ctx, _s=s, _fid=fetch_id, **deps):
                meta = deps[_fid]
                batch = self.source.batch_at(_s)  # DI: regenerate (pure fn)
                jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
                want = replay_digests.get(_s)
                if _s in self._donated_steps:
                    # the donating step already consumed this state's device
                    # buffers; a re-execution would read freed memory. This
                    # is unreachable via the executor (step nodes carry
                    # retries=0) and exists to make the hazard loud if a
                    # future caller re-runs a round graph by hand.
                    raise RuntimeError(
                        f"step {_s} already donated its input buffers; "
                        "re-executing it is unsafe (restore a snapshot and "
                        "build a fresh round graph instead)"
                    )
                if want is None:
                    # fresh execution: donation is safe — nothing can demand
                    # the pre-step state after this commit
                    self._donated_steps.add(_s)
                    step_fn = self._train_step
                else:
                    # replay-verification: run the NON-donating twin so a
                    # digest mismatch leaves the restored state intact
                    step_fn = self._train_step_verify
                new_params, new_opt, metrics = step_fn(state["params"], state["opt"], jbatch)
                out = {k: float(v) for k, v in metrics.items()}
                out["step"] = _s
                out["data_digest"] = meta["digest"]
                got = payload_digest(out)
                if want is not None and want != got:
                    raise RuntimeError(
                        f"non-deterministic replay at step {_s}: "
                        f"journal={want} recomputed={got}"
                    )
                # verified (or fresh): only now does the mutation commit
                state["params"], state["opt"] = new_params, new_opt
                return out

            deps = [fetch_id] + ([prev] if prev else [])
            g.add(step_id, run_step, deps=deps, data={"incarnation": incarnation}, retries=0)
            prev = step_id

        self._add_checkpoint_node(g, state, prev, end)
        return g

    def _add_checkpoint_node(
        self, g: ContextGraph, state: Dict[str, Any], prev: str, end: int
    ) -> None:
        """Append the round-closing checkpoint node (snapshot + CKPT record).

        The params save is synchronous; the ``-opt`` companion may be async
        (off the critical path). Recovery tolerates a torn pair — see
        :meth:`recover` and docs/training.md §5.
        """

        def checkpoint(ctx, **deps):
            last = deps[prev]
            next_step = last["step"] + 1
            tag = f"step{next_step:08d}"
            ref_p = self.store.save(
                tag, jax.device_get(state["params"]), {"next_step": next_step}, async_=False
            )
            ref_o = self.store.save(
                tag + "-opt",
                jax.device_get(state["opt"]),
                {"next_step": next_step},
                async_=self.tc.async_checkpoint,
            )
            self.journal.append(
                JournalRecord(
                    kind="CKPT", node_id=tag, ref=f"{ref_p};{ref_o}", meta={"next_step": next_step}
                )
            )
            return WithContext({"ref": ref_p, "next_step": next_step}, {"last_ckpt": ref_p})

        g.add(f"ckpt@{end}", checkpoint, deps=[prev])

    # -- shared machinery (the distributed trainer reuses all of it) --------------
    def _scan_journal(self) -> Tuple[Dict[int, str], int]:
        """(replay_digests, incarnation) from previous runs of this journal.

        ``replay_digests[step]`` is the metric-payload digest a previous
        incarnation committed for that step: the determinism oracle the
        re-executed step must match. The incarnation count salts stateful
        nodes' Ψ so they re-execute instead of replay-skipping.
        """
        replay_digests: Dict[int, str] = {}
        incarnation = 0
        if os.path.exists(self.journal.path):
            prefix = self.step_node_prefix
            for rec in self.journal.records():
                if rec.kind == "RUN_START":
                    incarnation += 1
                if rec.kind == "NODE_COMMIT" and rec.node_id.startswith(prefix):
                    if isinstance(rec.payload, dict) and "step" in rec.payload:
                        replay_digests[int(rec.payload["step"])] = rec.output_digest
        return replay_digests, incarnation

    @contextlib.contextmanager
    def _executor_scope(self) -> Iterator[Any]:
        """Yield the executor this trainer runs rounds on (local here)."""
        yield LocalExecutor(max_workers=4, journal=self.journal)

    def _collect_metrics(self, report) -> None:
        """Pull this round's step metrics out of a report, in step order.

        Besides the local ``metrics_log`` (summary.json), each round also
        feeds the process-global :mod:`repro.obs.metrics` registry so
        trainer progress shows up in the same snapshot as gateway/cache
        stats.
        """
        metrics = [
            report.outputs[n] for n in report.outputs if n.startswith(self.step_node_prefix)
        ]
        for m in sorted(metrics, key=lambda r: r["step"]):
            self.metrics_log.append(m)
            if m["step"] % self.tc.log_every == 0:
                print(
                    f"step {m['step']:5d} loss {m['loss']:.4f} "
                    f"gnorm {m['grad_norm']:.3f} "
                    f"lr {m['lr']:.2e}",
                    flush=True,
                )
        if metrics:
            reg = obs_metrics()
            reg.counter("repro_train_steps_total").inc(len(metrics))
            last = max(metrics, key=lambda m: m["step"])
            reg.gauge("repro_train_step").set(float(last["step"]))
            reg.gauge("repro_train_loss").set(float(last["loss"]))
            reg.gauge("repro_train_grad_norm").set(float(last["grad_norm"]))
            reg.gauge("repro_train_lr").set(float(last["lr"]))

    # -- main loop ----------------------------------------------------------------
    def train(self) -> Dict[str, Any]:
        if self.heartbeat:
            self.heartbeat.start()
        t0 = time.monotonic()  # wall_s is a duration: clock steps must not skew it
        # replay digests from previous incarnations (determinism check) +
        # incarnation nonce (see _round_graph docstring)
        replay_digests, incarnation = self._scan_journal()

        start, params, opt_state = self.recover()
        state = {"params": params, "opt": opt_state}
        self.rules.install()
        try:
            with self._executor_scope() as executor, self.mesh:
                s = start
                while s < self.tc.num_steps:
                    e = min(s + self.tc.checkpoint_every, self.tc.num_steps)
                    graph = self._round_graph(s, e, state, replay_digests, incarnation=incarnation)
                    report = executor.run(graph)
                    self._collect_metrics(report)
                    s = e
        finally:
            self.rules.uninstall()
            self.store.wait()
            self.journal.flush()
            if self.heartbeat:
                self.heartbeat.stop()
        wall = time.monotonic() - t0
        out = {
            "steps": self.tc.num_steps - start,
            "wall_s": wall,
            "final_loss": self.metrics_log[-1]["loss"] if self.metrics_log else None,
            "steps_per_s": (self.tc.num_steps - start) / max(wall, 1e-9),
        }
        with open(os.path.join(self.tc.run_dir, "summary.json"), "w") as fh:
            json.dump({**out, "log": self.metrics_log}, fh, indent=1)
        return out
