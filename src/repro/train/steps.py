"""Step functions: the atomic units the dry-run lowers and the trainer runs.

``make_train_step``: fwd + bwd + clip + AdamW, donating params/opt state.
``make_prefill_step`` / ``make_decode_step``: the serving pair.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from repro.models import Model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step", "make_opt_init"]


def make_opt_init(model: Model, opt_cfg: AdamWConfig):
    def opt_init(params):
        return adamw_init(params, opt_cfg)

    return opt_init


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch) -> Tuple[Any, Any, Dict[str, jax.Array]]:
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)
        new_params, new_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return decode_step
