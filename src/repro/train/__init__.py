"""Training stack: durable trainer loops and their atomic step functions.

``Trainer`` runs single-process durable rounds on a ``LocalExecutor``;
``DistributedTrainer`` expands each step into a data-parallel cluster graph
routed through the Gateway (see docs/training.md).
"""

from .distributed import DistTrainConfig, DistributedTrainer, build_grad_registry
from .steps import make_decode_step, make_opt_init, make_prefill_step, make_train_step
from .trainer import TrainConfig, Trainer

__all__ = [
    "TrainConfig",
    "Trainer",
    "DistTrainConfig",
    "DistributedTrainer",
    "build_grad_registry",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "make_opt_init",
]
