"""Workflow registry and durable per-workflow store.

A *workflow* is a named graph factory: ``factory(args) -> ContextGraph``.
The factory must rebuild the same graph for the same ``args`` in every
process incarnation — resume and fork re-create the graph from the factory
and rely on structural fn digests plus the journal to skip committed work.

The store owns the on-disk layout::

    <base_dir>/
      .cache/                 shared cross-run ResultCache (all workflows)
      <workflow_id>/
        journal.wal           the workflow's durable journal
        meta.json             {"workflow", "args", "status", "parent", ...}

``meta.json`` is published atomically (tmp + rename) so a concurrent reader
never sees a torn document.
"""

from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, List, Mapping, Optional

from repro.cache import atomic_write_bytes
from repro.core.graph import ContextGraph

__all__ = ["WorkflowRegistry", "WorkflowStore"]

GraphFactory = Callable[[Optional[Mapping[str, Any]]], ContextGraph]


class WorkflowRegistry:
    """name → graph factory. Weakly opinionated: any callable registers."""

    def __init__(self) -> None:
        self._factories: Dict[str, GraphFactory] = {}

    def register(self, name: str, factory: GraphFactory) -> None:
        """Register ``factory`` under ``name`` (last registration wins)."""
        self._factories[name] = factory

    def define(self, name: str):
        """Decorator form of :meth:`register`: ``@registry.define("order")``."""

        def wrap(factory: GraphFactory) -> GraphFactory:
            self.register(name, factory)
            return factory

        return wrap

    def get(self, name: str) -> GraphFactory:
        """The factory registered under ``name``; KeyError if unknown."""
        if name not in self._factories:
            raise KeyError(f"unknown workflow {name!r}")
        return self._factories[name]

    def names(self) -> List[str]:
        """Sorted names of every registered workflow."""
        return sorted(self._factories)


class WorkflowStore:
    """Filesystem layout + atomic meta.json bookkeeping for workflows."""

    def __init__(self, base_dir: str) -> None:
        self.base_dir = base_dir
        os.makedirs(base_dir, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def dir_for(self, workflow_id: str) -> str:
        """The workflow's own directory under the store root."""
        return os.path.join(self.base_dir, workflow_id)

    def journal_path(self, workflow_id: str) -> str:
        """Path of the workflow's durable journal."""
        return os.path.join(self.dir_for(workflow_id), "journal.wal")

    def meta_path(self, workflow_id: str) -> str:
        """Path of the workflow's meta.json document."""
        return os.path.join(self.dir_for(workflow_id), "meta.json")

    def cache_root(self) -> str:
        """Root of the ResultCache shared by every workflow in this store."""
        return os.path.join(self.base_dir, ".cache")

    # -- meta bookkeeping ----------------------------------------------------
    def exists(self, workflow_id: str) -> bool:
        """True iff the workflow has been created in this store."""
        return os.path.exists(self.meta_path(workflow_id))

    def create(self, workflow_id: str, meta: Mapping[str, Any]) -> None:
        """Create the workflow directory and publish its initial meta."""
        os.makedirs(self.dir_for(workflow_id), exist_ok=True)
        self._write_meta(workflow_id, dict(meta))

    def meta(self, workflow_id: str) -> Dict[str, Any]:
        """The workflow's current meta document; KeyError if unknown."""
        path = self.meta_path(workflow_id)
        if not os.path.exists(path):
            raise KeyError(f"unknown workflow_id {workflow_id!r}")
        with open(path, "rb") as fh:
            return json.loads(fh.read().decode("utf-8"))

    def update(self, workflow_id: str, **fields: Any) -> Dict[str, Any]:
        """Merge ``fields`` into the meta document and republish it."""
        meta = self.meta(workflow_id)
        meta.update(fields)
        self._write_meta(workflow_id, meta)
        return meta

    def list(self) -> List[str]:
        """Sorted ids of every workflow in the store."""
        out = []
        for name in os.listdir(self.base_dir):
            if os.path.exists(self.meta_path(name)):
                out.append(name)
        return sorted(out)

    def _write_meta(self, workflow_id: str, meta: Mapping[str, Any]) -> None:
        body = json.dumps(meta, indent=2, sort_keys=True, default=str)
        atomic_write_bytes(self.meta_path(workflow_id), body.encode("utf-8"))
