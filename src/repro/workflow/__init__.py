"""repro.workflow — interactive durability on top of the journal stack.

Named interrupt points suspend a run (clean drain + journaled ``SUSPEND``);
``resume(workflow_id, inputs=...)`` answers the interrupt durably and
continues from the suspended frontier with the committed prefix replayed
for free; ``fork(workflow_id, at=...)`` branches a child workflow whose
shared history is served by the content-addressed cache.

Usage::

    from repro.workflow import WorkflowRegistry, WorkflowRunner
    from repro.core import interrupt

    registry = WorkflowRegistry()

    @registry.define("order")
    def order(args):
        g = ContextGraph()
        g.add("total", compute_total)
        g.add("approved", lambda ctx, total: interrupt(ctx, "approve"),
              deps=["total"], interrupt="approve")
        g.add("ship", ship_it, deps=["approved"])
        return g

    runner = WorkflowRunner(registry, "runs/workflows")
    res = runner.run("order")                 # → suspended at "approve"
    res = runner.resume(res.workflow_id,      # possibly days later,
                        inputs={"approve": True})  # in a fresh process

Semantics, journal record formats, and the fork/cache contract are
specified in docs/durable-workflows.md.
"""

from repro.core.durable import Interrupted, interrupt

from .api import WorkflowError, WorkflowNotSuspended, WorkflowResult, WorkflowRunner
from .registry import WorkflowRegistry, WorkflowStore

__all__ = [
    "Interrupted",
    "interrupt",
    "WorkflowError",
    "WorkflowNotSuspended",
    "WorkflowRegistry",
    "WorkflowResult",
    "WorkflowRunner",
    "WorkflowStore",
]
