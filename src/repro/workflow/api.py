"""Durable workflows: interrupt, suspend/resume, fork-from-checkpoint.

The workflow layer gives a graph a *durable identity* — a ``workflow_id``
that outlives any single run — on top of the journal/executor/cache stack:

- a node declared with ``interrupt="approve"`` calls
  ``repro.core.interrupt(ctx, "approve")``; when the fact is absent the run
  *suspends* (a clean drain + journaled ``SUSPEND``, not an error),
- ``resume(workflow_id, inputs={...})`` journals a ``RESUME`` carrying the
  answers, injects them as Ψ facts on the interrupted node, and re-runs:
  the committed prefix replays for free and execution continues from the
  suspended frontier,
- ``fork(workflow_id, at=record_seq)`` branches a child workflow that
  shares the parent's committed prefix through the content-addressed cache
  (post-``at`` cache entries are masked so divergent history re-executes).

Each incarnation of a workflow is a separate *run* (``RUN_START`` …) in the
same journal; the ``workflow_id`` lives in the journal's ``LINEAGE`` header
and in the store's ``meta.json``. See docs/durable-workflows.md.
"""

from __future__ import annotations

import time
import uuid
from dataclasses import dataclass
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

from repro.cache import ResultCache
from repro.core.durable import Journal, JournalRecord
from repro.core.executor import ExecutionReport, LocalExecutor
from repro.core.graph import ContextGraph
from repro.journal.compact import CompactedHistoryError
from repro.obs.trace import get_tracer

from .registry import WorkflowRegistry, WorkflowStore

__all__ = [
    "WorkflowError",
    "WorkflowInterruptTimeout",
    "WorkflowNotSuspended",
    "WorkflowResult",
    "WorkflowRunner",
]


class WorkflowError(RuntimeError):
    """Typed failure from the workflow layer (unknown id, bad fork, ...)."""


class WorkflowNotSuspended(WorkflowError):
    """``resume(inputs=...)`` on a workflow with no suspended interrupt."""


class WorkflowInterruptTimeout(WorkflowError):
    """An ``on_timeout="escalate"`` interrupt expired without an answer.

    Raised by :meth:`WorkflowRunner.resume` when the pending interrupt's
    journaled deadline has passed, no explicit ``inputs`` were provided, and
    the node's policy is to escalate rather than answer itself. The workflow
    is marked ``status="escalated"`` in the store; a later ``resume`` with
    explicit ``inputs`` still works (human answers always win).
    """


@dataclass
class WorkflowResult:
    """Outcome of one workflow incarnation (run / resume / fork)."""

    workflow_id: str
    status: str  # "completed" | "suspended"
    report: ExecutionReport
    interrupt: str = ""  # set when suspended: the interrupt's name
    node: str = ""  # set when suspended: the node that raised it

    @property
    def suspended(self) -> bool:
        """True iff this incarnation ended at an interrupt point."""
        return self.status == "suspended"

    @property
    def outputs(self) -> Dict[str, Any]:
        """The run's node outputs (partial when suspended)."""
        return self.report.outputs


class WorkflowRunner:
    """Run, resume, and fork named workflows against a durable store.

    ``executor_factory(journal=..., cache=...)`` lets callers swap in a
    :class:`~repro.core.ClusterExecutor` (or anything with the same ``run``
    surface); the default is a :class:`LocalExecutor`. All workflows of one
    runner share a single content-addressed ResultCache, which is what makes
    fork's shared-prefix reuse free.
    """

    def __init__(
        self,
        registry: WorkflowRegistry,
        base_dir: str,
        *,
        executor_factory: Optional[Callable[..., Any]] = None,
        journal_sync: str = "always",
        max_workers: int = 8,
        cache: Optional[ResultCache] = None,
    ):
        self.registry = registry
        self.store = WorkflowStore(base_dir)
        self.executor_factory = executor_factory
        self.journal_sync = journal_sync
        self.max_workers = max_workers
        self.cache = cache if cache is not None else ResultCache(self.store.cache_root())

    # -- public API ----------------------------------------------------------
    def run(
        self,
        workflow: str,
        args: Optional[Mapping[str, Any]] = None,
        workflow_id: Optional[str] = None,
    ) -> WorkflowResult:
        """Start a new durable workflow; returns when it completes or suspends."""
        wid = workflow_id or f"{workflow}-{uuid.uuid4().hex[:8]}"
        if self.store.exists(wid):
            raise WorkflowError(
                f"workflow_id {wid!r} already exists; use resume() to continue it"
            )
        self.store.create(
            wid,
            {
                "workflow": workflow,
                "args": dict(args) if args else None,
                "status": "running",
            },
        )
        graph = self._graph(workflow, args)
        with self._journal(wid, {"workflow_id": wid, "workflow": workflow}) as j:
            self._apply_resumes(graph, j)
            with get_tracer().span(
                f"workflow:{wid}", kind="workflow", attrs={"workflow": workflow}
            ):
                report = self._execute(graph, j, self.cache, wid)
        return self._finish(wid, report)

    def resume(
        self,
        workflow_id: str,
        inputs: Optional[Mapping[str, Any]] = None,
    ) -> WorkflowResult:
        """Continue a suspended (or crashed) workflow in-place.

        ``inputs`` answer the *latest* journaled interrupt: they are appended
        as a durable ``RESUME`` record and injected as Ψ facts on the
        interrupted node, so ``interrupt(ctx, name)`` finds them and the node
        proceeds. The committed prefix is replayed from the journal — zero
        re-execution. Without ``inputs`` the workflow simply re-runs (useful
        after a crash that lost no interrupt: it drains to the same suspend).

        Interrupts declared with ``interrupt_timeout_s`` carry an absolute
        ``deadline`` in their SUSPEND record. When that deadline has passed
        and no explicit ``inputs`` are given, the journaled ``on_timeout``
        policy decides: ``"default"`` self-answers with the node's declared
        default (journaled as an auto-RESUME, so replay is deterministic);
        ``"escalate"`` marks the workflow ``escalated`` and raises
        :class:`WorkflowInterruptTimeout`. Explicit ``inputs`` always win,
        even after the deadline.
        """
        meta = self.store.meta(workflow_id)
        graph = self._graph(meta["workflow"], meta.get("args"))
        with self._journal(workflow_id, None) as j:
            pending = self._pending_suspend(j)
            node = pending.node_id if pending is not None else None
            name = str(pending.meta.get("interrupt", "")) if pending is not None else ""
            if inputs:
                if node is None:
                    raise WorkflowNotSuspended(
                        f"workflow {workflow_id!r} has no journaled SUSPEND to answer"
                    )
                j.append(
                    JournalRecord(
                        kind="RESUME",
                        node_id=node,
                        meta={"interrupt": name, "inputs": dict(inputs)},
                    )
                )
                j.flush()
            elif pending is not None and self._expired(pending.meta):
                policy = str(pending.meta.get("on_timeout", ""))
                if policy == "default":
                    j.append(
                        JournalRecord(
                            kind="RESUME",
                            node_id=node,
                            meta={
                                "interrupt": name,
                                "inputs": {name: pending.meta.get("default")},
                                "auto": "timeout",
                            },
                        )
                    )
                    j.flush()
                elif policy == "escalate":
                    self.store.update(workflow_id, status="escalated")
                    raise WorkflowInterruptTimeout(
                        f"workflow {workflow_id!r} interrupt {name!r} on node "
                        f"{node!r} expired at deadline "
                        f"{pending.meta.get('deadline')}; escalation required"
                    )
            self._apply_resumes(graph, j)
            with get_tracer().span(
                f"workflow:{workflow_id}",
                kind="workflow",
                attrs={"workflow": str(meta["workflow"]), "resume": True},
            ):
                report = self._execute(graph, j, self.cache, workflow_id)
        return self._finish(workflow_id, report)

    def fork(
        self,
        workflow_id: str,
        at: Optional[int] = None,
        inputs: Optional[Mapping[str, Any]] = None,
        node: Optional[str] = None,
        fork_id: Optional[str] = None,
    ) -> WorkflowResult:
        """Branch a child workflow from a committed prefix of the parent.

        ``at`` is a *logical* record sequence number in the parent journal:
        history journaled *before* ``at`` is shared (served from the
        content-addressed cache — never re-executed); everything at or after
        ``at`` is masked from the cache so the child re-executes it.
        ``at=None`` shares the whole committed history. Logical seqs are
        stable across journal compaction — suffix records keep their
        original numbering — but seqs *below* the compacted journal's
        ``base_seq`` were folded away (only live state survives, not
        per-record identity), so addressing one raises a typed
        :class:`~repro.journal.CompactedHistoryError`. ``inputs`` (with
        ``node``, or defaulting to the parent's latest suspended node) seed
        the divergence as Ψ facts, journaled in the child as a ``RESUME`` so
        child re-runs are durable.
        """
        meta = self.store.meta(workflow_id)
        child = fork_id or f"{workflow_id}-fork-{uuid.uuid4().hex[:6]}"
        if self.store.exists(child):
            raise WorkflowError(f"fork target {child!r} already exists")
        with self._journal(workflow_id, None) as parent_j:
            indexed = list(parent_j.indexed_records())
            records = [rec for _seq, rec in indexed]
            suspend_node, _suspend_name = self._latest_suspend_from(records)
            # default divergence target: the latest interrupt decision point,
            # whether or not the parent already answered it
            decision_node = suspend_node
            for rec in records:
                if rec.kind == "SUSPEND":
                    decision_node = rec.node_id
            deny = set()
            if at is not None:
                base, end = parent_j.base_seq(), parent_j.end_seq()
                if 0 <= at < base:
                    raise CompactedHistoryError(
                        f"fork point at={at} was folded away by compaction "
                        f"(journal base_seq={base}); compacted history keeps "
                        "live state, not per-record branch points"
                    )
                if not base <= at <= end:
                    raise WorkflowError(
                        f"fork point at={at} outside journal ({base}..{end})"
                    )
                for seq, rec in indexed:
                    if seq is None or seq < at:
                        continue
                    if rec.kind in ("CACHE_STORE", "CACHE_HIT"):
                        key = rec.meta.get("key") or rec.meta.get("cache")
                        if key:
                            deny.add(key)
            parent_j.append(
                JournalRecord(kind="FORK", node_id=suspend_node or "", meta={"child": child, "at": at})
            )
            parent_j.flush()
        self.store.create(
            child,
            {
                "workflow": meta["workflow"],
                "args": meta.get("args"),
                "status": "running",
                "parent": workflow_id,
                "forked_at": at,
            },
        )
        graph = self._graph(meta["workflow"], meta.get("args"))
        lineage = {
            "workflow_id": child,
            "workflow": meta["workflow"],
            "parent": workflow_id,
            "forked_at": at,
        }
        with self._journal(child, lineage) as j:
            # carry the parent's pre-fork interrupt answers into the child
            # journal, so the child is self-contained for its own re-runs
            for seq, rec in indexed:
                if rec.kind != "RESUME":
                    continue
                # folded records (seq None) predate any addressable seq
                if at is not None and seq is not None and seq >= at:
                    continue
                j.append(
                    JournalRecord(kind="RESUME", node_id=rec.node_id, meta=dict(rec.meta))
                )
            if inputs:
                target = node or decision_node
                if target is None:
                    raise WorkflowError(
                        "fork(inputs=...) needs node= when the parent journal "
                        "has no interrupt decision point to target"
                    )
                if target not in graph.nodes:
                    raise WorkflowError(f"fork target node {target!r} not in graph")
                j.append(
                    JournalRecord(
                        kind="RESUME",
                        node_id=target,
                        meta={
                            "interrupt": graph.nodes[target].interrupt,
                            "inputs": dict(inputs),
                        },
                    )
                )
            j.flush()
            self._apply_resumes(graph, j)
            cache = self.cache.restricted(deny) if deny else self.cache
            report = self._execute(graph, j, cache, child)
        return self._finish(child, report)

    def status(self, workflow_id: str) -> Dict[str, Any]:
        """The workflow's meta plus its pending interrupt (if suspended).

        A pending interrupt declared with a timeout also reports its absolute
        ``deadline`` (epoch seconds), the ``on_timeout`` policy, and whether
        the deadline has already ``expired``.
        """
        meta = self.store.meta(workflow_id)
        with Journal(self.store.journal_path(workflow_id), sync="never") as j:
            pending = self._pending_suspend(j)
        if meta.get("status") in ("suspended", "escalated") and pending is not None:
            info: Dict[str, Any] = {
                "node": pending.node_id,
                "interrupt": str(pending.meta.get("interrupt", "")),
            }
            if pending.meta.get("deadline") is not None:
                info["deadline"] = pending.meta["deadline"]
                info["on_timeout"] = str(pending.meta.get("on_timeout", ""))
                info["expired"] = self._expired(pending.meta)
            meta["pending_interrupt"] = info
        else:
            meta["pending_interrupt"] = None
        return meta

    # -- internals -----------------------------------------------------------
    def _graph(self, workflow: str, args: Optional[Mapping[str, Any]]) -> ContextGraph:
        graph = self.registry.get(workflow)(dict(args) if args else None)
        graph.validate()
        return graph

    def _journal(self, workflow_id: str, lineage: Optional[Mapping[str, Any]]) -> Journal:
        return Journal(
            self.store.journal_path(workflow_id),
            sync=self.journal_sync,
            lineage=lineage,
        )

    def _execute(
        self,
        graph: ContextGraph,
        journal: Journal,
        cache: Any,
        workflow_id: str,
    ) -> ExecutionReport:
        if self.executor_factory is not None:
            ex = self.executor_factory(journal=journal, cache=cache)
        else:
            ex = LocalExecutor(max_workers=self.max_workers, journal=journal, cache=cache)
        return ex.run(graph, run_meta={"workflow": workflow_id})

    @staticmethod
    def _apply_resumes(graph: ContextGraph, journal: Journal) -> None:
        # the journal is the source of truth for interrupt answers: re-apply
        # every RESUME in order so any incarnation sees every answer so far
        for rec in journal.records():
            if rec.kind != "RESUME":
                continue
            nid = rec.node_id
            inputs = rec.meta.get("inputs") or {}
            if nid in graph.nodes and inputs:
                n = graph.nodes[nid]
                n.data = {**dict(n.data), **inputs}

    @staticmethod
    def _pending_suspend_from(records) -> Optional[JournalRecord]:
        # latest SUSPEND not yet answered by a RESUME for the same node
        pending: Optional[JournalRecord] = None
        for rec in records:
            if rec.kind == "SUSPEND":
                pending = rec
            elif rec.kind == "RESUME" and pending is not None and rec.node_id == pending.node_id:
                pending = None  # already answered
        return pending

    def _pending_suspend(self, journal: Journal) -> Optional[JournalRecord]:
        return self._pending_suspend_from(list(journal.records()))

    @classmethod
    def _latest_suspend_from(cls, records) -> Tuple[Optional[str], str]:
        rec = cls._pending_suspend_from(records)
        if rec is None:
            return None, ""
        return rec.node_id, str(rec.meta.get("interrupt", ""))

    def _latest_suspend(self, journal: Journal) -> Tuple[Optional[str], str]:
        return self._latest_suspend_from(list(journal.records()))

    @staticmethod
    def _expired(meta: Mapping[str, Any], now: Optional[float] = None) -> bool:
        deadline = meta.get("deadline")
        if deadline is None:
            return False
        # wall-clock: 'deadline' is a journaled absolute wall time
        return (time.time() if now is None else now) >= float(deadline)

    def _finish(self, workflow_id: str, report: ExecutionReport) -> WorkflowResult:
        if report.suspended:
            self.store.update(
                workflow_id,
                status="suspended",
                interrupt=report.interrupt,
                interrupt_node=report.interrupt_node,
            )
            return WorkflowResult(
                workflow_id=workflow_id,
                status="suspended",
                report=report,
                interrupt=report.interrupt,
                node=report.interrupt_node,
            )
        self.store.update(workflow_id, status="completed", interrupt=None, interrupt_node=None)
        return WorkflowResult(workflow_id=workflow_id, status="completed", report=report)
