"""Run-timeline reconstruction: journal + span log → per-node view.

The journal alone is enough to rebuild a run's timeline post-hoc — every
NODE_COMMIT carries its dependency list in ``meta["deps"]`` and a wall
timestamp, and uncompacted journals additionally carry NODE_START records
giving each node a start edge. Compacted journals fold NODE_START away
(it is pure history); those nodes degrade to zero-duration commit events,
which keeps the ordering and dependency structure exact even when
durations are unknown.

When a ``spans.jsonl`` from a live-traced run is available, node spans
(matched by replay identity ``(node, ξ-digest, input-digest)``) override
the journal-derived start/duration with monotonic-clock-accurate values
and attach the executing worker.

The critical path is the longest chain through the dependency DAG by node
duration — the chain an infinitely wide cluster could not run any faster.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, Iterable, List, Optional, Tuple

from repro.obs.sinks import chrome_trace

if TYPE_CHECKING:  # repro.core imports are deferred to call time: this
    # module is reachable from repro.core's own import graph (stream
    # runtime → obs.metrics → obs package) and must not close the cycle
    from repro.core.durable import JournalRecord

#: Kinds the timeline deliberately ignores: no time geometry to extract.
#: Kept in sync with the dispatch in :meth:`Timeline.from_records` —
#: ``python -m repro lint`` (INV101) diffs ``handled ∪ ignored`` against
#: ``KNOWN_KINDS``, so a new kind must be classified here or handled there.
TIMELINE_IGNORED_KINDS = frozenset(
    {
        "CACHE_STORE",
        "CKPT",
        "SUSPEND",
        "RESUME",
        "FORK",
        "LINEAGE",
        "STREAM_EOS",
        "SNAPSHOT",
    }
)


@dataclass
class NodeTiming:
    """One node's reconstructed execution window."""

    node: str
    start: float = 0.0  # wall clock; 0.0 when unknown
    end: float = 0.0
    dur_s: float = 0.0
    attempts: int = 0
    chunks: int = 0  # CHUNK_COMMITs (stream nodes)
    status: str = "committed"  # committed | replayed | failed
    worker: str = ""  # from span log, when available
    deps: Tuple[str, ...] = ()
    source: str = "journal"  # journal | spans

    def to_obj(self) -> Dict[str, Any]:
        """JSON-serializable form."""
        return {
            "node": self.node,
            "start": self.start,
            "end": self.end,
            "dur_s": self.dur_s,
            "attempts": self.attempts,
            "chunks": self.chunks,
            "status": self.status,
            "worker": self.worker,
            "deps": list(self.deps),
            "source": self.source,
        }


@dataclass
class Timeline:
    """A run's per-node timings, dependency edges, and critical path."""

    nodes: Dict[str, NodeTiming] = field(default_factory=dict)
    run_start: float = 0.0
    run_end: float = 0.0
    cache_hits: int = 0
    requeues: int = 0
    handoffs: int = 0

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_journal(
        journal_path: str, spans: Optional[Iterable[Dict[str, Any]]] = None
    ) -> "Timeline":
        """Build a timeline from a journal file, optionally merging spans.

        Works on compacted journals: ``Journal.records()`` transparently
        expands SNAPSHOT records, and nodes whose NODE_START was folded
        away fall back to zero-duration commit events.
        """
        from repro.core.durable import Journal

        with Journal(journal_path, sync="never") as j:
            records = list(j.records())
        return Timeline.from_records(records, spans=spans)

    @staticmethod
    def from_records(
        records: "List[JournalRecord]", spans: Optional[Iterable[Dict[str, Any]]] = None
    ) -> "Timeline":
        """Build a timeline from already-loaded journal records."""
        tl = Timeline()
        starts: Dict[str, float] = {}
        for rec in records:
            if rec.kind == "RUN_START":
                tl.run_start = tl.run_start or rec.wall_time
            elif rec.kind == "RUN_END":
                tl.run_end = rec.wall_time
            elif rec.kind == "NODE_START":
                starts.setdefault(rec.node_id, rec.wall_time)
            elif rec.kind == "NODE_COMMIT":
                start = starts.get(rec.node_id, 0.0)
                nt = tl.nodes.get(rec.node_id) or NodeTiming(node=rec.node_id)
                nt.start = start or rec.wall_time
                nt.end = rec.wall_time
                nt.dur_s = max(0.0, rec.wall_time - start) if start else 0.0
                nt.attempts = max(nt.attempts, rec.attempt + 1)
                nt.status = "committed"
                nt.deps = tuple(rec.meta.get("deps") or ())
                tl.nodes[rec.node_id] = nt
            elif rec.kind == "NODE_FAIL":
                nt = tl.nodes.get(rec.node_id) or NodeTiming(node=rec.node_id)
                nt.attempts = max(nt.attempts, rec.attempt + 1)
                if nt.status != "committed":
                    nt.status = "failed"
                tl.nodes[rec.node_id] = nt
            elif rec.kind == "CHUNK_COMMIT":
                nt = tl.nodes.get(rec.node_id) or NodeTiming(node=rec.node_id)
                nt.chunks += 1
                tl.nodes[rec.node_id] = nt
            elif rec.kind == "CACHE_HIT":
                tl.cache_hits += 1
            elif rec.kind == "NODE_REQUEUE":
                tl.requeues += 1
            elif rec.kind == "GW_HANDOFF":
                tl.handoffs += 1
        if spans:
            tl._merge_spans(spans)
        return tl

    def _merge_spans(self, spans: Iterable[Dict[str, Any]]) -> None:
        """Overlay node spans' precise timings and worker attribution."""
        by_node: Dict[str, Dict[str, Any]] = {}
        workers: Dict[str, str] = {}
        for sp in spans:
            attrs = sp.get("attrs") or {}
            node = str(attrs.get("node") or "")
            if not node:
                continue
            if sp.get("kind") == "node":
                by_node[node] = sp
            elif sp.get("kind") == "rpc" and attrs.get("worker"):
                workers[node] = str(attrs["worker"])
        for node, sp in by_node.items():
            nt = self.nodes.get(node)
            if nt is None:
                continue
            nt.start = float(sp.get("ts", nt.start))
            nt.dur_s = float(sp.get("dur", nt.dur_s))
            nt.end = nt.start + nt.dur_s
            nt.source = "spans"
        for node, worker in workers.items():
            if node in self.nodes:
                self.nodes[node].worker = worker

    # -- analysis -----------------------------------------------------------
    def critical_path(self) -> Tuple[List[str], float]:
        """Longest duration-weighted dependency chain: ``(nodes, seconds)``.

        Duration ties (e.g. a compacted journal where every node degraded
        to zero duration) fall back to hop count, so the structural chain
        survives even without timings. Edges to dependencies missing from
        the timeline (e.g. satisfied entirely by replay in a later
        incarnation) are skipped.
        """
        memo: Dict[str, Tuple[float, List[str]]] = {}

        def best(node: str) -> Tuple[float, List[str]]:
            if node in memo:
                return memo[node]
            nt = self.nodes[node]
            memo[node] = (nt.dur_s, [node])  # provisional: breaks dep cycles
            top: Tuple[float, List[str]] = (0.0, [])
            for dep in nt.deps:
                if dep in self.nodes:
                    cand = best(dep)
                    if (cand[0], len(cand[1])) > (top[0], len(top[1])):
                        top = cand
            memo[node] = (nt.dur_s + top[0], top[1] + [node])
            return memo[node]

        winner: Tuple[float, List[str]] = (0.0, [])
        for node in self.nodes:
            cand = best(node)
            if (cand[0], len(cand[1])) > (winner[0], len(winner[1])):
                winner = cand
        return winner[1], winner[0]

    # -- export -------------------------------------------------------------
    def to_obj(self) -> Dict[str, Any]:
        """JSON-serializable form (nodes sorted by start time)."""
        path, path_s = self.critical_path()
        ordered = sorted(self.nodes.values(), key=lambda n: (n.start, n.node))
        return {
            "run_start": self.run_start,
            "run_end": self.run_end,
            "wall_s": max(0.0, self.run_end - self.run_start) if self.run_end else 0.0,
            "cache_hits": self.cache_hits,
            "requeues": self.requeues,
            "handoffs": self.handoffs,
            "nodes": [n.to_obj() for n in ordered],
            "critical_path": path,
            "critical_path_s": path_s,
        }

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome-trace object synthesized from the timeline itself.

        Usable even when the run was never live-traced — every committed
        node becomes one complete event on its worker's (or the journal's)
        lane.
        """
        spans = [
            {
                "name": nt.node,
                "kind": "node",
                "ts": nt.start,
                "dur": nt.dur_s,
                "status": nt.status,
                "attrs": {"worker": nt.worker or "journal", "attempts": nt.attempts},
            }
            for nt in self.nodes.values()
        ]
        return chrome_trace(spans)

    def render_text(self) -> str:
        """Human-readable table + critical-path summary for the CLI."""
        obj = self.to_obj()
        lines: List[str] = []
        base = self.run_start or min(
            (n.start for n in self.nodes.values() if n.start), default=0.0
        )
        width = max((len(n) for n in self.nodes), default=4)
        header = f"{'node':<{width}}  {'start+s':>8}  {'dur_s':>8}  att  chunks  worker  status"
        lines.append(header)
        for n in obj["nodes"]:
            rel = (n["start"] - base) if n["start"] else 0.0
            lines.append(
                f"{n['node']:<{width}}  {rel:>8.3f}  {n['dur_s']:>8.3f}  "
                f"{n['attempts']:>3}  {n['chunks']:>6}  {n['worker'] or '-':<6}  {n['status']}"
            )
        path = obj["critical_path"]
        if path:
            lines.append(
                f"critical path: {' -> '.join(path)} "
                f"({obj['critical_path_s']:.3f}s of {obj['wall_s']:.3f}s wall)"
            )
        if self.cache_hits or self.requeues or self.handoffs:
            lines.append(
                f"cache_hits={self.cache_hits} requeues={self.requeues} "
                f"handoffs={self.handoffs}"
            )
        return "\n".join(lines)

    def to_json(self) -> str:
        """The timeline as a stable JSON document."""
        return json.dumps(self.to_obj(), sort_keys=True)


__all__ = ["NodeTiming", "TIMELINE_IGNORED_KINDS", "Timeline"]
