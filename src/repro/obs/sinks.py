"""Span sinks and trace exporters.

Sinks receive finished spans as plain dicts (``Span.to_obj()``):

- :class:`RingSink` — bounded in-memory ring, the default harness for
  tests and interactive inspection.
- :class:`JsonlSink` — one JSON object per line, conventionally written
  to ``runs/<id>/spans.jsonl`` next to the run's journal so the trace CLI
  finds it.

Exporters turn span dicts into the Chrome-trace/Perfetto JSON format
(``chrome://tracing`` / https://ui.perfetto.dev): each span becomes one
complete ``"ph": "X"`` event, grouped into threads by worker (falling
back to span kind), with human-readable thread-name metadata events.
"""

from __future__ import annotations

import collections
import json
import os
import threading
from typing import Any, Dict, Iterable, List, Optional


class RingSink:
    """Keep the last ``capacity`` spans in memory."""

    def __init__(self, capacity: int = 4096):
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()

    def emit(self, span: Dict[str, Any]) -> None:
        """Record one finished span."""
        with self._lock:
            self._ring.append(span)

    def spans(self) -> List[Dict[str, Any]]:
        """Snapshot of retained spans, oldest first."""
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        """Drop every retained span."""
        with self._lock:
            self._ring.clear()


class JsonlSink:
    """Append spans to a JSONL file (one object per line, sorted keys)."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._fh: Optional[Any] = None

    def emit(self, span: Dict[str, Any]) -> None:
        """Write one span as a JSON line (opens the file lazily)."""
        line = json.dumps(span, sort_keys=True)
        with self._lock:
            if self._fh is None:
                parent = os.path.dirname(self.path)
                if parent:
                    os.makedirs(parent, exist_ok=True)
                self._fh = open(self.path, "a", encoding="utf-8")
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        """Flush and close the underlying file."""
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "JsonlSink":
        """Support ``with JsonlSink(...) as sink``."""
        return self

    def __exit__(self, *exc: Any) -> None:
        """Close on scope exit."""
        self.close()


def read_spans(path: str) -> List[Dict[str, Any]]:
    """Load a spans.jsonl file; blank/torn trailing lines are skipped."""
    out: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except ValueError:
                continue  # torn tail from a crashed writer — best effort
    return out


def chrome_trace(spans: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Render spans as a Chrome-trace (Perfetto-loadable) JSON object.

    Spans are grouped into threads by their ``worker`` attribute (falling
    back to span kind); timestamps are wall-clock microseconds so events
    from different hosts line up on one absolute axis.
    """
    events: List[Dict[str, Any]] = []
    tids: Dict[str, int] = {}
    for sp in spans:
        attrs = sp.get("attrs") or {}
        lane = str(attrs.get("worker") or sp.get("kind") or "main")
        if lane not in tids:
            tids[lane] = len(tids) + 1
            events.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": 1,
                    "tid": tids[lane],
                    "args": {"name": lane},
                }
            )
        events.append(
            {
                "ph": "X",
                "name": sp.get("name", "?"),
                "cat": sp.get("kind", "internal"),
                "pid": 1,
                "tid": tids[lane],
                "ts": float(sp.get("ts", 0.0)) * 1e6,
                "dur": float(sp.get("dur", 0.0)) * 1e6,
                "args": {
                    "trace": sp.get("trace", ""),
                    "span": sp.get("span", ""),
                    "parent": sp.get("parent", ""),
                    "status": sp.get("status", ""),
                    **attrs,
                },
            }
        )
    events.insert(
        0,
        {"ph": "M", "name": "process_name", "pid": 1, "tid": 0, "args": {"name": "repro"}},
    )
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable[Dict[str, Any]]) -> str:
    """Write :func:`chrome_trace` output to ``path``; returns the path."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(chrome_trace(spans), fh)
    return path


__all__ = ["JsonlSink", "RingSink", "chrome_trace", "read_spans", "write_chrome_trace"]
