"""Observability layer: distributed tracing, unified metrics, run timelines.

The paper's thesis is context-aware execution — ``repro.obs`` turns that
same context machinery into the observability substrate:

- :mod:`repro.obs.trace` — ``Span``/``Tracer``. Trace identity rides the
  run's Ψ context as a reserved ``obs.``-prefixed fact, so spans nest
  correctly across the gateway→worker hop on both transports (threaded
  HTTP and asyncio) and across ``ShardedGateway`` handoffs, with zero
  transport changes. Off by default; a disabled tracer is one attribute
  read per call site.
- :mod:`repro.obs.metrics` — ``MetricsRegistry`` with counters, gauges
  and histograms plus pull-collectors that absorb the pre-existing
  ad-hoc stats surfaces (``Gateway.stats()``, ``Channel.stats``,
  ``ResultCache.stats``) behind one snapshot API with Prometheus text
  and JSON export.
- :mod:`repro.obs.sinks` — span sinks (in-memory ring, JSONL file) and
  the Chrome-trace/Perfetto exporter.
- :mod:`repro.obs.timeline` — per-node timeline + critical path
  reconstructed post-hoc from a journal (compacted or not), optionally
  enriched by a span log; backs ``python -m repro trace``.

Attribute access is lazy: ``repro.obs`` sits *below* ``repro.core`` and
``repro.stream`` in the import graph (both instrument through it), so the
package must not eagerly import submodules that reach back up into them.

See docs/observability.md for the span model and propagation contract.
"""

import importlib

_EXPORTS = {
    "Span": "trace",
    "Tracer": "trace",
    "extract_trace": "trace",
    "get_tracer": "trace",
    "inject_trace": "trace",
    "strip_trace": "trace",
    "Counter": "metrics",
    "Gauge": "metrics",
    "Histogram": "metrics",
    "MetricsRegistry": "metrics",
    "cache_collector": "metrics",
    "channel_collector": "metrics",
    "gateway_collector": "metrics",
    "reset_metrics": "metrics",
    "JsonlSink": "sinks",
    "RingSink": "sinks",
    "chrome_trace": "sinks",
    "read_spans": "sinks",
    "write_chrome_trace": "sinks",
    "NodeTiming": "timeline",
    "Timeline": "timeline",
}

__all__ = ["trace", "metrics", "sinks", "timeline", *sorted(_EXPORTS)]


def __getattr__(name):
    """Resolve exported names (and submodules) on first access."""
    if name in ("trace", "metrics", "sinks", "timeline"):
        return importlib.import_module(f"repro.obs.{name}")
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}") from None
    return getattr(importlib.import_module(f"repro.obs.{module}"), name)
