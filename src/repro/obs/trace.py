"""Distributed tracing: spans propagated as Ψ context facts.

The trace contract (docs/observability.md) in three invariants:

1. **Propagation is the context.** A span crossing a process boundary is
   carried as one reserved fact under :data:`TRACE_KEY` inside the same
   ``Context`` that already travels in every task submission — both worker
   transports (threaded HTTP and asyncio) and ``ShardedGateway`` handoffs
   forward it untouched, so no wire format changes.
2. **Tracing never changes replay identity.** ``obs.``-prefixed facts are
   excluded from ``Context.digest()`` and injected with lamport 0, so a
   traced run commits byte-identical digests to an untraced one, and the
   fact is only stamped on the transient submit-time context — it is never
   stored into a node's output context.
3. **Replays are silent.** Call sites start spans only after the
   replay/cache probes miss; stages that turn out replayed call
   :meth:`Tracer.discard`. A replayed run therefore emits zero spans.

The tracer is a process-global singleton that is toggled, never replaced:
hot call sites cache ``get_tracer()`` once and guard with a single
``tracer.enabled`` attribute read, which is the entire disabled-mode cost.
"""

from __future__ import annotations

import threading
import time
import uuid
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, Iterator, List, Optional, Tuple

if TYPE_CHECKING:  # context imports are deferred to call time: this module
    # is imported by repro.core itself (gateway, server, executor), so an
    # eager import here would re-enter repro.core mid-initialization
    from repro.core.context import Context

#: The reserved context key carrying trace identity across process hops
#: (under ``repro.core.context.OBS_KEY_PREFIX``, the digest-excluded
#: namespace).
TRACE_KEY = "obs.trace"

#: Origin stamped on injected trace facts (never a worker identity).
TRACE_ORIGIN = "ψ.obs"


def _new_id() -> str:
    """A fresh 16-hex span/trace id."""
    return uuid.uuid4().hex[:16]


@dataclass
class Span:
    """One timed operation in a trace.

    ``start_wall`` is an epoch timestamp so spans correlate with journal
    record ``wall_time``; duration is measured on the monotonic clock
    (``_t0``) so it is immune to wall-clock steps.
    """

    name: str
    trace_id: str
    span_id: str
    parent_id: str = ""
    kind: str = "internal"  # run | node | rpc | task | stream | handoff | ...
    start_wall: float = 0.0
    dur_s: float = 0.0
    status: str = "ok"
    attrs: Dict[str, Any] = field(default_factory=dict)
    _t0: float = 0.0

    def to_obj(self) -> Dict[str, Any]:
        """The JSON-serializable wire/sink form of this span."""
        return {
            "name": self.name,
            "trace": self.trace_id,
            "span": self.span_id,
            "parent": self.parent_id,
            "kind": self.kind,
            "ts": self.start_wall,
            "dur": self.dur_s,
            "status": self.status,
            "attrs": dict(self.attrs),
        }


class Tracer:
    """Process-global span factory and sink fan-out.

    Disabled by default. Call sites hold the singleton (:func:`get_tracer`)
    and check :attr:`enabled` before building spans; :meth:`configure`
    mutates the flag and sink list in place so cached references stay
    valid. All sink emission happens at :meth:`end` time.
    """

    def __init__(self) -> None:
        self.enabled = False
        self._sinks: List[Any] = []
        self._lock = threading.Lock()
        self.discarded = 0  # spans started then dropped (replayed work)

    # -- lifecycle ----------------------------------------------------------
    def configure(self, *, enabled: Optional[bool] = None) -> None:
        """Toggle tracing; ``None`` leaves the flag unchanged."""
        if enabled is not None:
            self.enabled = bool(enabled)

    def add_sink(self, sink: Any) -> None:
        """Attach ``sink`` (any object with ``emit(span_obj)``)."""
        with self._lock:
            if sink not in self._sinks:
                self._sinks.append(sink)

    def remove_sink(self, sink: Any) -> None:
        """Detach ``sink``; unknown sinks are ignored."""
        with self._lock:
            if sink in self._sinks:
                self._sinks.remove(sink)

    @contextmanager
    def attached(self, sink: Any, *, enable: bool = True) -> Iterator[Any]:
        """Attach ``sink`` (optionally enabling tracing) for a scope.

        Restores the previous enabled flag and detaches the sink on exit —
        the standard harness for tests and for ``Client.run(trace=True)``.
        """
        prev = self.enabled
        self.add_sink(sink)
        if enable:
            self.enabled = True
        try:
            yield sink
        finally:
            self.enabled = prev
            self.remove_sink(sink)

    # -- span construction --------------------------------------------------
    def start_span(
        self,
        name: str,
        *,
        parent: Optional[Span] = None,
        trace_id: str = "",
        parent_id: str = "",
        kind: str = "internal",
        attrs: Optional[Dict[str, Any]] = None,
    ) -> Span:
        """Open a span. Parentage comes from ``parent`` or explicit ids.

        With neither, the span roots a brand-new trace.
        """
        if parent is not None:
            trace_id = parent.trace_id
            parent_id = parent.span_id
        return Span(
            name=name,
            trace_id=trace_id or _new_id(),
            span_id=_new_id(),
            parent_id=parent_id,
            kind=kind,
            start_wall=time.time(),  # record timestamp
            attrs=dict(attrs or {}),
            _t0=time.monotonic(),
        )

    def end(
        self,
        span: Span,
        *,
        status: str = "ok",
        attrs: Optional[Dict[str, Any]] = None,
    ) -> Span:
        """Close ``span`` and emit it to every attached sink."""
        span.dur_s = max(0.0, time.monotonic() - span._t0)
        span.status = status
        if attrs:
            span.attrs.update(attrs)
        obj = span.to_obj()
        with self._lock:
            sinks = list(self._sinks)
        for sink in sinks:
            try:
                sink.emit(obj)
            except Exception:  # a broken sink must never fail the run
                pass
        return span

    def discard(self, span: Span) -> None:
        """Drop a started span without emitting — the work was replayed."""
        self.discarded += 1

    @contextmanager
    def span(
        self,
        name: str,
        *,
        parent: Optional[Span] = None,
        trace_id: str = "",
        parent_id: str = "",
        kind: str = "internal",
        attrs: Optional[Dict[str, Any]] = None,
    ) -> Iterator[Optional[Span]]:
        """Context-managed span: ends ``ok`` on exit, ``error`` on raise.

        Yields ``None`` (and does nothing) when tracing is disabled.
        """
        if not self.enabled:
            yield None
            return
        sp = self.start_span(
            name, parent=parent, trace_id=trace_id, parent_id=parent_id, kind=kind, attrs=attrs
        )
        try:
            yield sp
        except BaseException:
            self.end(sp, status="error")
            raise
        self.end(sp)


_TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-global singleton tracer (stable — cache it freely)."""
    return _TRACER


# -- context propagation ----------------------------------------------------


def inject_trace(ctx: "Context", span: Span) -> "Context":
    """Stamp ``span``'s identity onto ``ctx`` as a transient Ψ fact.

    The fact uses lamport 0 so ``ctx.max_lamport()`` — and therefore the
    lamport (and digest) of every later real fact — is identical between
    traced and untraced runs. Any previous trace fact is replaced, never
    accumulated. The returned context is for the wire only; callers keep
    threading the *original* ``ctx`` into commit/output paths.
    """
    from repro.core.context import Context, ContextEntry

    entries = [e for e in ctx if e.key != TRACE_KEY]
    entries.append(
        ContextEntry.make(TRACE_KEY, {"t": span.trace_id, "s": span.span_id}, TRACE_ORIGIN, 0)
    )
    return Context(entries)


def extract_trace(ctx: "Context") -> Optional[Tuple[str, str]]:
    """Read ``(trace_id, parent_span_id)`` off ``ctx``, or ``None``."""
    raw = ctx.get(TRACE_KEY)
    if not isinstance(raw, dict):
        return None
    trace_id = str(raw.get("t", ""))
    span_id = str(raw.get("s", ""))
    if not trace_id or not span_id:
        return None
    return trace_id, span_id


def strip_trace(ctx: "Context") -> "Context":
    """Drop any trace fact from ``ctx`` (used before storing output ξ)."""
    from repro.core.context import Context

    if ctx.get(TRACE_KEY) is None:
        return ctx
    return Context([e for e in ctx if e.key != TRACE_KEY])


__all__ = [
    "TRACE_KEY",
    "TRACE_ORIGIN",
    "Span",
    "Tracer",
    "extract_trace",
    "get_tracer",
    "inject_trace",
    "strip_trace",
]
