"""Unified metrics: one registry over push instruments and pull collectors.

Two complementary surfaces feed one snapshot:

- **Push instruments** — :class:`Counter`, :class:`Gauge`,
  :class:`Histogram` created through :class:`MetricsRegistry`; call sites
  hold the instrument and update it directly (a lock-guarded float add).
- **Pull collectors** — zero-copy adapters over the stats surfaces that
  predate this module (``Gateway.stats()``, ``Channel.stats``,
  ``ResultCache.stats`` including the tiered backend's ``remote_errors``).
  A collector is just a callable returning ``{metric_name: value}``; it is
  polled at snapshot time, so the owning objects keep their cheap ad-hoc
  dicts and nothing on their hot paths changes.

Naming scheme (docs/observability.md): ``repro_<subsystem>_<what>[_total]``
with Prometheus-style ``{label="value"}`` suffixes baked into the name.
Snapshots are plain dicts, identical under ``REPRO_RUNTIME=thread|async``;
:meth:`MetricsRegistry.to_prometheus` renders text exposition format and
:meth:`MetricsRegistry.to_json` a stable JSON document. All timing helpers
use the monotonic clock.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Iterator, List, Mapping, Tuple

#: Default histogram bucket upper bounds, in seconds (latency-oriented).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001,
    0.005,
    0.01,
    0.05,
    0.1,
    0.5,
    1.0,
    5.0,
    10.0,
    30.0,
    60.0,
)


def _labeled(name: str, labels: Mapping[str, str]) -> str:
    """Render ``name{k="v",...}`` with labels sorted for determinism."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """A monotonically increasing float (use ``*_total`` names)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0) to the counter."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current cumulative value."""
        return self._value


class Gauge:
    """A point-in-time float that can go up and down."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        """Replace the gauge's value."""
        with self._lock:
            self._value = float(v)

    def add(self, n: float) -> None:
        """Adjust the gauge by ``n`` (negative to decrement)."""
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        """Current value."""
        return self._value


class Histogram:
    """Bucketed distribution of observations (Prometheus-compatible)."""

    __slots__ = ("name", "buckets", "_counts", "_sum", "_count", "_lock")

    def __init__(self, name: str, buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.buckets = tuple(sorted(buckets))
        self._counts = [0] * (len(self.buckets) + 1)  # +inf tail bucket
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        """Record one observation."""
        idx = len(self.buckets)
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                idx = i
                break
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1

    @contextmanager
    def time(self) -> Iterator[None]:
        """Observe the monotonic duration of the ``with`` body."""
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.observe(time.monotonic() - t0)

    def snapshot(self) -> Dict[str, Any]:
        """Cumulative bucket counts plus sum/count."""
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum: Dict[str, int] = {}
        acc = 0
        for ub, c in zip(self.buckets, counts, strict=False):  # counts has a +Inf slot
            acc += c
            cum[repr(ub)] = acc
        cum["+Inf"] = total
        return {"buckets": cum, "sum": s, "count": total}


class MetricsRegistry:
    """Instrument factory + collector host behind one snapshot API."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._collectors: Dict[str, Callable[[], Mapping[str, float]]] = {}

    # -- instruments --------------------------------------------------------
    def counter(self, name: str, **labels: str) -> Counter:
        """Get or create the counter ``name`` (labels baked into the name)."""
        key = _labeled(name, labels)
        with self._lock:
            inst = self._counters.get(key)
            if inst is None:
                inst = self._counters[key] = Counter(key)
        return inst

    def gauge(self, name: str, **labels: str) -> Gauge:
        """Get or create the gauge ``name``."""
        key = _labeled(name, labels)
        with self._lock:
            inst = self._gauges.get(key)
            if inst is None:
                inst = self._gauges[key] = Gauge(key)
        return inst

    def histogram(
        self, name: str, buckets: Tuple[float, ...] = DEFAULT_BUCKETS, **labels: str
    ) -> Histogram:
        """Get or create the histogram ``name``."""
        key = _labeled(name, labels)
        with self._lock:
            inst = self._histograms.get(key)
            if inst is None:
                inst = self._histograms[key] = Histogram(key, buckets)
        return inst

    @contextmanager
    def timer(self, name: str, **labels: str) -> Iterator[None]:
        """Shorthand: time the ``with`` body into histogram ``name``."""
        with self.histogram(name, **labels).time():
            yield

    # -- collectors ---------------------------------------------------------
    def register_collector(self, name: str, fn: Callable[[], Mapping[str, float]]) -> None:
        """(Re)register pull-collector ``name`` — polled at snapshot time."""
        with self._lock:
            self._collectors[name] = fn

    def unregister_collector(self, name: str) -> None:
        """Drop collector ``name``; unknown names are ignored."""
        with self._lock:
            self._collectors.pop(name, None)

    # -- export -------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """One flat view: counters, gauges (push + polled), histograms.

        Collector failures degrade to a ``repro_collector_errors`` entry
        rather than failing the snapshot — observability must never take
        down the observed.
        """
        with self._lock:
            counters = {k: c.value for k, c in self._counters.items()}
            gauges = {k: g.value for k, g in self._gauges.items()}
            hists = {k: h.snapshot() for k, h in self._histograms.items()}
            collectors = list(self._collectors.items())
        errors = 0
        for _name, fn in collectors:
            try:
                polled = fn()
            except Exception:
                errors += 1
                continue
            for k, v in polled.items():
                if k.split("{", 1)[0].endswith("_total"):
                    counters[k] = float(v)
                else:
                    gauges[k] = float(v)
        if errors:
            gauges["repro_collector_errors"] = float(errors)
        return {"counters": counters, "gauges": gauges, "histograms": hists}

    def to_json(self) -> str:
        """The snapshot as a stable (sorted-keys) JSON document."""
        return json.dumps(self.snapshot(), sort_keys=True)

    def to_prometheus(self) -> str:
        """The snapshot in Prometheus text exposition format."""
        snap = self.snapshot()
        lines: List[str] = []
        for name in sorted(snap["counters"]):
            lines.append(f"{name} {_fmt(snap['counters'][name])}")
        for name in sorted(snap["gauges"]):
            lines.append(f"{name} {_fmt(snap['gauges'][name])}")
        for name in sorted(snap["histograms"]):
            h = snap["histograms"][name]
            base, labels = _split_labels(name)
            for ub, c in h["buckets"].items():
                le = ",".join(filter(None, [labels, f'le="{ub}"']))
                lines.append(f"{base}_bucket{{{le}}} {c}")
            suffix = f"{{{labels}}}" if labels else ""
            lines.append(f"{base}_sum{suffix} {_fmt(h['sum'])}")
            lines.append(f"{base}_count{suffix} {h['count']}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        """Drop every instrument and collector (test isolation)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._collectors.clear()


def _fmt(v: float) -> str:
    """Integers render bare; floats keep their repr."""
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def _split_labels(key: str) -> Tuple[str, str]:
    """Split ``name{a="b"}`` into (``name``, ``a="b"``)."""
    if "{" not in key:
        return key, ""
    base, _, rest = key.partition("{")
    return base, rest.rstrip("}")


# -- collectors over the pre-existing stats surfaces -------------------------


def gateway_collector(gateway: Any) -> Callable[[], Dict[str, float]]:
    """Adapter over ``Gateway.stats()`` / ``AsyncGateway.stats()``.

    Emits gateway-level gauges, the cumulative ``metrics`` dict as
    ``repro_gateway_<key>_total`` counters, and per-worker gauges labeled
    ``{worker="..."}`` — schema-identical across both runtimes because
    ``stats()`` itself is defined once on the base Gateway.
    """

    def collect() -> Dict[str, float]:
        stats = gateway.stats()
        out: Dict[str, float] = {
            "repro_gateway_queue_depth": float(stats.get("queue_depth", 0)),
            "repro_gateway_silo_depth": float(stats.get("silo_depth", 0)),
            "repro_gateway_live_workers": float(stats.get("live_workers", 0)),
            "repro_gateway_suspended_runs": float(len(stats.get("suspended_runs") or ())),
            "repro_gateway_mean_alloc_us": float(stats.get("mean_alloc_us", 0.0)),
        }
        for key, val in (stats.get("metrics") or {}).items():
            out[f"repro_gateway_{key}_total"] = float(val)
        for wname, w in (stats.get("workers") or {}).items():
            lab = {"worker": wname}
            out[_labeled("repro_worker_live", lab)] = 1.0 if w.get("live") else 0.0
            out[_labeled("repro_worker_inflight", lab)] = float(w.get("inflight", 0))
            out[_labeled("repro_worker_completed_total", lab)] = float(w.get("completed", 0))
            out[_labeled("repro_worker_hb_misses", lab)] = float(w.get("hb_misses", 0))
            out[_labeled("repro_worker_ewma_latency_s", lab)] = float(
                w.get("ewma_latency_s", 0.0)
            )
        return out

    return collect


def cache_collector(cache: Any) -> Callable[[], Dict[str, float]]:
    """Adapter over ``ResultCache.stats`` plus tiered-backend counters.

    Surfaces the tiered backend's ``remote_hits``/``promotions``/
    ``remote_errors`` when the cache has one, so a lossy shared tier is
    visible without any cache-side changes.
    """

    def collect() -> Dict[str, float]:
        out = {f"repro_cache_{k}_total": float(v) for k, v in cache.stats.items()}
        backend = getattr(cache, "backend", None)
        for attr in ("remote_hits", "promotions", "remote_errors"):
            if hasattr(backend, attr):
                out[f"repro_cache_{attr}_total"] = float(getattr(backend, attr))
        if hasattr(backend, "corrupt_drops"):
            out["repro_cache_corrupt_drops_total"] = float(backend.corrupt_drops)
        return out

    return collect


def channel_collector(channel: Any, name: str) -> Callable[[], Dict[str, float]]:
    """Adapter over a stream ``Channel.stats`` dict (incl. ``put_blocked_s``)."""

    def collect() -> Dict[str, float]:
        lab = {"channel": name}
        out: Dict[str, float] = {}
        for key, val in channel.stats.items():
            suffix = "_total" if key in ("puts", "gets", "dropped") else ""
            out[_labeled(f"repro_channel_{key}{suffix}", lab)] = float(val)
        out[_labeled("repro_channel_depth", lab)] = float(channel.depth())
        return out

    return collect


_REGISTRY = MetricsRegistry()


def metrics() -> MetricsRegistry:
    """The process-global registry (stable singleton — cache it freely)."""
    return _REGISTRY


def reset_metrics() -> None:
    """Clear the global registry (test isolation helper)."""
    _REGISTRY.reset()


__all__ = [
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "cache_collector",
    "channel_collector",
    "gateway_collector",
    "metrics",
    "reset_metrics",
]
