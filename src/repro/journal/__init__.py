"""Journal lifecycle management: compaction and lineage projection.

The append-only journal (``repro.core.durable``) is the system's source of
truth, but a long-lived service needs two more things from it: a way to keep
replay cost O(live state) instead of O(history) — :func:`compact_journal`,
which folds a committed prefix into one digest-chained SNAPSHOT record —
and a way to *query* history — :class:`LineageIndex`, a disposable
projection answering provenance questions with bounded traversals.

See docs/journal-lifecycle.md.
"""

from repro.journal.compact import (
    CompactedHistoryError,
    CompactionError,
    CompactionStats,
    compact_journal,
)
from repro.journal.lineage import LineageIndex

__all__ = [
    "CompactedHistoryError",
    "CompactionError",
    "CompactionStats",
    "LineageIndex",
    "compact_journal",
]
