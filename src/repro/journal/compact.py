"""Journal compaction: fold committed history into one SNAPSHOT record.

A long-lived service's write-ahead journal grows without bound — every run
appends ``RUN_START``/``NODE_START``/``NODE_COMMIT``/… records, streams add
one ``CHUNK_COMMIT`` per chunk, and replay cost follows *history*, not live
state. Compaction rewrites the journal as::

    [ SNAPSHOT ] [ retained suffix records ... ]

where the SNAPSHOT (docs/journal-format.md §2.6) holds exactly the **live
frontier state** of the folded prefix — the records a future reader still
needs for bit-identical replay:

  - the ``LINEAGE`` header (durable identity of the file),
  - the last ``NODE_COMMIT`` per ``(node, ξ, inputs)`` replay identity,
  - every ``CHUNK_COMMIT`` + the last ``STREAM_EOS`` per stream identity
    (the chunks ARE a stream's durable value),
  - every ``SUSPEND`` and ``RESUME`` in order (the interrupt history:
    pending-suspend resolution and fork's default decision point both
    re-derive from it),
  - the last ``CKPT`` reference.

Pure history — ``RUN_START``/``RUN_END``, ``NODE_START``, ``NODE_FAIL``,
``NODE_REQUEUE``, ``CACHE_HIT``/``CACHE_STORE``, ``FORK``, ``GW_HANDOFF``,
superseded duplicate commits — is dropped, accounted
for only by the snapshot's digest chain. ``Journal.records()`` transparently
expands a SNAPSHOT back into its folded records, so every interpreting
reader (replay oracle, workflow runner, lineage index) sees an identical
history and replays with **zero re-execution**.

Compaction is an *offline* operation on a quiescent journal: the new file
is built in a temp sibling, digest-verified against the original (replay
state must match exactly), and atomically published with ``os.replace`` —
a crash mid-publish leaves the original journal as the untouched source of
truth and a stale ``.compact.tmp.*`` file that the next compaction sweeps.

See docs/journal-lifecycle.md for the operational policy.
"""

from __future__ import annotations

import binascii
import glob
import hashlib
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.durable import (
    SNAPSHOT_VERSION,
    Journal,
    JournalRecord,
    ReplayCache,
    encode_payload,
)
from repro.core.durable import _HEADER  # the (length, crc32) frame header

__all__ = [
    "CompactedHistoryError",
    "CompactionError",
    "CompactionStats",
    "compact_journal",
]

#: Record kinds that are pure history: safe to drop at compaction because no
#: reader derives live state from them (they are replay-ignored annotations
#: or run-lifecycle markers).
DROPPABLE_KINDS = frozenset(
    {
        "RUN_START",
        "RUN_END",
        "NODE_START",
        "NODE_FAIL",
        "NODE_REQUEUE",
        "CACHE_HIT",
        "CACHE_STORE",
        "FORK",
        "GW_HANDOFF",
    }
)


class CompactionError(RuntimeError):
    """Typed failure from the compaction pipeline (verification, torn state)."""


class CompactedHistoryError(RuntimeError):
    """An operation addressed a record seq that compaction folded away.

    Raised e.g. by ``WorkflowRunner.fork(at=...)`` when ``at`` is below the
    journal's ``base_seq``: the folded prefix retains live *state* but not
    per-record identity, so a branch point inside it no longer exists.
    """


@dataclass
class CompactionStats:
    """What one :func:`compact_journal` call did (or would do, dry-run)."""

    path: str
    before_records: int  # physical records before (snapshot counted as 1)
    after_records: int  # physical records after (snapshot + suffix)
    folded: int  # records newly folded into the snapshot this pass
    state_records: int  # live records the snapshot carries
    base_seq: int  # first logical seq still individually addressable
    chain: str  # digest-chain head over every record ever folded
    bytes_before: int
    bytes_after: int
    dry_run: bool = False

    def to_obj(self) -> Dict[str, object]:
        """Plain-dict form (CLI ``--json`` output)."""
        return {
            "path": self.path,
            "before_records": self.before_records,
            "after_records": self.after_records,
            "folded": self.folded,
            "state_records": self.state_records,
            "base_seq": self.base_seq,
            "chain": self.chain,
            "bytes_before": self.bytes_before,
            "bytes_after": self.bytes_after,
            "dry_run": self.dry_run,
        }


@dataclass
class _LiveState:
    """Ordered live records extracted from a folded prefix."""

    records: List[JournalRecord] = field(default_factory=list)


def _record_digest(rec: JournalRecord) -> str:
    """Stable digest of one record (its canonical encoded body)."""
    return hashlib.sha256(encode_payload(rec.to_obj())).hexdigest()[:16]


def _chain(chain: str, rec: JournalRecord) -> str:
    """Advance the fold chain over one record (same shape as chunk chains)."""
    return hashlib.sha256(f"{chain}:{_record_digest(rec)}".encode()).hexdigest()[:16]


def _fold(records: List[JournalRecord]) -> _LiveState:
    """Reduce ``records`` to the live state a replayer still needs.

    Keeps original relative order for everything retained, so order-dependent
    readers (``RESUME`` application, pending-``SUSPEND`` resolution) observe
    the exact history they would have seen uncompacted.
    """
    commit_at: Dict[Tuple[str, str, str], int] = {}  # identity -> index in out
    eos_at: Dict[Tuple[str, str, str], int] = {}
    ckpt_at: Optional[int] = None
    lineage_seen = False
    out: List[Optional[JournalRecord]] = []
    for rec in records:
        kind = rec.kind
        if kind in DROPPABLE_KINDS or kind == "SNAPSHOT":
            continue
        if kind == "LINEAGE":
            if lineage_seen:
                continue  # only the header names the identity
            lineage_seen = True
            out.append(rec)
        elif kind == "NODE_COMMIT":
            key = (rec.node_id, rec.context_digest, rec.input_digest)
            prev = commit_at.get(key)
            if prev is not None:
                out[prev] = None  # superseded duplicate (crash-scarred run)
            commit_at[key] = len(out)
            out.append(rec)
        elif kind == "CHUNK_COMMIT":
            out.append(rec)
        elif kind == "STREAM_EOS":
            key = (rec.node_id, rec.context_digest, rec.input_digest)
            prev = eos_at.get(key)
            if prev is not None:
                out[prev] = None
            eos_at[key] = len(out)
            out.append(rec)
        elif kind in ("RESUME", "SUSPEND"):
            # BOTH kept, answered or not: the SUSPEND/RESUME sequence IS the
            # interrupt history — pending-suspend resolution and fork's
            # default decision-point both re-derive from it in order
            out.append(rec)
        elif kind == "CKPT":
            if ckpt_at is not None:
                out[ckpt_at] = None  # only the latest checkpoint is live
            ckpt_at = len(out)
            out.append(rec)
        else:  # a KNOWN kind with no fold rule: conservatively retain it
            out.append(rec)
    return _LiveState(records=[r for r in out if r is not None])


def _frame(rec: JournalRecord) -> bytes:
    """One on-disk journal frame for ``rec`` (format §1)."""
    body = encode_payload(rec.to_obj())
    return _HEADER.pack(len(body), binascii.crc32(body)) + body


def _publish(tmp_path: str, path: str) -> None:
    """Atomically install the compacted journal (the crash-safety boundary)."""
    os.replace(tmp_path, path)


def _sweep_stale_tmp(path: str) -> int:
    """Discard partial snapshots orphaned by a crash mid-publish."""
    n = 0
    for stale in glob.glob(glob.escape(path) + ".compact.tmp.*"):
        try:
            os.remove(stale)
            n += 1
        except OSError:
            pass
    return n


def _replay_state(journal: Journal) -> Tuple[dict, dict, set, list, Optional[str]]:
    """Everything replay-relevant a journal encodes, in comparable form."""
    replay = ReplayCache(journal)
    commits = {
        key: (rec.output_digest, rec.ref, _record_digest(rec))
        for key, rec in replay._committed.items()
    }
    chunks = {
        key: [(_record_digest(r)) for r in replay.stream_chunks(*key)]
        for key in replay._chunks
    }
    eos = set(replay._eos)
    resumes = []
    pending = None
    for rec in journal.records():
        if rec.kind == "RESUME":
            resumes.append(_record_digest(rec))
        elif rec.kind == "SUSPEND":
            pending = rec.node_id
        if rec.kind == "RESUME" and pending == rec.node_id:
            pending = None
    return commits, chunks, eos, resumes, pending


def compact_journal(
    path: str,
    keep_since: Optional[int] = None,
    verify: bool = True,
    dry_run: bool = False,
) -> CompactionStats:
    """Compact the journal at ``path`` in place (offline, quiescent file).

    ``keep_since`` is the retention policy: logical record seqs ``>=
    keep_since`` are retained as physical suffix records (still addressable,
    e.g. as ``fork(at=...)`` points); everything below is folded into the
    SNAPSHOT. ``None`` folds the whole journal. Re-compacting a compacted
    journal folds the previous snapshot's state together with any newly
    foldable suffix — a journal never carries more than one SNAPSHOT, always
    as its first record.

    With ``verify=True`` (default) the candidate file must reproduce the
    original's full replay state — committed identities and output digests,
    chunk sequences, EOS markers, RESUME history, pending SUSPEND — before
    it is published; a mismatch raises :class:`CompactionError` and leaves
    the original untouched. ``dry_run`` computes stats without writing.
    """
    if not os.path.exists(path):
        raise CompactionError(f"no journal at {path!r}")
    _sweep_stale_tmp(path)
    bytes_before = os.path.getsize(path)

    with Journal(path, sync="never") as j:
        raw = list(j.records(expand=False))
        base0 = j.base_seq()
        end = j.end_seq()

    prior = raw[0] if raw and raw[0].kind == "SNAPSHOT" else None
    chain = str(prior.meta.get("chain", "")) if prior is not None else ""
    prior_state: List[JournalRecord] = []
    if prior is not None:
        prior_state = [
            JournalRecord.from_obj(o) for o in prior.meta.get("records") or ()
        ]
    suffix = raw[1:] if prior is not None else raw

    cut = end if keep_since is None else max(base0, min(int(keep_since), end))
    fold_suffix = suffix[: cut - base0]
    kept_suffix = suffix[cut - base0 :]
    for rec in fold_suffix:
        chain = _chain(chain, rec)

    state = _fold(prior_state + fold_suffix)
    snapshot = JournalRecord(
        kind="SNAPSHOT",
        wall_time=time.time(),  # record timestamp
        meta={
            "version": SNAPSHOT_VERSION,
            "base_seq": cut,
            "chain": chain,
            "folded": len(fold_suffix),
            "records": [r.to_obj() for r in state.records],
        },
    )

    stats = CompactionStats(
        path=path,
        before_records=len(raw),
        after_records=1 + len(kept_suffix),
        folded=len(fold_suffix),
        state_records=len(state.records),
        base_seq=cut,
        chain=chain,
        bytes_before=bytes_before,
        bytes_after=0,
        dry_run=dry_run,
    )
    if dry_run:
        return stats

    tmp = f"{path}.compact.tmp.{os.getpid()}"
    with open(tmp, "wb") as fh:
        fh.write(_frame(snapshot))
        for rec in kept_suffix:
            fh.write(_frame(rec))
        fh.flush()
        os.fsync(fh.fileno())

    if verify:
        try:
            with Journal(path, sync="never") as orig_j:
                want = _replay_state(orig_j)
            with Journal(tmp, sync="never") as tmp_j:
                got = _replay_state(tmp_j)
        except Exception as exc:
            os.remove(tmp)
            raise CompactionError(f"snapshot verification crashed: {exc}") from exc
        if want != got:
            os.remove(tmp)
            raise CompactionError(
                f"snapshot for {path!r} does not reproduce the original "
                "replay state; original left untouched"
            )

    _publish(tmp, path)
    stats.bytes_after = os.path.getsize(path)
    return stats
