"""Lineage index: a derived, disposable projection over the journal.

The journal is the immutable source of truth; this module projects it into
a queryable index answering *"which inputs and context digests produced
this artifact?"* — the Engram dual-store shape (append-only ledger + a
rebuildable projection for queries). The index is never persisted and never
authoritative: throw it away and :meth:`LineageIndex.build` it again from
the journal whenever you like. Because ``Journal.records()`` transparently
expands SNAPSHOT records, the same build works on compacted journals — the
provenance answers are identical before and after compaction.

Maintained either way:

  - **batch rebuild** — ``LineageIndex.build(journal)`` scans once;
  - **incremental** — call :meth:`LineageIndex.apply` on each record as it
    is appended; projection determinism (rebuilt == incremental) is a
    tested property (tests/test_lineage.py).

Traversals are bounded: :meth:`LineageIndex.provenance` takes a ``depth``
limit and is cycle-safe, so a query over an adversarial or enormous graph
does bounded work.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Set

from repro.core.durable import Journal, JournalRecord

__all__ = ["LINEAGE_IGNORED_KINDS", "LineageIndex"]

#: Kinds the projection deliberately ignores: run activity, not provenance.
#: Kept in sync with the dispatch in :meth:`LineageIndex.apply` — ``python
#: -m repro lint`` (INV101) diffs ``handled ∪ ignored`` against
#: ``KNOWN_KINDS``, so a new kind must be classified here or handled there.
LINEAGE_IGNORED_KINDS = frozenset(
    {
        "RUN_START",
        "RUN_END",
        "NODE_START",
        "NODE_FAIL",
        "NODE_REQUEUE",
        "CACHE_STORE",
        "CKPT",
        "FORK",
        "GW_HANDOFF",
        "SNAPSHOT",
    }
)


class LineageIndex:
    """Queryable provenance projection of one journal.

    Tracks, per node id, the latest committed identity — context digest,
    input digest, output digest, checkpoint ref, declared upstream ``deps``
    — plus stream chunk/EOS progress, cache-hit counts, union-group
    membership, and interrupt (SUSPEND/RESUME) history.
    """

    def __init__(self) -> None:
        self._header: Optional[Dict[str, Any]] = None
        self._entries: Dict[str, Dict[str, Any]] = {}
        self._chunks: Dict[str, int] = {}  # node -> committed chunk count
        self._eos: Set[str] = set()
        self._member_of: Dict[str, str] = {}  # member node -> union group
        self._cache_hits: Dict[str, int] = {}
        self._produced: Dict[str, List[str]] = {}  # output digest -> nodes
        self._resumes: List[Dict[str, Any]] = []
        self._pending_suspend: Optional[str] = None
        self.applied = 0  # records this projection has absorbed

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, journal: Journal) -> "LineageIndex":
        """Project a whole journal (compacted or not) in one scan."""
        idx = cls()
        for rec in journal.records():
            idx.apply(rec)
        return idx

    def apply(self, rec: JournalRecord) -> None:
        """Absorb one journal record (incremental maintenance).

        Applying every record of a journal in append order yields exactly
        the state of a from-scratch :meth:`build` — projection determinism.
        """
        self.applied += 1
        kind = rec.kind
        if kind == "LINEAGE":
            if self._header is None:
                self._header = dict(rec.meta)
        elif kind == "NODE_COMMIT":
            deps = [str(d) for d in rec.meta.get("deps") or ()]
            members = [str(m) for m in rec.meta.get("members") or ()]
            entry = {
                "node": rec.node_id,
                "context_digest": rec.context_digest,
                "input_digest": rec.input_digest,
                "output_digest": rec.output_digest,
                "ref": rec.ref,
                "deps": deps,
                "members": members,
                "volatile": bool(rec.meta.get("volatile")),
                "stream": int(rec.meta.get("stream") or 0),
            }
            self._entries[rec.node_id] = entry
            for m in members:
                self._member_of[m] = rec.node_id
            if rec.output_digest:
                seen = self._produced.setdefault(rec.output_digest, [])
                if rec.node_id not in seen:
                    seen.append(rec.node_id)
        elif kind == "CHUNK_COMMIT":
            self._chunks[rec.node_id] = self._chunks.get(rec.node_id, 0) + 1
        elif kind == "STREAM_EOS":
            self._eos.add(rec.node_id)
        elif kind == "CACHE_HIT":
            self._cache_hits[rec.node_id] = self._cache_hits.get(rec.node_id, 0) + 1
        elif kind == "SUSPEND":
            self._pending_suspend = rec.node_id
        elif kind == "RESUME":
            self._resumes.append(
                {"node": rec.node_id, "keys": sorted(rec.meta.get("inputs") or {})}
            )
            if self._pending_suspend == rec.node_id:
                self._pending_suspend = None
        # every other kind (RUN_START/END, NODE_START, FORK, ...) is run
        # activity, not provenance — ignored by the projection

    # -- queries -------------------------------------------------------------
    def nodes(self) -> List[str]:
        """All node ids with a committed entry, sorted."""
        return sorted(self._entries)

    def entry(self, node_id: str) -> Optional[Dict[str, Any]]:
        """Latest committed identity for ``node_id`` (member ids resolve
        to their union group's entry), or None if never committed."""
        e = self._entries.get(node_id)
        if e is None and node_id in self._member_of:
            e = self._entries.get(self._member_of[node_id])
        return dict(e) if e is not None else None

    def produced(self, output_digest: str) -> List[str]:
        """Node ids that committed an output with this digest, in order."""
        return list(self._produced.get(output_digest, ()))

    def consumers(self, node_id: str) -> List[str]:
        """Nodes whose declared deps include ``node_id``, sorted."""
        return sorted(
            n for n, e in self._entries.items() if node_id in e["deps"]
        )

    def provenance(
        self, node_id: str, depth: Optional[int] = None
    ) -> Dict[str, Any]:
        """Bounded upstream provenance tree for ``node_id``.

        Recurses through declared ``deps`` up to ``depth`` levels
        (``None`` = unbounded but cycle-safe). Frontier nodes beyond the
        bound carry ``"truncated": True``; deps with no committed entry
        carry ``"missing": True``.
        """
        return self._provenance(node_id, depth, set())

    def _provenance(
        self, node_id: str, depth: Optional[int], seen: Set[str]
    ) -> Dict[str, Any]:
        entry = self.entry(node_id)
        if entry is None:
            return {"node": node_id, "missing": True}
        group = self._member_of.get(node_id)
        node: Dict[str, Any] = {
            "node": node_id,
            "context_digest": entry["context_digest"],
            "input_digest": entry["input_digest"],
            "output_digest": entry["output_digest"],
        }
        if group is not None:
            node["group"] = group
        if entry["stream"]:
            node["chunks"] = self._chunks.get(node_id, 0)
            node["eos"] = node_id in self._eos
        if self._cache_hits.get(node_id):
            node["cache_hits"] = self._cache_hits[node_id]
        resolved = entry["node"]  # group id for members
        if resolved in seen or node_id in seen:
            node["cycle"] = True
            return node
        if depth is not None and depth <= 0:
            if entry["deps"]:
                node["truncated"] = True
            return node
        sub_depth = None if depth is None else depth - 1
        sub_seen = seen | {node_id, resolved}
        node["deps"] = [
            self._provenance(d, sub_depth, sub_seen) for d in entry["deps"]
        ]
        return node

    def resumes(self) -> List[Dict[str, Any]]:
        """Interrupt answers applied over the journal's history, in order."""
        return [dict(r) for r in self._resumes]

    def pending_suspend(self) -> Optional[str]:
        """Node id of the latest unanswered SUSPEND, if any."""
        return self._pending_suspend

    def to_obj(self) -> Dict[str, Any]:
        """Canonical plain-dict form of the full projection state.

        Used by the projection-determinism property test (rebuilt ==
        incremental) and the CLI ``--json`` output.
        """
        return {
            "header": dict(self._header) if self._header else None,
            "entries": {n: dict(e) for n, e in sorted(self._entries.items())},
            "chunks": dict(sorted(self._chunks.items())),
            "eos": sorted(self._eos),
            "member_of": dict(sorted(self._member_of.items())),
            "cache_hits": dict(sorted(self._cache_hits.items())),
            "produced": {d: list(ns) for d, ns in sorted(self._produced.items())},
            "resumes": [dict(r) for r in self._resumes],
            "pending_suspend": self._pending_suspend,
        }
