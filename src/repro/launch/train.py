"""Production training CLI: ``python -m repro.launch.train --arch <id>``.

Selects an assigned architecture config, optionally reduced for local
hardware, and runs the SerPyTor durable trainer (journal + checkpoints +
heartbeat + elastic mesh). On a real TPU pod this is the per-host entry
point; in this container it runs the reduced config on CPU.
"""
from __future__ import annotations

import argparse

from repro.configs import SHAPES, get_config, list_archs, smoke_variant
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_archs()))
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--run-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced same-family config (CPU container)")
    ap.add_argument("--full", dest="reduced", action="store_false",
                    help="use the full published config (real hardware)")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--journal-sync", default="batch",
                    choices=["always", "batch", "never"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = smoke_variant(cfg)
        batch = args.batch or 2
        seq = args.seq or 64
    else:
        batch = args.batch or shape.global_batch
        seq = args.seq or shape.seq_len

    run_dir = args.run_dir or f"runs/{cfg.name}"
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {batch}×{seq} → {run_dir}")
    tc = TrainConfig(run_dir=run_dir, num_steps=args.steps,
                     checkpoint_every=args.checkpoint_every,
                     global_batch=batch, seq_len=seq,
                     journal_sync=args.journal_sync,
                     opt=AdamWConfig(lr=3e-4, warmup_steps=10,
                                     total_steps=args.steps))
    out = Trainer(cfg, tc).train()
    print(f"done: {out['steps']} steps, {out['steps_per_s']:.2f} steps/s, "
          f"final loss {out['final_loss']}")


if __name__ == "__main__":
    main()
