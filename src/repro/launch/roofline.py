import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")

_DOC = """Roofline analysis with LOOP-CORRECTED HLO costs.

XLA's cost_analysis counts a while-loop (lax.scan) body ONCE regardless of
trip count, so full-depth compiles under-report FLOPs / bytes / collective
traffic for scanned layer stacks. Correction: probe the model at small
depths where segments are UNROLLED (run_stack unrolls ≤4 repeats), fit the
exact linear model

    cost(R_1..R_k) = base + Σ_j slope_j · R_j       (R_j = segment repeats)

from k+1 probe compiles (all-ones, then 2 for each segment in turn), and
evaluate at the true depths. All numbers come from real compiled HLO of the
real sharded program — no hand modeling; the analytic 6·N·D is reported
alongside as the "useful FLOPs" numerator.

Terms (TPU v5e, per chip):
    compute_s   = HLO_FLOPs / 197e12
    memory_s    = HLO_bytes_accessed / 819e9
    collective_s = collective_bytes / 50e9      (single-link conservative)

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --all
  PYTHONPATH=src python -m repro.launch.roofline --arch yi-6b --shape train_4k
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import sys
import traceback
from typing import Any, Dict, List, Optional, Tuple


from repro.configs import SHAPES, cell_applicability, get_config, list_archs
from repro.launch.dryrun import (RESULTS_DIR, arch_run_defaults, lower_cell,
                                 model_flops)
from repro.launch.mesh import HW
from repro.models.transformer import derive_segments, layer_pattern
from repro.optim.adamw import AdamWConfig
from repro.sharding.specs import ShardingOptions

ROOFLINE_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "roofline")


# --------------------------------------------------------------------------
# probe-config construction
# --------------------------------------------------------------------------

def probe_cfg(cfg, seg_repeats: List[int], enc_layers: Optional[int] = None):
    """Rebuild cfg with each segment's repeats overridden (pattern-level)."""
    segments = derive_segments(layer_pattern(cfg))
    assert len(seg_repeats) == len(segments)
    pattern: List[str] = []
    for (unit, _), r in zip(segments, seg_repeats, strict=True):
        pattern.extend(list(unit) * r)
    kw: Dict[str, Any] = dict(block_pattern=tuple(pattern),
                              num_layers=len(pattern))
    if cfg.first_k_dense:
        kw["first_k_dense"] = sum(1 for k in pattern if k == "dense")
    if cfg.is_encdec and enc_layers is not None:
        kw["encoder_layers"] = enc_layers
    return dataclasses.replace(cfg, **kw)


def true_repeats(cfg) -> Tuple[List[int], int]:
    segments = derive_segments(layer_pattern(cfg))
    return [r for _, r in segments], cfg.encoder_layers


# --------------------------------------------------------------------------
# cost extraction
# --------------------------------------------------------------------------

def extract_costs(rec: Dict[str, Any]) -> Dict[str, float]:
    cost = rec.get("cost", {})
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(rec["collectives"]["total_bytes"]),
        **{f"coll_{k}": float(v) for k, v in
           rec["collectives"]["bytes_per_kind"].items()},
    }


def fit_linear(samples: List[Tuple[List[int], Dict[str, float]]],
               targets: List[int]) -> Dict[str, float]:
    """samples: [(repeat-vector, costs)]; first sample must be all-ones and
    sample j+1 must differ only in segment j (=2)."""
    ones_costs = samples[0][1]
    k = len(samples) - 1
    keys = set()
    for _, c in samples:
        keys.update(c)
    out: Dict[str, float] = {}
    for key in keys:
        c0 = ones_costs.get(key, 0.0)
        slopes = [samples[j + 1][1].get(key, 0.0) - c0 for j in range(k)]
        base = c0 - sum(slopes)
        total = base + sum(s * t for s, t in zip(slopes, targets, strict=True))
        # tiny cells can fit negative slopes (XLA optimizes the 2-deep probe
        # differently than the 1-deep one); clamp to the measured floor —
        # the fit is only meaningful when cost actually scales with depth.
        out[key] = max(total, c0, 0.0)
        out[f"{key}__slope"] = sum(slopes)
        out[f"{key}__base"] = base
    return out


# --------------------------------------------------------------------------
# the analysis
# --------------------------------------------------------------------------

def analyze_cell(arch: str, shape_name: str,
                 options: Optional[ShardingOptions] = None,
                 opt_cfg: Optional[AdamWConfig] = None,
                 cfg_override=None,
                 tag: str = "") -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    runnable, why = cell_applicability(cfg, shape)
    if not runnable:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    defaults = arch_run_defaults(arch)
    if options is None:
        options = ShardingOptions(**defaults["options"])
    if opt_cfg is None:
        opt_cfg = AdamWConfig(**defaults["opt"])

    repeats, enc_layers = true_repeats(cfg)
    k = len(repeats)
    probes: List[Tuple[List[int], Optional[int]]] = [([1] * k, 1 if enc_layers else None)]
    for j in range(k):
        vec = [1] * k
        vec[j] = 2
        probes.append((vec, 1 if enc_layers else None))
    if enc_layers:
        probes.append(([1] * k, 2))  # encoder slope

    samples = []
    for vec, enc in probes:
        pcfg = probe_cfg(cfg, vec, enc)
        rec = lower_cell(arch, shape_name, multi_pod=False, options=options,
                         opt_cfg=opt_cfg, cfg=pcfg)
        if rec["status"] != "ok":
            return {"arch": arch, "shape": shape_name, "status": "error",
                    "error": f"probe {vec} failed: {rec.get('error')}"}
        key = vec + ([enc] if enc_layers else [])
        samples.append((key, extract_costs(rec)))

    targets = repeats + ([enc_layers] if enc_layers else [])
    fitted = fit_linear(samples, targets)

    n_dev = 256  # single-pod roofline
    flops = fitted["flops"]              # per-device, loop-corrected
    byts = fitted["bytes"]
    coll = fitted["coll"]
    compute_s = flops / HW.PEAK_FLOPS_BF16
    memory_s = byts / HW.HBM_BW
    collective_s = coll / HW.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_per_dev = mf / n_dev
    useful_ratio = mf_per_dev / max(flops, 1.0)
    step_s = max(terms.values())          # no-overlap bound
    roofline_frac = (mf_per_dev / HW.PEAK_FLOPS_BF16) / max(step_s, 1e-12)

    advice = {
        "compute_s": "reduce non-useful FLOPs (remat policy, dispatch "
                     "overhead, fused kernels) or spread over more chips",
        "memory_s": "cut activation traffic: fused kernels (flash/wkv), "
                    "bf16 intermediates, chunked CE, better layouts",
        "collective_s": "reshard: bigger per-collective payloads, overlap "
                        "with compute, reduce-scatter instead of all-reduce, "
                        "fewer boundary reshards",
    }[dominant]

    rec = {
        "arch": arch, "shape": shape_name, "status": "ok", "tag": tag,
        "mesh": "16x16", "devices": n_dev,
        "options": {"fsdp": options.fsdp, "seq_parallel": options.seq_parallel,
                    "cache_seq_shard": options.cache_seq_shard,
                    "expert_parallel": options.expert_parallel},
        "loop_corrected": {
            "hlo_flops_per_dev": flops,
            "hlo_bytes_per_dev": byts,
            "collective_bytes_per_dev": coll,
            "per_kind": {kk[5:]: vv for kk, vv in fitted.items()
                         if kk.startswith("coll_") and "__" not in kk},
        },
        "terms_s": terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "model_flops_per_dev": mf_per_dev,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
        "advice": advice,
        "probe_count": len(probes),
    }
    return rec


def cell_out_path(arch: str, shape: str, tag: str = "") -> str:
    os.makedirs(ROOFLINE_DIR, exist_ok=True)
    sfx = f".{tag}" if tag else ""
    return os.path.join(ROOFLINE_DIR, f"{arch}__{shape}{sfx}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells: List[Tuple[str, str]]
    if args.all:
        archs = [a for a in list_archs() if a != "serpytor-demo-100m"]
        cells = [(a, s) for a in archs for s in SHAPES]
    else:
        cells = [(args.arch, s) for s in ([args.shape] if args.shape
                                          else list(SHAPES))]

    failures = 0
    for arch, shape in cells:
        path = cell_out_path(arch, shape, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"[skip-cached] {arch} × {shape}")
            continue
        print(f"[roofline] {arch} × {shape} ...", flush=True)
        try:
            rec = analyze_cell(arch, shape, tag=args.tag)
        except Exception as exc:
            failures += 1
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(exc).__name__}: {exc}",
                   "traceback": traceback.format_exc()}
        with open(path, "w") as fh:
            json.dump(rec, fh, indent=1)
        if rec["status"] == "ok":
            t = rec["terms_s"]
            print(f"  compute={t['compute_s']*1e3:.2f}ms "
                  f"memory={t['memory_s']*1e3:.2f}ms "
                  f"collective={t['collective_s']*1e3:.2f}ms "
                  f"dominant={rec['dominant']} "
                  f"roofline_frac={rec['roofline_fraction']:.3f}")
        elif rec["status"] == "skipped":
            print(f"  skipped: {rec['reason'][:70]}")
        else:
            print(f"  ERROR: {rec['error'][:160]}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
