import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")

_DOC = """Performance hillclimb driver (§Perf).

Each ITERATION is (name, hypothesis, mutation of cfg/ShardingOptions/opt);
the driver re-runs the loop-corrected roofline for the cell under the
mutation, diffs the three terms against the previous accepted state, and
appends a structured entry (hypothesis → change → before → after →
confirmed/refuted) to results/perf/<cell>.json. Greedy: a mutation is kept
when it improves the dominant term; refuted mutations are recorded and
reverted.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --cell deepseek-v3-671b:train_4k
  PYTHONPATH=src python -m repro.launch.perf --list
"""
__doc__ = _DOC

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.configs import get_config
from repro.launch.roofline import analyze_cell
from repro.launch.dryrun import arch_run_defaults
from repro.optim.adamw import AdamWConfig
from repro.sharding.specs import ShardingOptions

PERF_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "results", "perf")


@dataclasses.dataclass
class Iteration:
    name: str
    hypothesis: str
    mutate: Callable  # (cfg, options, opt) -> (cfg, options, opt)


def _opt(o, **kw):
    return dataclasses.replace(o, **kw)


# ---------------------------------------------------------------------------
# iteration catalogs per hillclimb cell
# ---------------------------------------------------------------------------
ITERATIONS: Dict[Tuple[str, str], List[Iteration]] = {
    ("deepseek-v3-671b", "train_4k"): [
        Iteration(
            "gshard_einsum_dispatch",
            "PAPER-ERA BASELINE PROBE (expected REGRESSION, kept for the "
            "record): GShard one-hot einsum dispatch costs O(T·S_g·k·cf) "
            "dispatch-matmul FLOPs and a (G,S,E,C) combine tensor; vs the "
            "default shard_map all-to-all engine this should inflate "
            "compute and memory terms by >2x.",
            lambda c, o, a: (dataclasses.replace(c, moe_impl="einsum"), o, a)),
        Iteration(
            "seq_parallel_residuals",
            "Activations between layers are replicated over the 16-way "
            "model axis; the 58 scan-carried residuals (B,S,d) dominate "
            "live memory and the all-gather at each layer boundary is "
            "paid anyway by TP. Sharding the seq dim over `model` between "
            "blocks (sequence parallelism) cuts residual memory ~16x and "
            "converts duplicate math (norms) into sharded math; collective "
            "bytes should not grow (AG moves, does not multiply).",
            lambda c, o, a: (c, _opt(o, seq_parallel=True), a)),
        Iteration(
            "remat_dots_policy",
            "remat='full' recomputes every matmul in the backward pass: "
            "~4/3 FLOPs multiplier on a compute-heavy MoE. Saving matmul "
            "outputs (checkpoint_dots) trades HBM for FLOPs; with seq-"
            "parallel residuals there is memory headroom, so compute term "
            "should drop ~20% while memory term rises.",
            lambda c, o, a: (dataclasses.replace(c, remat="dots"), o, a)),
    ],
    # most collective-bound cell in the baseline table (22.7s coll vs 11.7s mem)
    ("rwkv6-7b", "train_4k"): [
        Iteration(
            "seq_parallel_residuals",
            "RWKV time/channel-mix activations (B,S,d) are model-replicated "
            "between layers; token-shift and WKV operate per-position, so "
            "sharding S over `model` between blocks divides activation "
            "collective payloads by 16. Expect the collective term (the "
            "dominant one) to drop several-fold; WKV itself recomputes "
            "from a gathered slice.",
            lambda c, o, a: (c, _opt(o, seq_parallel=True), a)),
        Iteration(
            "remat_dots_policy",
            "full remat re-runs the FLOP-light but traffic-heavy WKV "
            "chunk scan in bwd, doubling its activation collectives; "
            "checkpoint_dots saves matmul outputs so bwd re-reads instead "
            "of re-communicating — collective and compute terms should "
            "both drop, memory term rises.",
            lambda c, o, a: (dataclasses.replace(c, remat="dots"), o, a)),
    ],
    # worst roofline fraction in the baseline table (0.024)
    ("granite-moe-3b-a800m", "train_4k"): [
        Iteration(
            "gshard_einsum_dispatch",
            "PAPER-ERA BASELINE PROBE (expected REGRESSION): with E=40 "
            "small experts the one-hot dispatch tensor (G,S,E,C) and its "
            "matmuls should inflate compute/memory terms vs the a2a "
            "default; recorded to quantify the a2a engine's win.",
            lambda c, o, a: (dataclasses.replace(c, moe_impl="einsum"), o, a)),
        Iteration(
            "seq_parallel_residuals",
            "d_model=1536 activations over 1M tokens dominate memory for "
            "this small-expert model (params are tiny); sequence-parallel "
            "residuals divide the dominant memory term ~16x.",
            lambda c, o, a: (c, _opt(o, seq_parallel=True), a)),
        Iteration(
            "remat_dots_policy",
            "with activations sequence-sharded there is memory headroom; "
            "checkpoint_dots removes the 4/3 recompute FLOPs and halves "
            "re-communication in bwd.",
            lambda c, o, a: (dataclasses.replace(c, remat="dots"), o, a)),
    ],
}


def run_cell(arch: str, shape: str, only: Optional[str] = None) -> Dict:
    os.makedirs(PERF_DIR, exist_ok=True)
    defaults = arch_run_defaults(arch)
    cfg = get_config(arch)
    options = ShardingOptions(**defaults["options"])
    opt = AdamWConfig(**defaults["opt"])

    def measure(tag, c, o, a):
        rec = analyze_cell(arch, shape, options=o, opt_cfg=a, cfg_override=c,
                           tag=tag)
        assert rec["status"] == "ok", rec
        return rec

    print(f"=== hillclimb {arch} × {shape} ===")
    t0 = time.monotonic()
    baseline = measure("baseline", cfg, options, opt)
    log: List[Dict[str, Any]] = [{"iter": "baseline",
                                  "terms_s": baseline["terms_s"],
                                  "dominant": baseline["dominant"],
                                  "roofline_fraction":
                                      baseline["roofline_fraction"]}]
    print(f"baseline: {baseline['terms_s']} dominant={baseline['dominant']}")

    cur = (cfg, options, opt)
    cur_rec = baseline
    for it in ITERATIONS.get((arch, shape), []):
        if only and only != it.name:
            continue
        c2, o2, a2 = it.mutate(*cur)
        rec = measure(it.name, c2, o2, a2)
        before, after = cur_rec["terms_s"], rec["terms_s"]
        dom = cur_rec["dominant"]
        improved = after[dom] < before[dom] * 0.999 and \
            max(after.values()) <= max(before.values()) * 1.05
        verdict = "confirmed" if improved else "refuted"
        entry = {
            "iter": it.name, "hypothesis": it.hypothesis,
            "before_s": before, "after_s": after,
            "dominant_before": dom, "dominant_after": rec["dominant"],
            "roofline_fraction": rec["roofline_fraction"],
            "verdict": verdict, "kept": improved,
        }
        log.append(entry)
        print(f"[{verdict.upper():9s}] {it.name}: "
              f"{dom} {before[dom]*1e3:.2f}ms → {after[dom]*1e3:.2f}ms; "
              f"step bound {max(before.values())*1e3:.2f} → "
              f"{max(after.values())*1e3:.2f}ms")
        if improved:
            cur = (c2, o2, a2)
            cur_rec = rec

    out = {
        "arch": arch, "shape": shape,
        "baseline": baseline["terms_s"],
        "final": cur_rec["terms_s"],
        "baseline_fraction": baseline["roofline_fraction"],
        "final_fraction": cur_rec["roofline_fraction"],
        "wall_s": time.monotonic() - t0,
        "log": log,
    }
    with open(os.path.join(PERF_DIR, f"{arch}__{shape}.json"), "w") as fh:
        json.dump(out, fh, indent=1)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=[])
    ap.add_argument("--iter", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for (a, s), its in ITERATIONS.items():
            print(f"{a}:{s}")
            for it in its:
                print(f"  - {it.name}")
        return 0
    cells = [tuple(c.split(":")) for c in args.cell] or list(ITERATIONS)
    for arch, shape in cells:
        out = run_cell(arch, shape, only=args.iter)
        print(f"=> {arch}×{shape}: roofline fraction "
              f"{out['baseline_fraction']:.3f} → {out['final_fraction']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
