"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations


import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""

    PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
    HBM_BW = 819e9                # per chip, B/s
    ICI_BW = 50e9                 # per link, B/s (~50 GB/s/link)
    HBM_BYTES = 16 * 2 ** 30      # 16 GiB per chip
    CHIPS_PER_POD = 256


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Degenerate mesh over the local device set (CPU tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))
