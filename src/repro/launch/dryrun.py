import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count on first initialization). See MULTI-POD DRY-RUN step 0.

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each runnable cell this script:
  1. builds the model and sharding rules over the production mesh,
  2. jits the right step (train_step / prefill_step / decode_step) with
     explicit in/out shardings,
  3. ``.lower(...).compile()`` — proving the distribution config is coherent,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     into a JSON cache (results/dryrun/<cell>.json), incrementally (resume-
     safe: completed cells are skipped on rerun — the dry-run loop itself is
     durable, in the spirit of the paper).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
__doc__ = _DOC

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import (SHAPES, cell_applicability, get_config, input_specs,
                           list_archs)
from repro.launch.mesh import HW, make_production_mesh
from repro.models import build
from repro.optim.adamw import AdamWConfig
from repro.sharding.specs import ShardingOptions, ShardingRules
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

_COLLECTIVE_RE = re.compile(
    r"(\ball-gather(?:-start)?|\ball-reduce(?:-start)?|\breduce-scatter"
    r"|\ball-to-all|\bcollective-permute(?:-start)?)\b")
_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|s64|u32|s8|u8|pred|s16|u16)"
                       r"\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "s64": 8,
          "u32": 4, "s8": 1, "u8": 1, "pred": 1, "s16": 2, "u16": 2}


_COLLECTIVE_OPS = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute", "all-gather-start", "all-reduce-start",
                   "collective-permute-start"}


def parse_collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result bytes of every collective op in optimized HLO.

    Matches on the OPCODE position (the token right before the first '('
    on the rhs) — matching anywhere in the line would also hit operand
    references like ``get-tuple-element(%all-reduce.109)`` and double-count.
    """
    per_kind: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "=" not in line or "(" not in line:
            continue
        rhs = line.split("=", 1)[1]
        paren = rhs.find("(")
        # tuple-typed results start with '(' immediately: the opcode is after
        # the closing paren of the type. Find the first '(' PRECEDED by an
        # opcode token instead: scan tokens.
        head, _, _ = rhs.partition("(")
        opcode = head.strip().split()[-1] if head.strip() else ""
        if opcode not in _COLLECTIVE_OPS:
            # tuple-typed result: "(f32[..], f32[..]) all-reduce(...)"
            m = re.match(r"\s*\((?:[^()]|\([^()]*\))*\)\s*([a-z0-9-]+)\(", rhs)
            if m is None or m.group(1) not in _COLLECTIVE_OPS:
                continue
            opcode = m.group(1)
            head = rhs[: m.start(1)]
        kind = opcode.replace("-start", "")
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(head):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES.get(dt, 4)
        if nbytes:
            per_kind[kind] = per_kind.get(kind, 0) + nbytes
            counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_per_kind": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


def model_flops(cfg, shape) -> float:
    """Analytic 6·N·D (active N for MoE); decode D = batch tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _mem_analysis_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0) or 0)
    return out


def _cost_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or k in ("utilization",))}
    except Exception:
        return {}


def arch_run_defaults(arch: str) -> Dict[str, Any]:
    """Per-arch distribution defaults (documented in EXPERIMENTS.md §Dry-run).

    - granite-moe: 40 experts don't divide the 16-way model axis → tensor-
      parallel the expert FFN dim instead of EP (expert_parallel=False).
    - deepseek-v3: AdamW m/v in bf16 — f32 states (5.4 TB) cannot fit 512
      v5e chips; bf16 states + f32 master-free update is the documented
      memory mode for this config.
    """
    out: Dict[str, Any] = {"options": {}, "opt": {}}
    if arch == "granite-moe-3b-a800m":
        out["options"]["expert_parallel"] = False
    if arch == "deepseek-v3-671b":
        out["opt"]["state_dtype"] = "bfloat16"
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               options: Optional[ShardingOptions] = None,
               opt_cfg: Optional[AdamWConfig] = None,
               want_hlo: bool = False,
               cfg=None) -> Dict[str, Any]:
    """Lower+compile one cell; returns the result record."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    runnable, why = cell_applicability(cfg, shape)
    if not runnable:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    defaults = arch_run_defaults(arch)
    if options is None:
        options = ShardingOptions(**defaults["options"])
    if opt_cfg is None:
        opt_cfg = AdamWConfig(**defaults["opt"])
    rules = ShardingRules(cfg, mesh, options)
    model = build(cfg)
    t0 = time.monotonic()

    captured: Dict[str, Any] = {}

    def _init_params_only(r):
        p, ax = model.init(r)
        captured["axes"] = ax  # static side product, captured during trace
        return p

    param_shapes = jax.eval_shape(_init_params_only, jax.random.key(0))
    axes = captured["axes"]
    param_sh = rules.param_sharding_tree(axes, param_shapes)
    params_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        param_shapes, param_sh)
    batch_sds = input_specs(cfg, shape)
    batch_sh = rules.batch_spec(batch_sds)
    batch_sds = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        batch_sds, batch_sh)

    with mesh:
        rules.install()
        try:
            if shape.kind == "train":
                from repro.train.steps import make_opt_init

                opt_shapes = jax.eval_shape(make_opt_init(model, opt_cfg),
                                            param_shapes)
                opt_sh = {"m": param_sh, "v": param_sh,
                          "step": rules.replicated()}
                opt_sds = jax.tree.map(
                    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                       sharding=sh),
                    opt_shapes, {"m": jax.tree.map(lambda x: x, opt_sh["m"]),
                                 "v": opt_sh["v"], "step": opt_sh["step"]})
                step = make_train_step(model, opt_cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(param_sh, {"m": opt_sh["m"], "v": opt_sh["v"],
                                             "step": opt_sh["step"]}, batch_sh),
                    out_shardings=(param_sh,
                                   {"m": opt_sh["m"], "v": opt_sh["v"],
                                    "step": opt_sh["step"]}, None),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(params_sds, opt_sds, batch_sds)
            elif shape.kind == "prefill":
                step = make_prefill_step(model)
                jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
                lowered = jitted.lower(params_sds, batch_sds)
            else:  # decode
                cache_shapes = jax.eval_shape(
                    lambda: model.init_cache(shape.global_batch, shape.seq_len))
                cache_sh = rules.cache_sharding_tree(cache_shapes)
                cache_sds = jax.tree.map(
                    lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                       sharding=sh),
                    cache_shapes, cache_sh)
                step = make_decode_step(model)
                jitted = jax.jit(step,
                                 in_shardings=(param_sh, cache_sh, batch_sh),
                                 out_shardings=(None, cache_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_sds, cache_sds, batch_sds)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
        finally:
            rules.uninstall()

    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    mem = _mem_analysis_dict(compiled)
    cost = _cost_analysis_dict(compiled)
    n_dev = mesh.size
    hbm_per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "multi_pod": multi_pod,
        "mesh": dict(zip(mesh.axis_names,
                         np.array(mesh.devices.shape).tolist(), strict=True)),
        "devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem, "cost": cost, "collectives": coll,
        "hbm_per_device_gib": hbm_per_dev / 2 ** 30,
        "fits_hbm": bool(hbm_per_dev <= HW.HBM_BYTES),
        "model_flops_analytic": model_flops(cfg, SHAPES[shape_name]),
        "options": {
            "fsdp": options.fsdp, "seq_parallel": options.seq_parallel,
            "cache_seq_shard": options.cache_seq_shard,
            "expert_parallel": options.expert_parallel,
            "overrides": list(options.logical_overrides),
        },
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    if want_hlo:
        rec["hlo_text"] = hlo
    return rec


def cell_path(arch: str, shape: str, multi_pod: bool, tag: str = "") -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "mp" if multi_pod else "sp"
    tag = f".{tag}" if tag else ""
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{suffix}{tag}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--cache-seq-shard", default="auto")
    ap.add_argument("--moe-impl", default=None, choices=[None, "einsum", "sort"])
    args = ap.parse_args()

    if args.all:
        archs = [a for a in list_archs() if a != "serpytor-demo-100m"]
        shapes = list(SHAPES)
    else:
        archs = [args.arch]
        shapes = [args.shape] if args.shape else list(SHAPES)

    custom = args.seq_parallel or args.no_fsdp or args.cache_seq_shard != "auto"
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                path = cell_path(arch, shape, multi_pod, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {arch} × {shape} "
                          f"({'2x16x16' if multi_pod else '16x16'})")
                    continue
                label = f"{arch} × {shape} ({'2x16x16' if multi_pod else '16x16'})"
                print(f"[lower] {label} ...", flush=True)
                try:
                    defaults = arch_run_defaults(arch)
                    opts = None
                    if custom:
                        kw = dict(defaults["options"])
                        kw.update(fsdp=not args.no_fsdp,
                                  seq_parallel=args.seq_parallel,
                                  cache_seq_shard=args.cache_seq_shard)
                        opts = ShardingOptions(**kw)
                    cfg = get_config(arch)
                    if args.moe_impl and cfg.num_experts:
                        import dataclasses

                        cfg = dataclasses.replace(cfg, moe_impl=args.moe_impl)
                    rec = lower_cell(arch, shape, multi_pod=multi_pod,
                                     options=opts, cfg=cfg)
                except Exception as exc:
                    failures += 1
                    rec = {"arch": arch, "shape": shape, "status": "error",
                           "multi_pod": multi_pod,
                           "error": f"{type(exc).__name__}: {exc}",
                           "traceback": traceback.format_exc()}
                    print(f"[FAIL] {label}: {exc}")
                with open(path, "w") as fh:
                    json.dump(rec, fh, indent=1)
                if rec["status"] == "ok":
                    print(f"[ok] {label}: compile={rec['compile_s']}s "
                          f"hbm/dev={rec['hbm_per_device_gib']:.2f}GiB "
                          f"coll={rec['collectives']['total_bytes']/2**30:.2f}GiB")
                    print("  memory_analysis:", rec["memory"])
                    print("  cost_analysis:", {k: v for k, v in
                                               rec["cost"].items()
                                               if "flops" in k or "bytes" in k})
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
