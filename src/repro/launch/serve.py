"""Production serving CLI: gateway + workers over real HTTP transport.

``python -m repro.launch.serve --arch qwen3-1.7b --requests 8`` spins up N
WorkerServers (each: app port + heartbeat port, reduced model replica),
routes generation requests through the Gateway with context affinity, and
reports latency/throughput + the system/application health split.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs, smoke_variant
from repro.core import (Context, Gateway, TaskRegistry, WorkerClient,
                        WorkerServer)
from repro.models import build


def build_registry(cfg, model, params) -> TaskRegistry:
    reg = TaskRegistry()
    decode = jax.jit(model.decode_step)

    @reg.task("generate")
    def generate(ctx, prompt, new_tokens):
        toks = jnp.asarray(np.asarray(prompt, np.int32))[None, :]
        S = toks.shape[1]
        logits, cache = model.prefill(params, {"tokens": toks},
                                      pad_to=S + int(new_tokens))
        tok = jnp.argmax(logits, axis=-1)
        out = []
        for _ in range(int(new_tokens)):
            out.append(int(tok[0]))
            logits, cache = decode(params, cache, {"token": tok})
            tok = jnp.argmax(logits, axis=-1)
        return {"tokens": out}

    @reg.task("health")
    def health(ctx):
        return {"params_mb": sum(x.size * x.dtype.itemsize
                                 for x in jax.tree.leaves(params)) / 2**20}

    return reg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list(list_archs()))
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve CLI supports text decoder archs; "
                         "use examples/serve_lm.py patterns for multimodal")
    model = build(cfg)
    params, _ = model.init(jax.random.key(0))
    print(f"serving {cfg.name} ({cfg.param_count()/1e6:.1f}M reduced) on "
          f"{args.workers} HTTP workers")

    servers = [WorkerServer(f"w{i}", build_registry(cfg, model, params)).start()
               for i in range(args.workers)]
    clients = [WorkerClient(s.name, s.address, s.heartbeat_server.address,
                            timeout=300) for s in servers]
    try:
        rng = np.random.default_rng(0)
        with Gateway(clients,
                     allocation=("context_affinity", "least_loaded")) as gw:
            t0 = time.monotonic()
            futs = [gw.submit("generate",
                              Context.origin({"session": f"s{i}"}),
                              {"prompt": rng.integers(
                                  0, cfg.vocab_size,
                                  args.prompt_len).tolist(),
                               "new_tokens": args.new_tokens},
                              affinity_key=f"s{i % 2}")
                    for i in range(args.requests)]
            outs = [f.result(timeout=600) for f in futs]
            wall = time.monotonic() - t0
        tok = sum(len(o["tokens"]) for o in outs)
        print(f"{args.requests} requests / {tok} tokens in {wall:.2f}s "
              f"({tok/wall:.1f} tok/s); alloc {gw.mean_alloc_us():.1f}µs")
        hb = clients[0].heartbeat()
        print(f"worker w0 heartbeat: ok={hb['ok']} "
              f"cpu={hb['cpu']['used_frac']:.2f}")
    finally:
        for s in servers:
            s.stop()


if __name__ == "__main__":
    main()
