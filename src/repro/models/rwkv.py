"""RWKV6 "Finch" blocks: data-dependent token-shift + WKV6 + channel mix.

Attention-free: per-layer state = (wkv state (B,H,K,V), time-mix shift x_prev
(B,d), channel-mix shift x_prev (B,d)) — O(1) in sequence length, which is
what makes the long_500k decode shape runnable for this family.

Decay contract: per-step log decay is clamped to [-4, -1e-4] before the WKV
op (see kernels/ref.wkv6_chunked_ref range analysis).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import ParamStore, dense, shard_activation

__all__ = ["init_rwkv_layer", "rwkv_time_mix", "rwkv_channel_mix",
           "init_rwkv_state"]

_LOGW_MIN, _LOGW_MAX = -4.0, -1e-4


def init_rwkv_layer(store: ParamStore, name: str, cfg) -> None:
    sub = store.sub(name)
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    dl, ml = cfg.rwkv_decay_lora, cfg.rwkv_mix_lora

    tm = sub.sub("time_mix")
    # static token-shift mixing coefficients (one per stream r,k,v,w,g)
    for s in ("r", "k", "v", "w", "g"):
        tm.param(f"mu_{s}", (d,), ("embed",), init="zeros")
    tm.param("mu_x", (d,), ("embed",), init="zeros")
    # data-dependent mixing LoRA (maps shifted x → per-stream corrections)
    tm.param("mix_a", (d, ml * 5), ("embed", None), scale=0.02)
    tm.param("mix_b", (ml * 5, d * 5), (None, "embed"), scale=0.02)
    # projections
    tm.param("wr", (d, d), ("embed", "heads"))
    tm.param("wk", (d, d), ("embed", "heads"))
    tm.param("wv", (d, d), ("embed", "heads"))
    tm.param("wg", (d, d), ("embed", "heads"))
    tm.param("wo", (d, d), ("heads", "embed"))
    # data-dependent decay LoRA + static decay + bonus
    tm.param("w0", (d,), ("embed",), init="zeros")
    tm.param("decay_a", (d, dl), ("embed", None), scale=0.02)
    tm.param("decay_b", (dl, d), (None, "embed"), scale=0.02)
    tm.param("u", (H, hs), ("heads", None), init="normal", scale=0.5)
    tm.sub("ln_x").param("scale", (d,), ("embed",), init="ones")  # per-head GN≈LN

    cm = sub.sub("channel_mix")
    cm.param("mu_r", (d,), ("embed",), init="zeros")
    cm.param("mu_k", (d,), ("embed",), init="zeros")
    cm.param("wk", (d, cfg.d_ff), ("embed", "mlp"))
    cm.param("wv", (cfg.d_ff, d), ("mlp", "embed"))
    cm.param("wr", (d, d), ("embed", "heads"))


def init_rwkv_state(cfg, batch: int, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    return {"wkv": jnp.zeros((batch, H, hs, hs), jnp.float32),
            "tm_prev": jnp.zeros((batch, d), dtype),
            "cm_prev": jnp.zeros((batch, d), dtype)}


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} stream: zeros (or carried state) at t=0."""
    B, T, d = x.shape
    first = jnp.zeros((B, 1, d), x.dtype) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1, :]], axis=1)


def rwkv_time_mix(x: jax.Array, p: Dict[str, Any], cfg, *,
                  state: Optional[Dict[str, Any]] = None
                  ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    B, T, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    tm = p["time_mix"]
    prev = state["tm_prev"] if state is not None else None
    xs = _token_shift(x, prev)
    dx = xs - x

    # data-dependent mixing (DDLerp of Finch)
    base = x + dx * tm["mu_x"]
    lora = jnp.tanh(dense(base, tm["mix_a"]))                    # (B,T,5*ml)
    corr = dense(lora, tm["mix_b"]).reshape(B, T, 5, d)          # (B,T,5,d)
    streams = {}
    for i, s in enumerate(("r", "k", "v", "w", "g")):
        mix = tm[f"mu_{s}"] + corr[:, :, i, :]
        streams[s] = x + dx * mix

    r = dense(streams["r"], tm["wr"]).reshape(B, T, H, hs)
    k = dense(streams["k"], tm["wk"]).reshape(B, T, H, hs)
    v = dense(streams["v"], tm["wv"]).reshape(B, T, H, hs)
    g = dense(streams["g"], tm["wg"])
    logw = tm["w0"] + dense(jnp.tanh(dense(streams["w"], tm["decay_a"])),
                            tm["decay_b"])
    logw = -jnp.exp(jnp.clip(logw.astype(jnp.float32), -20.0, 1.3863))  # ≤ e^1.386=4
    logw = jnp.clip(logw, _LOGW_MIN, _LOGW_MAX)
    w = jnp.exp(logw).reshape(B, T, H, hs)

    # (B,H,T,·) for the kernel
    rk = jnp.moveaxis(r, 2, 1)
    kk = jnp.moveaxis(k, 2, 1)
    vk = jnp.moveaxis(v, 2, 1)
    wk_ = jnp.moveaxis(w, 2, 1).astype(jnp.float32)
    rk = shard_activation(rk, "heads_bhsd")
    s0 = state["wkv"] if state is not None else None
    out, s_new = ops.wkv6(rk, kk, vk, wk_, p["time_mix"]["u"], initial_state=s0,
                          impl=cfg.attn_impl)
    out = jnp.moveaxis(out, 1, 2).reshape(B, T, d)

    # per-head group norm (ln_x) then gate
    outf = out.astype(jnp.float32).reshape(B, T, H, hs)
    mu = outf.mean(-1, keepdims=True)
    var = outf.var(-1, keepdims=True)
    outf = (outf - mu) * jax.lax.rsqrt(var + 64e-5)
    out = (outf.reshape(B, T, d) * tm["ln_x"]["scale"].astype(jnp.float32)
           ).astype(x.dtype)
    out = out * jax.nn.silu(g)
    out = dense(out, tm["wo"])
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["wkv"] = s_new
        new_state["tm_prev"] = x[:, -1, :]
    return out, new_state


def rwkv_channel_mix(x: jax.Array, p: Dict[str, Any], cfg, *,
                     state: Optional[Dict[str, Any]] = None
                     ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    cm = p["channel_mix"]
    prev = state["cm_prev"] if state is not None else None
    xs = _token_shift(x, prev)
    dx = xs - x
    xk = x + dx * cm["mu_k"]
    xr = x + dx * cm["mu_r"]
    hidden = jnp.square(jax.nn.relu(dense(xk, cm["wk"])))
    hidden = shard_activation(hidden, "mlp_bsf")
    out = jax.nn.sigmoid(dense(xr, cm["wr"])) * dense(hidden, cm["wv"])
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["cm_prev"] = x[:, -1, :]
    return out, new_state
