"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU with gating.

Recurrent block (temporal mixing):
    x → [W_in gate-branch → GeLU] ⊙ [W_in rec-branch → conv1d(w=4) → RG-LRU]
      → W_out
RG-LRU:
    r_t = σ(W_a ξ + b_a);  i_t = σ(W_x ξ + b_x)
    a_t = exp(c · softplus(Λ) · (−r_t))          (a = σ(Λ)^{c·r} in the paper;
                                                  identical parameterization)
    h_t = a_t ⊙ h_{t-1} + sqrt(1−a_t²) ⊙ (i_t ⊙ ξ_t)

Per-layer decode state: (h (B, lru_width) f32, conv tail (B, w−1, lru_width)).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import ParamStore, dense, shard_activation

__all__ = ["init_recurrent_block", "recurrent_block", "init_rglru_state"]

_C = 8.0  # Griffin's fixed temperature


def init_recurrent_block(store: ParamStore, name: str, cfg) -> None:
    sub = store.sub(name)
    d, w = cfg.d_model, cfg.lru_width
    sub.param("w_in_rec", (d, w), ("embed", "lru"))
    sub.param("w_in_gate", (d, w), ("embed", "lru"))
    sub.param("conv_w", (cfg.conv1d_width, w), (None, "lru"), scale=0.3)
    sub.param("conv_b", (w,), ("lru",), init="zeros")
    sub.param("lambda_", (w,), ("lru",), init="normal", scale=1.0)
    sub.param("w_a", (w, w), ("lru", "lru"))
    sub.param("b_a", (w,), ("lru",), init="zeros")
    sub.param("w_x", (w, w), ("lru", "lru"))
    sub.param("b_x", (w,), ("lru",), init="zeros")
    sub.param("w_out", (w, d), ("lru", "embed"))


def init_rglru_state(cfg, batch: int, dtype) -> Dict[str, Any]:
    w = cfg.lru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}


def _causal_conv1d(x: jax.Array, weight: jax.Array, bias: jax.Array,
                   tail: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B,T,W); weight: (K,W). Returns (y, new_tail)."""
    B, T, W = x.shape
    K = weight.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, W), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)            # (B, T+K-1, W)
    y = jnp.zeros((B, T, W), jnp.float32)
    for i in range(K):  # K is tiny (4): unrolled taps, no conv primitive needed
        y = y + xp[:, i: i + T, :].astype(jnp.float32) * weight[i].astype(jnp.float32)
    y = (y + bias.astype(jnp.float32)).astype(x.dtype)
    return y, xp[:, T:, :]


def recurrent_block(x: jax.Array, p: Dict[str, Any], cfg, *,
                    state: Optional[Dict[str, Any]] = None
                    ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    B, T, d = x.shape
    gate = jax.nn.gelu(dense(x, p["w_in_gate"]))
    xi = dense(x, p["w_in_rec"])
    xi = shard_activation(xi, "lru_bsw")
    tail = state["conv"] if state is not None else None
    xi, new_tail = _causal_conv1d(xi, p["conv_w"], p["conv_b"], tail)

    r = jax.nn.sigmoid(dense(xi, p["w_a"], p["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(xi, p["w_x"], p["b_x"]).astype(jnp.float32))
    log_a_base = -_C * jax.nn.softplus(p["lambda_"].astype(jnp.float32))  # (W,)
    a = jnp.exp(log_a_base[None, None, :] * r)        # (B,T,W) in (0,1)
    gated_in = (i * xi.astype(jnp.float32)).astype(x.dtype)

    h0 = state["h"] if state is not None else None
    h, h_last = ops.rglru(gated_in, a.astype(jnp.float32), initial_state=h0,
                          impl=cfg.attn_impl)
    out = dense(h.astype(x.dtype) * gate, p["w_out"])
    new_state = None
    if state is not None:
        new_state = {"h": h_last, "conv": new_tail}
    return out, new_state
