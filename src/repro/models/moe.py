"""Mixture-of-Experts: top-k router + two dispatch engines.

``einsum`` (GShard/Switch baseline): group tokens, build one-hot dispatch /
combine tensors, expert compute via einsum. GSPMD turns the group→expert
resharding into all-to-all. Capacity-bounded with token dropping.

``sort`` (beyond-paper optimized): sort token-assignments by expert id and
gather into capacity slots — no one-hot matmul FLOPs. Same capacity/drop
semantics; used in the §Perf hillclimb.

Both engines share the router (softmax top-k, optional shared experts,
load-balance aux loss) so they are numerically interchangeable when no
tokens are dropped.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import ParamStore, dense, shard_activation

__all__ = ["init_moe", "moe_block"]


def init_moe(store: ParamStore, name: str, cfg) -> None:
    sub = store.sub(name)
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    sub.param("router", (d, E), ("embed", None), scale=0.02)
    e = sub.sub("experts")
    e.param("w_gate", (E, d, ff), ("experts", "embed", "moe_mlp"))
    e.param("w_up", (E, d, ff), ("experts", "embed", "moe_mlp"))
    e.param("w_down", (E, ff, d), ("experts", "moe_mlp", "embed"))
    if cfg.num_shared_experts:
        s = sub.sub("shared")
        sff = ff * cfg.num_shared_experts
        s.param("w_gate", (d, sff), ("embed", "mlp"))
        s.param("w_up", (d, sff), ("embed", "mlp"))
        s.param("w_down", (sff, d), ("mlp", "embed"))


def _router(x_flat: jax.Array, p: Dict[str, Any], cfg
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x_flat (T, d) → (weights (T,k), expert_idx (T,k), aux_loss scalar)."""
    logits = dense(x_flat, p["router"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)  # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E · Σ_e f_e · P_e
    E = cfg.num_experts
    f = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    P = probs.mean(0)
    aux = E * jnp.sum(f * P) * cfg.router_aux_coef
    return weights.astype(x_flat.dtype), idx, aux


def _expert_ffn(h: jax.Array, ep: Dict[str, Any], cfg) -> jax.Array:
    """h: (E, C, d) → (E, C, d), batched per-expert GLU FFN."""
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
    gate = jnp.einsum("ecd,edf->ecf", h, ep["w_gate"],
                      preferred_element_type=jnp.float32).astype(h.dtype)
    up = jnp.einsum("ecd,edf->ecf", h, ep["w_up"],
                    preferred_element_type=jnp.float32).astype(h.dtype)
    mid = actf(gate) * up
    mid = shard_activation(mid, "moe_ecf")
    return jnp.einsum("ecf,efd->ecd", mid, ep["w_down"],
                      preferred_element_type=jnp.float32).astype(h.dtype)


# --------------------------------------------------------------------------
# engine 1: GShard one-hot einsum dispatch (baseline)
# --------------------------------------------------------------------------

def _moe_einsum(x_flat, weights, idx, p, cfg):
    T, d = x_flat.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    G = max(1, T // cfg.moe_group_size)
    S = T // G
    cap = max(1, int(S * k / E * cfg.moe_capacity_factor))
    xg = x_flat[: G * S].reshape(G, S, d)
    wg = weights[: G * S].reshape(G, S, k)
    ig = idx[: G * S].reshape(G, S, k)

    # position_in_expert via per-rank cumulative counts (GShard algorithm);
    # ONE combine tensor accumulates all k ranks (gate-weighted one-hots are
    # disjoint in (E, C)), and the dispatch mask is its support — peak live
    # memory is 2 × (G,S,E,C), independent of k.
    combine = jnp.zeros((G, S, E, cap), xg.dtype)
    counts = jnp.zeros((G, E), jnp.int32)
    for r in range(k):
        onehot = jax.nn.one_hot(ig[..., r], E, dtype=jnp.int32)       # (G,S,E)
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None]   # (G,S,E)
        pos_r = jnp.sum(pos * onehot, axis=-1)                        # (G,S)
        keep = pos_r < cap
        sel = jax.nn.one_hot(ig[..., r], E, dtype=xg.dtype) \
            * (keep * wg[..., r])[..., None].astype(xg.dtype)         # (G,S,E)
        slot = jax.nn.one_hot(jnp.where(keep, pos_r, 0), cap, dtype=xg.dtype)
        combine = combine + jnp.einsum("gse,gsc->gsec", sel, slot)
        counts = counts + jnp.sum(onehot, axis=1)
    dispatch = (combine > 0).astype(xg.dtype)                         # (G,S,E,C)
    h = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    h = h.transpose(1, 0, 2, 3).reshape(E, G * cap, d)                # expert-major
    h = shard_activation(h, "moe_ecd")
    h = _expert_ffn(h, p["experts"], cfg)
    h = h.reshape(E, G, cap, d).transpose(1, 0, 2, 3)                 # (G,E,C,d)
    out = jnp.einsum("gsec,gecd->gsd", combine, h)
    out_flat = out.reshape(G * S, d)
    if G * S < T:
        out_flat = jnp.concatenate([out_flat, jnp.zeros((T - G * S, d), x_flat.dtype)])
    return out_flat


# --------------------------------------------------------------------------
# engine 2: sort/gather dispatch (no one-hot matmul FLOPs)
# --------------------------------------------------------------------------

def _moe_sort(x_flat, weights, idx, p, cfg, cap_override: int = 0):
    """Group-LOCAL sort dispatch: every sort/gather/scatter is batched over
    groups that stay sharded on the data axes; only the expert-major einsum
    reshards (G↔E), which GSPMD lowers to the one all-to-all MoE actually
    needs. No one-hot matmul FLOPs (the einsum engine's overhead) and no
    global argsort (which GSPMD cannot shard — it replicates everything).
    """
    T, d = x_flat.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    S = min(4096, T)
    while T % S:
        S //= 2
    G = T // S
    A = S * k
    if cap_override:
        cap = min(S, cap_override)       # per-group dropless bound is S
    else:
        cap = max(1, min(S, int(S * k / E * cfg.moe_capacity_factor)))

    xg = x_flat.reshape(G, S, d)
    eg = idx.reshape(G, A)                               # assignment → expert
    wg = weights.reshape(G, A)
    garange = jnp.arange(G)[:, None]

    order = jnp.argsort(eg, axis=-1, stable=True)        # per-group sort
    e_sorted = jnp.take_along_axis(eg, order, axis=-1)   # (G, A)
    t_sorted = order // k                                # token idx in group
    w_sorted = jnp.take_along_axis(wg, order, axis=-1)

    counts = jnp.zeros((G, E), jnp.int32).at[garange, eg].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts        # (G, E)
    pos_in_e = jnp.arange(A)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=-1)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, E * cap)  # (G, A)

    # dispatch: per-group scatter of token indices, then batched gather
    src = jnp.full((G, E * cap + 1), S, jnp.int32)
    src = src.at[garange, slot].set(jnp.where(keep, t_sorted, S))
    x_pad = jnp.concatenate([xg, jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    h = jnp.take_along_axis(x_pad, src[:, : E * cap, None], axis=1)  # (G,EC,d)
    h = h.reshape(G, E, cap, d).transpose(1, 0, 2, 3).reshape(E, G * cap, d)
    h = shard_activation(h, "moe_ecd")                   # ← the all-to-all
    h = _expert_ffn(h, p["experts"], cfg)
    h = h.reshape(E, G, cap, d).transpose(1, 0, 2, 3).reshape(G, E * cap, d)
    h_pad = jnp.concatenate([h, jnp.zeros((G, 1, d), h.dtype)], axis=1)

    # combine: per-assignment gather + weighted per-token segment sum
    gathered = jnp.take_along_axis(h_pad, slot[..., None], axis=1)
    gathered = gathered * (w_sorted * keep.astype(w_sorted.dtype))[..., None]
    out = jnp.zeros((G, S, d), jnp.float32).at[garange[..., None], t_sorted].add(
        gathered.astype(jnp.float32))
    return out.reshape(T, d).astype(x_flat.dtype)


# --------------------------------------------------------------------------
# engine 3: shard_map all-to-all expert parallelism (production default)
# --------------------------------------------------------------------------

def _moe_a2a(x: jax.Array, p: Dict[str, Any], cfg, mesh_ctx) -> Tuple[jax.Array, jax.Array]:
    """Explicit EP over the model axis (DeepSeek-style dispatch).

    Inside shard_map every device owns a sequence slice of its DP batch plus
    E/M experts (E padded to a multiple of M; pad experts are unroutable).
    Dispatch = local per-expert sort → ONE all_to_all over `model`; combine is
    the mirror all_to_all. GSPMD never sees a global gather/scatter — this is
    the fix for the einsum engine's O(T·S_g·k) dispatch tensors.

    Token accounting: x enters model-replicated (B,S,d); we slice S over the
    model axis (free: slicing a replicated tensor), route S/M tokens per
    device, and all-gather the combined output back to replicated — the
    standard sequence-parallel MoE sandwich.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = mesh_ctx["mesh"]
    dp = mesh_ctx["dp_axes"]
    maxis = mesh_ctx["model_axis"]
    M = mesh.shape[maxis]
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    Ep = ((E + M - 1) // M) * M                     # padded expert count
    E_loc = Ep // M
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    if B % dp_total or S % M:
        return None  # caller falls back to a GSPMD engine
    S_loc = S // M
    T_loc = (B // dp_total) * S_loc
    cap = max(1, int(math.ceil(T_loc * k / Ep * cfg.moe_capacity_factor)))
    cap = min(cap, T_loc)

    ep = p["experts"]

    def pad_experts(w):
        return jnp.pad(w, ((0, Ep - E),) + ((0, 0),) * (w.ndim - 1))

    wg_, wu_, wd_ = (pad_experts(ep[n]) for n in ("w_gate", "w_up", "w_down"))

    def local_fn(x_blk, router_w, wg, wu, wd):
        # x_blk: (B_loc, S_loc, d); wg/wu/wd: (E_loc, ·, ·) local experts
        Bl = x_blk.shape[0]
        xt = x_blk.reshape(Bl * S_loc, d)
        logits = jnp.einsum("td,de->te", xt, router_w,
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)      # (T_loc, E) real experts
        w_k, i_k = jax.lax.top_k(probs, k)
        w_k = (w_k / jnp.maximum(w_k.sum(-1, keepdims=True), 1e-9)).astype(xt.dtype)

        # local per-expert slotting (sorted assignments, capacity-bounded)
        A = xt.shape[0] * k
        eflat = i_k.reshape(A)
        wflat = w_k.reshape(A)
        order = jnp.argsort(eflat, stable=True)
        e_sorted = eflat[order]
        t_sorted = order // k
        w_sorted = wflat[order]
        counts = jnp.zeros((Ep,), jnp.int32).at[e_sorted].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(A) - starts[e_sorted]
        keep = pos < cap
        slot = jnp.where(keep, e_sorted * cap + pos, Ep * cap)

        src = jnp.full((Ep * cap + 1,), xt.shape[0], jnp.int32)
        src = src.at[slot].set(jnp.where(keep, t_sorted, xt.shape[0]))
        x_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
        send = x_pad[src[: Ep * cap]].reshape(M, E_loc * cap, d)

        recv = jax.lax.all_to_all(send, maxis, split_axis=0, concat_axis=0,
                                  tiled=False)       # (M_src, E_loc*cap, d)
        h = recv.reshape(M, E_loc, cap, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, M * cap, d)
        actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
        gate = jnp.einsum("ecd,edf->ecf", h, wg,
                          preferred_element_type=jnp.float32).astype(h.dtype)
        up = jnp.einsum("ecd,edf->ecf", h, wu,
                        preferred_element_type=jnp.float32).astype(h.dtype)
        hmid = actf(gate) * up
        hout = jnp.einsum("ecf,efd->ecd", hmid, wd,
                          preferred_element_type=jnp.float32).astype(h.dtype)
        back = hout.reshape(E_loc, M, cap, d).transpose(1, 0, 2, 3) \
            .reshape(M, E_loc * cap, d)
        got = jax.lax.all_to_all(back, maxis, split_axis=0, concat_axis=0,
                                 tiled=False).reshape(Ep * cap, d)
        got = jnp.concatenate([got, jnp.zeros((1, d), got.dtype)], 0)
        contrib = got[slot] * (w_sorted * keep.astype(w_sorted.dtype))[:, None]
        out = jnp.zeros((xt.shape[0], d), jnp.float32).at[t_sorted].add(
            contrib.astype(jnp.float32))
        return out.reshape(Bl, S_loc, d).astype(x_blk.dtype)

    dp_spec = dp if len(dp) > 1 else dp[0] if dp else None
    out = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp_spec, maxis, None), P(), P(maxis), P(maxis), P(maxis)),
        out_specs=P(dp_spec, maxis, None),
        check_rep=False,
    )(x, p["router"], wg_, wu_, wd_)
    # aux loss approximated from a replicated router pass is avoided: compute
    # it outside on the full batch only when training needs it (caller does).
    return out


def moe_block(x: jax.Array, p: Dict[str, Any], cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (out, aux_loss).

    Decode-sized batches (T ≤ 1024) dispatch DROPLESS (capacity = T): serving
    must be deterministic and never silently drop a request's token."""
    from .layers import get_mesh_context

    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    weights, idx, aux = _router(x_flat, p, cfg)
    mesh_ctx = get_mesh_context()
    out = None
    if B * S <= 1024:
        out = _moe_sort(x_flat, weights, idx, p, cfg, cap_override=B * S)
        # cap_override clamps to per-group size internally → dropless
    elif cfg.moe_impl == "a2a" and mesh_ctx is not None \
            and mesh_ctx.get("model_axis"):
        res = _moe_a2a(x, p, cfg, mesh_ctx)
        if res is not None:
            out = res.reshape(B * S, d)
    if out is None:
        engine = _moe_sort if cfg.moe_impl == "sort" else _moe_einsum
        out = engine(x_flat, weights, idx, p, cfg)
    if cfg.num_shared_experts:
        sp = p["shared"]
        from .layers import glu_mlp

        out = out + glu_mlp(x_flat, sp, cfg.act, glu=True)
    return out.reshape(B, S, d), aux
