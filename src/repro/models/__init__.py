"""Model substrate: every assigned architecture on one composable stack."""
from .model import Model, build, count_params_analytic, param_count_from_tree

__all__ = ["Model", "build", "count_params_analytic", "param_count_from_tree"]
