"""Shared neural-net substrate: norms, RoPE, GLU MLPs, embeddings, param init.

Params are plain nested dicts. Every leaf is created through ``param()``,
which also records a *logical axis* tuple in a parallel annotation tree —
the sharding rule engine (sharding/specs.py) maps logical axes to mesh axes.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ParamStore", "rmsnorm", "layernorm", "apply_norm", "norm_param",
           "dense", "rope", "glu_mlp", "init_glu_mlp", "shard_activation",
           "set_activation_sharder", "softcap", "DTYPES"]

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


# --------------------------------------------------------------------------
# param creation with logical-axis annotations
# --------------------------------------------------------------------------

class ParamStore:
    """Collects params + logical-axis annotations during init."""

    def __init__(self, rng: jax.Array, dtype: jnp.dtype):
        self._rng = rng
        self.dtype = dtype
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}

    def next_rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def sub(self, name: str) -> "ParamStore":
        child = ParamStore(self.next_rng(), self.dtype)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              init: str = "normal", scale: Optional[float] = None) -> jax.Array:
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        elif init == "normal":
            fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
            std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
            val = (jax.random.truncated_normal(self.next_rng(), -2, 2, shape,
                                               jnp.float32) * std).astype(self.dtype)
        elif init == "embed":
            std = scale if scale is not None else 0.02
            val = (jax.random.truncated_normal(self.next_rng(), -2, 2, shape,
                                               jnp.float32) * std).astype(self.dtype)
        else:
            raise ValueError(init)
        self.params[name] = val
        self.axes[name] = axes
        return val


# --------------------------------------------------------------------------
# activation-sharding + mesh hooks (installed by the launcher; no-op otherwise)
# --------------------------------------------------------------------------
_ACT_SHARDER: Optional[Callable[[jax.Array, str], jax.Array]] = None
_MESH_CONTEXT: Optional[Dict[str, Any]] = None  # {"mesh", "dp_axes", "model_axis"}


def set_activation_sharder(fn: Optional[Callable[[jax.Array, str], jax.Array]]) -> None:
    global _ACT_SHARDER
    _ACT_SHARDER = fn


def shard_activation(x: jax.Array, kind: str) -> jax.Array:
    """kind ∈ {tokens_bsd, tokens_bsd_seq, heads_bhsd, logits_bsv, moe_egcd, ...}."""
    if _ACT_SHARDER is None:
        return x
    return _ACT_SHARDER(x, kind)


def set_mesh_context(ctx: Optional[Dict[str, Any]]) -> None:
    """Mesh info for layers that use explicit shard_map collectives (MoE a2a)."""
    global _MESH_CONTEXT
    _MESH_CONTEXT = ctx


def get_mesh_context() -> Optional[Dict[str, Any]]:
    return _MESH_CONTEXT


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_param(store: ParamStore, name: str, dim: int, kind: str) -> None:
    sub = store.sub(name)
    sub.param("scale", (dim,), ("embed",), init="ones")
    if kind == "layernorm":
        sub.param("bias", (dim,), ("embed",), init="zeros")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x: jax.Array, p: Dict[str, jax.Array], kind: str,
               eps: float = 1e-6) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


# --------------------------------------------------------------------------
# dense / matmul with f32 accumulation
# --------------------------------------------------------------------------

def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    # Output in the compute dtype: the MXU accumulates in f32 internally
    # regardless, but keeping the *result* (and therefore any cross-chip
    # TP partial-sum all-reduce GSPMD inserts) in bf16 halves collective
    # bytes — the standard Megatron-style trade. Logit matmuls that need
    # f32 results use explicit einsums in model.py.
    out = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        out = out + b.astype(out.dtype)
    return out


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


# --------------------------------------------------------------------------
# rotary position embedding (partial fraction + arbitrary positions)
# --------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10_000.0,
         fraction: float = 1.0) -> jax.Array:
    """x: (..., S, D) with positions (..., S) or (S,). Rotates first
    ``fraction·D`` dims (StableLM partial rotary), rest pass through."""
    D = x.shape[-1]
    rot = int(D * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast cos/sin over any head dims between batch and S
    while cos.ndim < x_rot.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1) if rot < D \
        else out.astype(x.dtype)


# --------------------------------------------------------------------------
# (G)LU MLP
# --------------------------------------------------------------------------

def init_glu_mlp(store: ParamStore, name: str, d_model: int, d_ff: int,
                 glu: bool = True) -> None:
    sub = store.sub(name)
    if glu:
        sub.param("w_gate", (d_model, d_ff), ("embed", "mlp"))
    sub.param("w_up", (d_model, d_ff), ("embed", "mlp"))
    sub.param("w_down", (d_ff, d_model), ("mlp", "embed"))


def glu_mlp(x: jax.Array, p: Dict[str, jax.Array], act: str = "silu",
            glu: bool = True) -> jax.Array:
    actf = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    up = dense(x, p["w_up"])
    h = actf(dense(x, p["w_gate"])) * up if glu else actf(up)
    h = shard_activation(h, "mlp_bsf")
    return dense(h, p["w_down"])
