"""Attention blocks: GQA (qkv-bias, qk-norm, partial RoPE, local window) + MLA.

Two call modes:
  - full-sequence (train / prefill): uses kernels.ops.flash_attention
  - cached decode (Sq == 1 against a fixed-size cache + running position)

Cache layout (per layer, managed by the caller / scan):
  GQA: {"k": (B, S, Hkv, D), "v": (B, S, Hkv, D), "pos": ()} — pos is GLOBAL.
  MLA: {"ckv": (B, S, kv_lora), "krope": (B, S, rope_dim), "pos": ()}
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import ParamStore, dense, norm_param, apply_norm, rope, rmsnorm, \
    shard_activation

__all__ = ["init_gqa", "gqa_attention", "init_mla", "mla_attention",
           "init_gqa_cache", "init_mla_cache"]


# ==========================================================================
# GQA
# ==========================================================================

def init_gqa(store: ParamStore, name: str, cfg) -> None:
    sub = store.sub(name)
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    sub.param("wq", (d, H * hd), ("embed", "heads"))
    sub.param("wk", (d, KV * hd), ("embed", "kv_heads"))
    sub.param("wv", (d, KV * hd), ("embed", "kv_heads"))
    sub.param("wo", (H * hd, d), ("heads", "embed"))
    if cfg.qkv_bias:
        sub.param("bq", (H * hd,), ("heads",), init="zeros")
        sub.param("bk", (KV * hd,), ("kv_heads",), init="zeros")
        sub.param("bv", (KV * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        sub.param("q_norm", (hd,), (None,), init="ones")
        sub.param("k_norm", (hd,), (None,), init="ones")


def init_gqa_cache(cfg, batch: int, seq_len: int, dtype) -> Dict[str, Any]:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, seq_len, KV, hd), dtype),
            "v": jnp.zeros((batch, seq_len, KV, hd), dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}  # per-sequence positions


def _project_qkv(x, p, cfg, positions):
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(x, p["wq"], p.get("bq"))
    k = dense(x, p["wk"], p.get("bk"))
    v = dense(x, p["wv"], p.get("bv"))
    q = q.reshape(B, -1, H, hd)
    k = k.reshape(B, -1, KV, hd)
    v = v.reshape(B, -1, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    # (B, H, S, D) layout for the kernel; rope over positions
    q = jnp.moveaxis(q, 1, 2)
    k = jnp.moveaxis(k, 1, 2)
    v = jnp.moveaxis(v, 1, 2)
    q = rope(q, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    k = rope(k, positions, theta=cfg.rope_theta, fraction=cfg.rope_fraction)
    return q, k, v


def gqa_attention(x: jax.Array, p: Dict[str, Any], cfg, *,
                  positions: jax.Array,
                  cache: Optional[Dict[str, Any]] = None,
                  causal: bool = True,
                  window: Optional[int] = None,
                  cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                  ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    """Returns (out (B,S,d), updated cache). Modes:
       - cross_kv given: encoder-decoder cross attention (no cache update);
       - cache given:    single-token decode (S == 1);
       - else:           full-sequence self attention."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim

    if cross_kv is not None:
        k, v = cross_kv  # (B, Hkv, Ssrc, hd) — precomputed, already roped/plain
        q = dense(x, p["wq"], p.get("bq")).reshape(B, S, H, hd)
        if cfg.qk_norm:
            q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        q = jnp.moveaxis(q, 1, 2)
        out = ops.flash_attention(q, k, v, causal=False, impl=cfg.attn_impl)
        out = jnp.moveaxis(out, 1, 2).reshape(B, S, H * hd)
        return dense(out, p["wo"]), None

    q, k, v = _project_qkv(x, p, cfg, positions)
    q = shard_activation(q, "heads_bhsd")

    if cache is None:
        out = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  impl=cfg.attn_impl)
        out = jnp.moveaxis(out, 1, 2).reshape(B, S, H * hd)
        return dense(out, p["wo"]), None

    # ---- cached decode: S == 1, per-sequence insert at cache["pos"] ----------
    pos = cache["pos"]                 # (B,) — slots may be at different steps
    k_new = jnp.moveaxis(k, 1, 2)      # (B, 1, KV, hd)
    v_new = jnp.moveaxis(v, 1, 2)
    Sc = cache["k"].shape[1]
    if window and window > 0 and Sc == window:
        slot = jnp.mod(pos, window)    # ring buffer for local attention
    else:
        slot = jnp.minimum(pos, Sc - 1)
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(
        k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(
        v_new[:, 0].astype(cache["v"].dtype))
    kq = jnp.moveaxis(k_cache, 1, 2)   # (B, KV, Sc, hd)
    vq = jnp.moveaxis(v_cache, 1, 2)
    kq = shard_activation(kq, "cache_bhsd")
    vq = shard_activation(vq, "cache_bhsd")
    g = H // cfg.num_kv_heads
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(kq, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(vq, g, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * (hd ** -0.5)
    idx = jnp.arange(Sc)
    if window and window > 0 and Sc == window:
        ages = jnp.mod(pos[:, None] - idx[None, :], window)  # (B, Sc)
        valid = ages < jnp.minimum(pos + 1, window)[:, None]
    else:
        valid = idx[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf).astype(x.dtype)
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, H * hd)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    return dense(out, p["wo"]), new_cache


# ==========================================================================
# MLA — DeepSeek-V3 multi-head latent attention
# ==========================================================================

def init_mla(store: ParamStore, name: str, cfg) -> None:
    sub = store.sub(name)
    d, H = cfg.d_model, cfg.num_heads
    qn, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    # query low-rank path
    sub.param("wq_a", (d, cfg.q_lora_rank), ("embed", "lora"))
    norm_param(sub, "q_norm", cfg.q_lora_rank, "rmsnorm")
    sub.param("wq_b", (cfg.q_lora_rank, H * (qn + qr)), ("lora", "heads"))
    # kv low-rank path: compressed latent + shared rope key
    sub.param("wkv_a", (d, cfg.kv_lora_rank + qr), ("embed", "lora"))
    norm_param(sub, "kv_norm", cfg.kv_lora_rank, "rmsnorm")
    sub.param("wkv_b", (cfg.kv_lora_rank, H * (qn + vh)), ("lora", "heads"))
    sub.param("wo", (H * vh, d), ("heads", "embed"))


def init_mla_cache(cfg, batch: int, seq_len: int, dtype) -> Dict[str, Any]:
    return {"ckv": jnp.zeros((batch, seq_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, seq_len, cfg.qk_rope_head_dim), dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


def _mla_q(x, p, cfg, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    qn, qr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = apply_norm(dense(x, p["wq_a"]), p["q_norm"], "rmsnorm", cfg.norm_eps)
    q = dense(cq, p["wq_b"]).reshape(B, S, H, qn + qr)
    q = jnp.moveaxis(q, 1, 2)                        # (B,H,S,qn+qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = rope(q_rope, positions, theta=cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_expand_kv(ckv, krope, p, cfg):
    """latent (B,S,r) + shared rope key (B,S,qr) → per-head K,V (B,H,S,·)."""
    B, S, _ = ckv.shape
    H = cfg.num_heads
    qn, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kv = dense(ckv, p["wkv_b"]).reshape(B, S, H, qn + vh)
    kv = jnp.moveaxis(kv, 1, 2)
    k_nope, v = kv[..., :qn], kv[..., qn:]
    k_rope = jnp.broadcast_to(krope[:, None], (B, H, S, qr))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    return k, v


def mla_attention(x: jax.Array, p: Dict[str, Any], cfg, *,
                  positions: jax.Array,
                  cache: Optional[Dict[str, Any]] = None,
                  ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    B, S, d = x.shape
    H = cfg.num_heads
    qn, qr, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (qn + qr) ** -0.5

    q = _mla_q(x, p, cfg, positions)                 # (B,H,S,qn+qr)
    kv_a = dense(x, p["wkv_a"])                       # (B,S,r+qr)
    ckv = apply_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"], "rmsnorm",
                     cfg.norm_eps)
    krope = rope(kv_a[..., cfg.kv_lora_rank:], positions, theta=cfg.rope_theta)

    if cache is None:
        k, v = _mla_expand_kv(ckv, krope, p, cfg)
        out = ops.flash_attention(q, k, v, causal=True, scale=scale,
                                  impl=cfg.attn_impl)
        out = jnp.moveaxis(out, 1, 2).reshape(B, S, H * vh)
        return dense(out, p["wo"]), None

    # cached decode: ABSORBED attention — stay in the compressed latent space
    # (never materialize per-head K/V over the 32k cache):
    #   logits = (q_nope · W_uk) · ckv + q_rope · k_rope
    #   out    = (probs · ckv) · W_uv
    pos = cache["pos"]                         # (B,) per-sequence positions
    Sc = cache["ckv"].shape[1]
    slot = jnp.minimum(pos, Sc - 1)
    bidx = jnp.arange(B)
    ckv_c = cache["ckv"].at[bidx, slot].set(ckv[:, 0].astype(cache["ckv"].dtype))
    krope_c = cache["krope"].at[bidx, slot].set(
        krope[:, 0].astype(cache["krope"].dtype))
    ckv_s = shard_activation(ckv_c, "cache_bsr")
    krope_s = shard_activation(krope_c, "cache_bsr")
    r = cfg.kv_lora_rank
    wkv_b = p["wkv_b"].reshape(r, H, qn + vh)
    w_uk, w_uv = wkv_b[..., :qn], wkv_b[..., qn:]       # (r,H,qn), (r,H,vh)
    q_nope, q_rope = q[..., :qn], q[..., qn:]            # (B,H,1,·)
    q_lat = jnp.einsum("bhqn,rhn->bhqr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))         # (B,H,1,r)
    logits = (jnp.einsum("bhqr,bsr->bhqs", q_lat, ckv_s.astype(jnp.float32))
              + jnp.einsum("bhqe,bse->bhqs", q_rope.astype(jnp.float32),
                           krope_s.astype(jnp.float32))) * scale
    valid = jnp.arange(Sc)[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bhqr", probs, ckv_s.astype(jnp.float32))
    out = jnp.einsum("bhqr,rhv->bhqv", out_lat,
                     w_uv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, H * vh)
    return dense(out, p["wo"]), {"ckv": ckv_c, "krope": krope_c, "pos": pos + 1}
