"""Transformer assembly: layer kinds, segment scan, encoder-decoder.

Layer kinds:
  dense : self-attention (GQA or MLA) + GLU MLP
  moe   : self-attention + MoE FFN
  rec   : Griffin recurrent block (conv1d + RG-LRU) + GLU MLP
  attn  : alias of dense used inside hybrid patterns (local window applies)
  rwkv  : RWKV6 time-mix + channel-mix
  enc   : bidirectional encoder self-attention + MLP
  xattn : decoder self-attention + cross-attention + MLP (enc-dec)

The layer stack is compressed into SEGMENTS — (unit kinds, repeats) — and
each segment executes as ONE lax.scan over stacked params, so HLO size and
compile time are O(#distinct units), not O(num_layers). Caches are stacked
along the same leading axis and scanned together with the params.

Modes: "train" (no cache), "prefill" (build cache), "decode" (Sq=1, use
cache). Every apply returns (h, new_cache_or_None, aux_loss).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .attention import (gqa_attention, init_gqa, init_gqa_cache, init_mla,
                        init_mla_cache, mla_attention)
from .layers import (ParamStore, apply_norm, dense, glu_mlp, init_glu_mlp,
                     norm_param, shard_activation)
from .moe import init_moe, moe_block
from .rglru import init_recurrent_block, init_rglru_state, recurrent_block
from .rwkv import (init_rwkv_layer, init_rwkv_state, rwkv_channel_mix,
                   rwkv_time_mix)

__all__ = ["derive_segments", "layer_pattern", "init_layer", "apply_layer",
           "init_layer_cache", "run_stack", "init_stack", "init_stack_cache"]

_UNROLL_MAX = 4  # segments this short run unrolled (exact cost accounting)


# --------------------------------------------------------------------------
# pattern → segments
# --------------------------------------------------------------------------

def layer_pattern(cfg) -> Tuple[str, ...]:
    if cfg.block_pattern:
        return tuple(cfg.block_pattern)
    if cfg.family == "ssm":
        return ("rwkv",) * cfg.num_layers
    if cfg.num_experts:
        return ("dense",) * cfg.first_k_dense + \
               ("moe",) * (cfg.num_layers - cfg.first_k_dense)
    if cfg.is_encdec:
        return ("xattn",) * cfg.num_layers  # decoder layers cross-attend
    return ("dense",) * cfg.num_layers


def derive_segments(pattern: Sequence[str], max_unit: int = 4
                    ) -> List[Tuple[Tuple[str, ...], int]]:
    """Greedy tiling: [(unit_kinds, repeats), ...] covering the pattern."""
    segments: List[Tuple[Tuple[str, ...], int]] = []
    i = 0
    n = len(pattern)
    while i < n:
        best: Tuple[int, int] = (1, 1)  # (unit_len, repeats)
        best_score = 0
        for ul in range(1, min(max_unit, n - i) + 1):
            unit = tuple(pattern[i: i + ul])
            r = 1
            while pattern[i + r * ul: i + (r + 1) * ul] == unit:
                r += 1
            # only true repetition wins coverage — a long non-repeating unit
            # must not swallow a repeatable prefix (e.g. d,d,d,m vs (d)×3)
            score = r * ul if r >= 2 else 1
            if score > best_score or (score == best_score and ul < best[0]):
                best, best_score = (ul, r), score
        ul, r = best
        segments.append((tuple(pattern[i: i + ul]), r))
        i += ul * r
    return segments


# --------------------------------------------------------------------------
# single-layer init / apply / cache
# --------------------------------------------------------------------------

def init_layer(store: ParamStore, cfg, kind: str) -> None:
    if kind == "rwkv":
        norm_param(store, "ln1", cfg.d_model, cfg.norm)
        norm_param(store, "ln2", cfg.d_model, cfg.norm)
        init_rwkv_layer(store, "rwkv", cfg)
        return
    if kind == "rec":
        norm_param(store, "ln1", cfg.d_model, cfg.norm)
        init_recurrent_block(store, "rec", cfg)
        norm_param(store, "ln2", cfg.d_model, cfg.norm)
        init_glu_mlp(store, "mlp", cfg.d_model, cfg.d_ff, cfg.glu)
        return
    # attention-bearing kinds
    norm_param(store, "ln1", cfg.d_model, cfg.norm)
    if cfg.mla:
        init_mla(store, "attn", cfg)
    else:
        init_gqa(store, "attn", cfg)
    if kind == "xattn":
        norm_param(store, "ln_x", cfg.d_model, cfg.norm)
        init_gqa(store, "xattn", cfg)
    norm_param(store, "ln2", cfg.d_model, cfg.norm)
    if kind == "moe":
        init_moe(store, "moe", cfg)
    else:
        init_glu_mlp(store, "mlp", cfg.d_model, cfg.d_ff, cfg.glu)


def init_layer_cache(cfg, kind: str, batch: int, seq_len: int, dtype,
                     src_len: int = 0) -> Any:
    if kind == "rwkv":
        return init_rwkv_state(cfg, batch, dtype)
    if kind == "rec":
        return init_rglru_state(cfg, batch, dtype)
    size = min(cfg.window, seq_len) if (cfg.window and kind == "attn") else seq_len
    cache = init_mla_cache(cfg, batch, size, dtype) if cfg.mla \
        else init_gqa_cache(cfg, batch, size, dtype)
    if kind == "xattn":
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        cache = {"self": cache,
                 "cross_k": jnp.zeros((batch, KV, src_len, hd), dtype),
                 "cross_v": jnp.zeros((batch, KV, src_len, hd), dtype)}
    return cache


def _self_attention(h, lp, cfg, kind, *, positions, cache, mode):
    window = cfg.window if (cfg.window and kind == "attn") else None
    causal = kind != "enc"
    if cfg.mla:
        return mla_attention(h, lp["attn"], cfg, positions=positions,
                             cache=cache if mode == "decode" else None)
    out, new_cache = gqa_attention(h, lp["attn"], cfg, positions=positions,
                                   cache=cache if mode == "decode" else None,
                                   causal=causal, window=window)
    return out, new_cache


def _prefill_cache_from_full(h_in, lp, cfg, kind, positions, seq_len):
    """Recompute k/v once more for cache building (prefill mode).

    Cheap relative to the full forward; keeps attention fns single-purpose.
    """
    from .attention import _project_qkv  # reuse projection
    from .layers import rope

    B = h_in.shape[0]
    pos_vec = jnp.full((B,), seq_len, jnp.int32)   # per-sequence positions
    if cfg.mla:
        kv_a = dense(h_in, lp["attn"]["wkv_a"])
        ckv = apply_norm(kv_a[..., :cfg.kv_lora_rank], lp["attn"]["kv_norm"],
                         "rmsnorm", cfg.norm_eps)
        krope = rope(kv_a[..., cfg.kv_lora_rank:], positions, theta=cfg.rope_theta)
        return {"ckv": ckv, "krope": krope, "pos": pos_vec}
    q, k, v = _project_qkv(h_in, lp["attn"], cfg, positions)
    k = jnp.moveaxis(k, 1, 2)  # (B,S,KV,hd)
    v = jnp.moveaxis(v, 1, 2)
    window = cfg.window if (cfg.window and kind == "attn") else 0
    if window and k.shape[1] > window:
        # ring-buffer invariant: slot i holds the kv of global pos ≡ i (mod W)
        k = jnp.roll(k[:, -window:], seq_len % window, axis=1)
        v = jnp.roll(v[:, -window:], seq_len % window, axis=1)
    return {"k": k, "v": v, "pos": pos_vec}


def apply_layer(h: jax.Array, lp: Dict[str, Any], cfg, kind: str, *,
                positions: jax.Array, mode: str,
                cache: Optional[Any] = None,
                enc_out: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Optional[Any], jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    B = h.shape[0]
    seq_len = h.shape[1]
    if mode == "prefill" and cache is None and kind in ("rwkv", "rec"):
        cache = init_layer_cache(cfg, kind, B, seq_len, h.dtype)

    if kind == "rwkv":
        x1 = apply_norm(h, lp["ln1"], cfg.norm, cfg.norm_eps)
        tm_out, st = rwkv_time_mix(x1, lp["rwkv"], cfg,
                                   state=cache if mode != "train" else None)
        h = h + tm_out
        x2 = apply_norm(h, lp["ln2"], cfg.norm, cfg.norm_eps)
        cm_out, st2 = rwkv_channel_mix(x2, lp["rwkv"], cfg, state=st)
        h = h + cm_out
        if mode == "train":
            return h, None, aux
        if mode == "prefill":
            st2 = dict(st2)
            st2["tm_prev"] = x1[:, -1, :]
            st2["cm_prev"] = x2[:, -1, :]
        return h, st2, aux

    if kind == "rec":
        x1 = apply_norm(h, lp["ln1"], cfg.norm, cfg.norm_eps)
        rec_out, st = recurrent_block(x1, lp["rec"], cfg,
                                      state=cache if mode != "train" else None)
        h = h + rec_out
        x2 = apply_norm(h, lp["ln2"], cfg.norm, cfg.norm_eps)
        h = h + glu_mlp(x2, lp["mlp"], cfg.act, cfg.glu)
        if mode == "train":
            return h, None, aux
        if mode == "prefill" and st is None:
            st = init_rglru_state(cfg, B, h.dtype)
        return h, st, aux

    # attention-bearing kinds ------------------------------------------------
    x1 = apply_norm(h, lp["ln1"], cfg.norm, cfg.norm_eps)
    attn_out, new_cache = _self_attention(
        x1, lp, cfg, kind, positions=positions,
        cache=(cache["self"] if kind == "xattn" else cache) if cache is not None
        else None,
        mode=mode)
    h = h + attn_out
    if mode == "prefill":
        new_cache = _prefill_cache_from_full(x1, lp, cfg, kind, positions, seq_len)

    if kind == "xattn":
        xx = apply_norm(h, lp["ln_x"], cfg.norm, cfg.norm_eps)
        if mode == "decode":
            ck, cv = cache["cross_k"], cache["cross_v"]
        else:
            # project encoder output with this layer's cross weights
            xp = lp["xattn"]
            Bq, Ssrc, _ = enc_out.shape
            KV, hd = cfg.num_kv_heads, cfg.head_dim
            ck = dense(enc_out, xp["wk"], xp.get("bk")).reshape(Bq, Ssrc, KV, hd)
            cv = dense(enc_out, xp["wv"], xp.get("bv")).reshape(Bq, Ssrc, KV, hd)
            ck, cv = jnp.moveaxis(ck, 1, 2), jnp.moveaxis(cv, 1, 2)
        x_out, _ = gqa_attention(xx, lp["xattn"], cfg, positions=positions,
                                 cross_kv=(ck, cv))
        h = h + x_out
        if mode == "prefill":
            new_cache = {"self": new_cache, "cross_k": ck, "cross_v": cv}
        elif mode == "decode":
            new_cache = {"self": new_cache, "cross_k": ck, "cross_v": cv}

    x2 = apply_norm(h, lp["ln2"], cfg.norm, cfg.norm_eps)
    if kind == "moe":
        ffn_out, aux = moe_block(x2, lp["moe"], cfg)
    else:
        ffn_out = glu_mlp(x2, lp["mlp"], cfg.act, cfg.glu)
    h = h + ffn_out
    h = shard_activation(h, "tokens_bsd")
    return h, (new_cache if mode != "train" else None), aux


# --------------------------------------------------------------------------
# stacked segments: init + scan execution
# --------------------------------------------------------------------------

def init_stack(store: ParamStore, cfg, pattern: Sequence[str], prefix: str = "seg"
               ) -> List[Tuple[Tuple[str, ...], int]]:
    """Init all layers, stacked per segment-unit position. Returns segments."""
    segments = derive_segments(pattern)
    for si, (unit, repeats) in enumerate(segments):
        seg = store.sub(f"{prefix}{si}")
        for uj, kind in enumerate(unit):
            # init `repeats` copies and stack along axis 0
            copies = []
            axes_ref = None
            for _ in range(repeats):
                tmp = ParamStore(seg.next_rng(), seg.dtype)
                init_layer(tmp, cfg, kind)
                copies.append(tmp.params)
                axes_ref = tmp.axes
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *copies)
            seg.params[f"u{uj}"] = stacked
            seg.axes[f"u{uj}"] = jax.tree.map(
                lambda a: ("layers",) + a, axes_ref,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x))
    return segments


def init_stack_cache(cfg, segments, batch: int, seq_len: int, dtype,
                     src_len: int = 0, prefix: str = "seg") -> Dict[str, Any]:
    cache: Dict[str, Any] = {}
    for si, (unit, repeats) in enumerate(segments):
        seg_cache = {}
        for uj, kind in enumerate(unit):
            one = init_layer_cache(cfg, kind, batch, seq_len, dtype, src_len)
            seg_cache[f"u{uj}"] = jax.tree.map(
                lambda x, r=repeats: jnp.broadcast_to(x, (r,) + x.shape).copy(),
                one)
        cache[f"{prefix}{si}"] = seg_cache
    return cache


def run_stack(h: jax.Array, params: Dict[str, Any], cfg, segments, *,
              positions: jax.Array, mode: str,
              cache: Optional[Dict[str, Any]] = None,
              enc_out: Optional[jax.Array] = None,
              prefix: str = "seg",
              ) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """Run all segments in order. Returns (h, new_cache, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict[str, Any]] = {} if cache is not None or \
        mode == "prefill" else None

    for si, (unit, repeats) in enumerate(segments):
        seg_params = params[f"{prefix}{si}"]
        seg_cache = cache.get(f"{prefix}{si}") if cache is not None else None

        def unit_body(carry, xs, _unit=unit):
            h_c, aux_c = carry
            up, uc = xs
            out_caches = {}
            for uj, kind in enumerate(_unit):
                h_c, c_new, a = apply_layer(
                    h_c, up[f"u{uj}"], cfg, kind, positions=positions,
                    mode=mode, cache=None if uc is None else uc[f"u{uj}"],
                    enc_out=enc_out)
                aux_c = aux_c + a
                if c_new is not None:
                    out_caches[f"u{uj}"] = c_new
            return (h_c, aux_c), (out_caches if out_caches else None)

        body = unit_body
        if mode == "train" and cfg.remat != "none":
            policy = None if cfg.remat == "full" else \
                jax.checkpoint_policies.checkpoint_dots
            body = jax.checkpoint(unit_body, policy=policy,
                                  prevent_cse=False)

        if repeats <= _UNROLL_MAX:
            # unrolled: exact XLA cost accounting (a scanned body is counted
            # once by cost_analysis) — this is what the roofline probes rely on
            outs = []
            for r in range(repeats):
                (h, total_aux), c_out = body(
                    (h, total_aux),
                    (jax.tree.map(lambda x, i=r: x[i], seg_params),
                     None if seg_cache is None else
                     jax.tree.map(lambda x, i=r: x[i], seg_cache)))
                outs.append(c_out)
            if new_cache is not None and outs and outs[0] is not None:
                new_cache[f"{prefix}{si}"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *outs)
        else:
            (h, total_aux), caches_out = jax.lax.scan(
                body, (h, total_aux), (seg_params, seg_cache))
            if new_cache is not None and caches_out is not None:
                new_cache[f"{prefix}{si}"] = caches_out
    return h, new_cache, total_aux
