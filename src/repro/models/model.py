"""Public model API: build(cfg) → Model with init / loss / prefill / decode.

Batch conventions (all int32 tokens):
  decoder LM       train/prefill: {"tokens": (B, S)}
  vlm (internvl)   {"tokens": (B, S - Nv), "patch_embeds": (B, Nv, fd)}
  audio (seamless) {"frames": (B, Ssrc, fd), "tokens": (B, S)}
  decode (all)     {"token": (B,)} + cache

The loss is next-token CE (f32 logsumexp) + z-loss + MoE aux (+ MTP for
DeepSeek). ``prefill`` returns (last-position logits, cache). ``decode_step``
consumes one token per sequence against the cache.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import DTYPES, ParamStore, apply_norm, dense, norm_param, softcap, \
    shard_activation
from .transformer import (apply_layer, init_layer, init_stack, init_stack_cache,
                          layer_pattern, run_stack)

__all__ = ["Model", "build", "count_params_analytic", "param_count_from_tree"]


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Tuple[Dict, Dict]]
    loss_fn: Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]
    prefill: Callable[..., Tuple[jax.Array, Dict]]
    decode_step: Callable[..., Tuple[jax.Array, Dict]]
    init_cache: Callable[..., Dict]
    segments: Any
    enc_segments: Any = None


# --------------------------------------------------------------------------
# builders
# --------------------------------------------------------------------------

def build(cfg: ModelConfig) -> Model:
    pattern = layer_pattern(cfg)
    from .transformer import derive_segments

    segments = derive_segments(pattern)
    enc_segments = derive_segments(("enc",) * cfg.encoder_layers) \
        if cfg.is_encdec else None
    pdtype = DTYPES[cfg.param_dtype]
    cdtype = DTYPES[cfg.compute_dtype]
    # vocab-parallel logits need an evenly shardable vocab: pad the embedding
    # tables to a multiple of 512 (16-way model axis × 32 lanes); pad ids are
    # masked out of every softmax/argmax. <0.1% extra params on all configs.
    vpad = ((cfg.vocab_size + 511) // 512) * 512

    # -- init ----------------------------------------------------------------
    def init(rng: jax.Array) -> Tuple[Dict, Dict]:
        store = ParamStore(rng, pdtype)
        store.sub("embed").param("table", (vpad, cfg.d_model),
                                 ("vocab", "embed"), init="embed")
        init_stack(store, cfg, pattern, prefix="seg")
        norm_param(store, "final_norm", cfg.d_model, cfg.norm)
        if not cfg.tie_embeddings:
            store.param("unembed", (cfg.d_model, vpad),
                        ("embed", "vocab"), scale=0.02)
        if cfg.is_encdec:
            enc = store.sub("encoder")
            enc.param("frontend_proj", (cfg.frontend_dim or cfg.d_model,
                                        cfg.d_model), (None, "embed"))
            init_stack(enc, cfg, ("enc",) * cfg.encoder_layers, prefix="seg")
            norm_param(enc, "final_norm", cfg.d_model, cfg.norm)
        if cfg.frontend == "vision_stub":
            fr = store.sub("frontend")
            fr.param("proj1", (cfg.frontend_dim, cfg.d_model), (None, "embed"))
            fr.param("proj2", (cfg.d_model, cfg.d_model), ("embed", "embed"))
        if cfg.mtp:
            mtp = store.sub("mtp")
            norm_param(mtp, "norm_h", cfg.d_model, cfg.norm)
            norm_param(mtp, "norm_e", cfg.d_model, cfg.norm)
            mtp.param("proj", (2 * cfg.d_model, cfg.d_model), (None, "embed"))
            init_layer(mtp.sub("layer"), cfg,
                       "dense" if not cfg.num_experts else "dense")
        return store.params, store.axes

    # -- embedding helpers -----------------------------------------------------
    def embed_tokens(params, tokens):
        return params["embed"]["table"][tokens].astype(cdtype)

    def unembed(params, h):
        h = apply_norm(h, params["final_norm"], cfg.norm, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = jnp.einsum("...d,vd->...v", h, params["embed"]["table"],
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("...d,dv->...v", h, params["unembed"],
                                preferred_element_type=jnp.float32)
        logits = softcap(logits, cfg.logit_softcap)
        if vpad != cfg.vocab_size:  # mask pad-vocab slots out of softmax
            logits = jnp.where(jnp.arange(vpad) < cfg.vocab_size, logits,
                               -1e30)
        return logits

    def build_inputs(params, batch):
        """→ (h (B,S,d), positions (S,), enc_out or None, targets/None,
            loss_mask)."""
        enc_out = None
        if cfg.is_encdec:
            ep = params["encoder"]
            src = batch["frames"].astype(cdtype)
            eh = dense(src, ep["frontend_proj"])
            eh = shard_activation(eh, "tokens_bsd")
            pos_e = jnp.arange(src.shape[1])
            eh, _, _ = run_stack(eh, ep, cfg, enc_segments, positions=pos_e,
                                 mode="train", prefix="seg")
            enc_out = apply_norm(eh, ep["final_norm"], cfg.norm, cfg.norm_eps)
        tokens = batch["tokens"]
        h = embed_tokens(params, tokens)
        mask = jnp.ones(tokens.shape, bool)
        if cfg.frontend == "vision_stub":
            fr = params["frontend"]
            vis = batch["patch_embeds"].astype(cdtype)
            vis = dense(jax.nn.gelu(dense(vis, fr["proj1"])), fr["proj2"])
            h = jnp.concatenate([vis, h], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros(vis.shape[:2], bool), mask], axis=1)
        h = shard_activation(h, "tokens_bsd")
        positions = jnp.arange(h.shape[1])
        return h, positions, enc_out, tokens, mask

    # -- loss ------------------------------------------------------------------
    def loss_fn(params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        h, positions, enc_out, tokens, mask = build_inputs(params, batch)
        h, _, aux = run_stack(h, params, cfg, segments, positions=positions,
                              mode="train", enc_out=enc_out, prefix="seg")
        logits = unembed(params, h)                      # (B, St, V) f32
        logits = shard_activation(logits, "logits_bsv")
        # next-token CE on the token (non-frontend) positions
        n_text = tokens.shape[1]
        logits_txt = logits[:, -n_text:, :]
        ce, z = _ce_loss(logits_txt[:, :-1], tokens[:, 1:])
        loss = ce + cfg.z_loss_coef * z + aux
        metrics = {"ce": ce, "z_loss": z, "aux_loss": aux, "loss": loss}
        if cfg.mtp:
            mtp_loss = _mtp_loss(params, h[:, -n_text:, :], tokens)
            loss = loss + cfg.mtp_coef * mtp_loss
            metrics["mtp_loss"] = mtp_loss
            metrics["loss"] = loss
        return loss, metrics

    def _ce_loss(logits, targets):
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - gold)
        z = jnp.mean(jnp.square(lse))
        return ce, z

    def _mtp_loss(params, h, tokens):
        """DeepSeek-V3 MTP depth-1: predict t+2 from (h_t, emb(t+1))."""
        mp = params["mtp"]
        hh = apply_norm(h[:, :-2, :], mp["norm_h"], cfg.norm, cfg.norm_eps)
        ee = apply_norm(embed_tokens(params, tokens[:, 1:-1]), mp["norm_e"],
                        cfg.norm, cfg.norm_eps)
        x = dense(jnp.concatenate([hh, ee], axis=-1), mp["proj"])
        pos = jnp.arange(x.shape[1])
        x, _, _ = apply_layer(x, mp["layer"], cfg, "dense", positions=pos,
                              mode="train")
        logits = unembed(params, x)
        ce, _ = _ce_loss(logits, tokens[:, 2:])
        return ce

    # -- prefill ------------------------------------------------------------------
    def prefill(params, batch, pad_to: int = 0) -> Tuple[jax.Array, Dict]:
        h, positions, enc_out, tokens, _ = build_inputs(params, batch)
        h, cache, _ = run_stack(h, params, cfg, segments, positions=positions,
                                mode="prefill", enc_out=enc_out, prefix="seg")
        logits = unembed(params, h[:, -1:, :])[:, 0, :cfg.vocab_size]
        if pad_to:
            cache = _pad_cache(cache, pad_to, cfg)
        return logits, cache

    # -- decode -----------------------------------------------------------------
    def init_cache(batch_size: int, seq_len: int, *, src_len: int = 0) -> Dict:
        src = src_len or cfg.source_len_for_decode
        return init_stack_cache(cfg, segments, batch_size, seq_len, cdtype,
                                src_len=src if cfg.is_encdec else 0,
                                prefix="seg")

    def decode_step(params, cache, batch) -> Tuple[jax.Array, Dict]:
        tok = batch["token"]                                # (B,)
        h = embed_tokens(params, tok[:, None])              # (B,1,d)
        pos = _cache_pos(cache, tok.shape[0])               # (B,) per-seq
        positions = pos[:, None]                            # (B,1) for rope
        h, new_cache, _ = run_stack(h, params, cfg, segments,
                                    positions=positions, mode="decode",
                                    cache=cache, prefix="seg")
        logits = unembed(params, h[:, 0, :])[:, :cfg.vocab_size]
        return logits, new_cache

    return Model(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill,
                 decode_step=decode_step, init_cache=init_cache,
                 segments=segments, enc_segments=enc_segments)


_PAD_AXIS = {"k": -3, "v": -3, "ckv": -2, "krope": -2}


def _pad_cache(cache, pad_to: int, cfg):
    """Grow a prefill cache to ``pad_to`` slots (decode appends after S).

    Ring (local-window) caches are already complete and are left alone.
    """

    def pad(path, x):
        key = path[-1] if path else ""
        if key not in _PAD_AXIS:
            return x
        ax = _PAD_AXIS[key] % x.ndim
        cur = x.shape[ax]
        if cur >= pad_to or (cfg.window and cur == cfg.window):
            return x
        widths = [(0, 0)] * x.ndim
        widths[ax] = (0, pad_to - cur)
        return jnp.pad(x, widths)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return pad(path, tree)

    return walk(cache, ())


def _cache_pos(cache, batch: int) -> jax.Array:
    """Per-sequence decode positions: max over 'pos' leaves → (B,).

    Leaves are (L, B) (stacked per segment); layers advance together so the
    max across layers is exact. RWKV/RG-LRU caches have no pos (O(1) state);
    fall back to zeros — their layers don't use positions."""
    poses = []

    def visit(path, x):
        if path and path[-1] == "pos":
            v = x
            while v.ndim > 1:
                v = v.max(axis=0)
            poses.append(jnp.broadcast_to(v, (batch,)))

    _walk(cache, (), visit)
    if not poses:
        return jnp.zeros((batch,), jnp.int32)
    out = poses[0]
    for p in poses[1:]:
        out = jnp.maximum(out, p)
    return out


def _walk(tree, path, visit):
    if isinstance(tree, dict):
        for k in sorted(tree):
            _walk(tree[k], path + (k,), visit)
    else:
        visit(path, tree)


# --------------------------------------------------------------------------
# analytic parameter counts (roofline 6ND)
# --------------------------------------------------------------------------

def param_count_from_tree(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


@functools.lru_cache(maxsize=64)
def _count_cache(cfg: ModelConfig, active_only: bool) -> int:
    model = build(cfg)
    shapes = jax.eval_shape(lambda r: model.init(r)[0], jax.random.key(0))
    total = 0
    routed = 0

    def visit(path, leaf):
        nonlocal total, routed
        total += leaf.size
        if "experts" in path:
            routed += leaf.size

    _walk_shapes(shapes, (), visit)
    if active_only and cfg.num_experts:
        k = cfg.num_experts_per_tok
        total = total - routed + routed * k // cfg.num_experts
    return int(total)


def _walk_shapes(tree, path, visit):
    if isinstance(tree, dict):
        for k in sorted(tree):
            _walk_shapes(tree[k], path + (k,), visit)
    else:
        visit(path, tree)


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    return _count_cache(cfg, active_only)
