"""Sharding rule engine: logical axes → PartitionSpec over the production mesh.

Parameters carry logical-axis tuples (see models/layers.ParamStore). The rule
table maps logical axes to mesh axes; spec construction resolves conflicts
positionally (first dimension wins a mesh axis; later dims fall back to
replication) — this is what makes e.g. expert tensors (experts, embed,
moe_mlp) come out as (model, fsdp, None) without per-tensor special cases.

Activation constraint kinds (shard_activation call sites in models/):
  tokens_bsd   (B,S,d)        batch→dp [, seq→model when seq_parallel]
  heads_bhsd   (B,H,S,hd)     batch→dp, heads→model
  mlp_bsf      (B,S,ff)       batch→dp, ff→model
  logits_bsv   (B,S,V)        batch→dp, vocab→model
  cache_bhsd   (B,KV,S,hd)    batch→dp, KV→model if divisible else S→model
  cache_bsr    (B,S,r)        batch→dp, seq→model (MLA latent)
  moe_ecd/ecf  (E,T,d/f)      experts→model, tokens→dp
  lru_bsw      (B,S,W)        batch→dp, width→model
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingOptions", "ShardingRules"]

Axis = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class ShardingOptions:
    """Per-run distribution knobs (hillclimb levers)."""

    fsdp: bool = True              # shard params over dp axes (ZeRO-3)
    seq_parallel: bool = False     # shard activations' seq dim on model axis
    cache_seq_shard: str = "auto"  # auto | heads | seq — decode cache layout
    expert_parallel: bool = True   # experts on model axis (else fsdp-only)
    logical_overrides: Tuple[Tuple[str, Any], ...] = ()


class ShardingRules:
    def __init__(self, cfg, mesh: Mesh, options: "ShardingOptions | None" = None):
        self.cfg = cfg
        self.mesh = mesh
        self.opt = options if options is not None else ShardingOptions()
        names = mesh.axis_names
        self.dp_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data")
                                              if a in names)
        self.model_axis = "model" if "model" in names else None
        self.model_size = mesh.shape["model"] if self.model_axis else 1
        dp: Axis = self.dp_axes if len(self.dp_axes) > 1 else \
            (self.dp_axes[0] if self.dp_axes else None)
        fsdp_axis: Axis = dp if options.fsdp else None
        self.table: Dict[str, Axis] = {
            "layers": None,
            "vocab": self.model_axis,
            "embed": fsdp_axis,
            "heads": self.model_axis,
            "kv_heads": self.model_axis,
            "mlp": self.model_axis,
            "moe_mlp": self.model_axis,
            "experts": self.model_axis if options.expert_parallel else fsdp_axis,
            "lru": self.model_axis,
            "lora": None,
        }
        for k, v in options.logical_overrides:
            self.table[k] = v
        self.dp: Axis = dp

        kv = max(cfg.num_kv_heads, 1)
        if options.cache_seq_shard == "heads":
            self.cache_on_heads = True
        elif options.cache_seq_shard == "seq":
            self.cache_on_heads = False
        else:
            self.cache_on_heads = (kv % max(self.model_size, 1) == 0
                                   and not cfg.mla)

    # -- divisibility sanitizer -------------------------------------------------
    def _axis_size(self, axis: Axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, str):
            return int(self.mesh.shape[axis])
        out = 1
        for a in axis:
            out *= int(self.mesh.shape[a])
        return out

    def sanitize(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Drop mesh axes that do not divide the dimension (pjit requires
        even tiling for input shardings). Partial drops keep the divisible
        prefix of a composite axis tuple."""
        out = []
        padded = tuple(spec) + (None,) * (len(shape) - len(spec))
        for dim, ax in zip(shape, padded, strict=True):
            if ax is None:
                out.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            kept = []
            size = 1
            for a in axes:
                nxt = size * int(self.mesh.shape[a])
                if dim % nxt == 0:
                    kept.append(a)
                    size = nxt
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)

    # -- params ---------------------------------------------------------------
    def param_spec(self, axes: Tuple[Optional[str], ...],
                   shape: Optional[Tuple[int, ...]] = None) -> P:
        used: set = set()
        out = []
        for ax in axes:
            mapped = self.table.get(ax) if ax is not None else None
            flat = (mapped,) if isinstance(mapped, str) else (mapped or ())
            flat = tuple(a for a in flat if a is not None and a not in used)
            if flat:
                used.update(flat)
                out.append(flat if len(flat) > 1 else flat[0])
            else:
                out.append(None)
        spec = P(*out)
        if shape is not None:
            spec = self.sanitize(spec, shape)
        return spec

    def param_sharding_tree(self, axes_tree, shapes_tree=None):
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        if shapes_tree is None:
            return jax.tree.map(
                lambda a: NamedSharding(self.mesh, self.param_spec(a)),
                axes_tree, is_leaf=is_axes)
        return jax.tree.map(
            lambda a, s: NamedSharding(self.mesh,
                                       self.param_spec(a, tuple(s.shape))),
            axes_tree, shapes_tree, is_leaf=is_axes)

    # -- activations -------------------------------------------------------------
    def activation_spec(self, kind: str) -> P:
        dp, m = self.dp, self.model_axis
        if kind == "tokens_bsd":
            return P(dp, m if self.opt.seq_parallel else None, None)
        if kind == "heads_bhsd":
            return P(dp, m, None, None)
        if kind == "mlp_bsf":
            return P(dp, None, m)
        if kind == "logits_bsv":
            return P(dp, None, m)
        if kind == "cache_bhsd":
            return P(dp, m, None, None) if self.cache_on_heads \
                else P(dp, None, m, None)
        if kind == "cache_bsr":
            return P(dp, m, None)
        if kind in ("moe_ecd", "moe_ecf"):
            return P(m, dp, None)
        if kind == "lru_bsw":
            return P(dp, None, m)
        return P()

    def install(self) -> None:
        """Install the activation-constraint hook used inside model code."""
        from repro.models.layers import set_activation_sharder, set_mesh_context

        set_mesh_context({"mesh": self.mesh, "dp_axes": self.dp_axes,
                          "model_axis": self.model_axis})

        def sharder(x, kind):
            spec = self.activation_spec(kind)
            if len(spec) != x.ndim:
                return x
            spec = self.sanitize(spec, tuple(x.shape))
            if all(s is None for s in spec):
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))

        set_activation_sharder(sharder)

    def uninstall(self) -> None:
        from repro.models.layers import set_activation_sharder, set_mesh_context

        set_activation_sharder(None)
        set_mesh_context(None)

    def __enter__(self) -> "ShardingRules":
        self.install()
        return self

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # -- batch / cache ------------------------------------------------------------
    def batch_spec(self, batch_tree) -> Any:
        def spec(x):
            nd = len(x.shape)
            p = self.sanitize(P(self.dp, *([None] * (nd - 1))), tuple(x.shape))
            return NamedSharding(self.mesh, p)

        return jax.tree.map(spec, batch_tree)

    def cache_sharding_tree(self, cache_tree) -> Any:
        """Cache leaves are keyed dicts; leading axis is the stacked-layers dim."""
        m = self.model_axis

        def walk(tree, path):
            if isinstance(tree, dict):
                return {k: walk(v, path + (k,)) for k, v in tree.items()}
            key = path[-1] if path else ""
            nd = len(tree.shape)
            if key in ("k", "v"):          # (L, B, S, KV, hd)
                if self.cache_on_heads:
                    spec = P(None, self.dp, None, m, None)
                else:
                    spec = P(None, self.dp, m, None, None)
            elif key in ("ckv", "krope"):  # (L, B, S, r)
                spec = P(None, self.dp, m, None)
            elif key in ("cross_k", "cross_v"):  # (L, B, KV, Ssrc, hd)
                spec = P(None, self.dp, m if self.cache_on_heads else None,
                         None, None)
            elif key == "wkv":             # (L, B, H, K, V)
                spec = P(None, self.dp, m, None, None)
            elif key in ("h",):            # (L, B, W)
                spec = P(None, self.dp, m)
            elif key in ("conv",):         # (L, B, w-1, W)
                spec = P(None, self.dp, None, m)
            elif key in ("tm_prev", "cm_prev"):  # (L, B, d)
                spec = P(None, self.dp, None)
            else:                           # pos scalars etc.
                spec = P(*([None] * nd))
            if len(spec) != nd:
                spec = P(*([None] * nd))
            spec = self.sanitize(spec, tuple(tree.shape))
            return NamedSharding(self.mesh, spec)

        return walk(cache_tree, ())

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())
