"""Sharded, journal-integrated checkpoint store.

Layout: <root>/<tag>/
    manifest.json       — pytree structure, shapes, dtypes, shard map, digest
    shard-<i>.npz.zst   — compressed npz of this host's param shards
                          (repro.wire tagged frame: zstd when installed,
                          zlib fallback — self-describing either way)

Design points:
  - atomic publish: writes go to <tag>.tmp/ and are renamed into place only
    after the manifest fsync — a crash mid-save never corrupts the latest
    complete checkpoint (the durable-execution contract for large payloads).
    Individual files use the same content-addressed atomic-write helper as
    the result cache (repro.cache.store.atomic_write_bytes): immutable
    bytes published by tmp-write + rename, never mutated in place;
  - the journal stores only the checkpoint *ref* (tag + digest), never
    tensors (§4.2: event history + blob store);
  - async mode hands the (already device-fetched) arrays to a writer thread
    so the train step resumes immediately — the save is off the critical
    path (the §5 "bottlenecks magnify" fix);
  - multi-host: each host writes its own shard file; the manifest records
    the host count. On restore each host reads its file. (Single-host in
    this container, but the layout is the production one.)
"""
from __future__ import annotations

import hashlib
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


from repro.cache.store import atomic_write_bytes
from repro.wire import JsonCodec, compress, decompress

__all__ = ["CheckpointStore"]


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield "/".join(path), tree


def _unflatten(flat: Dict[str, Any], like):
    def build(tree, path):
        if isinstance(tree, dict):
            return {k: build(v, path + (str(k),)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            vals = [build(v, path + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(vals)
        return flat["/".join(path)]

    return build(like, ())


class CheckpointStore:
    def __init__(self, root: str, host_index: int = 0, num_hosts: int = 1,
                 keep: int = 3):
        self.root = root
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._async_thread: Optional[threading.Thread] = None
        self._async_err: Optional[BaseException] = None

    # -- save -------------------------------------------------------------
    def save(self, tag: str, tree: Any, extra_meta: Optional[dict] = None,
             async_: bool = False) -> str:
        """Returns the journal ref 'tag@digest'. async_: returns immediately
        after fetching arrays to host; IO happens on a writer thread."""
        flat = {k: np.asarray(v) for k, v in _flatten(tree)}
        digest = self._digest(flat)  # hash the tensors exactly once per save
        if async_:
            self.wait()  # one in-flight save at a time

            def work():
                try:
                    self._write(tag, flat, tree, extra_meta, digest)
                except BaseException as e:  # surfaced on next wait()
                    self._async_err = e

            self._async_thread = threading.Thread(target=work, daemon=True)
            self._async_thread.start()
        else:
            self._write(tag, flat, tree, extra_meta, digest)
        return f"{tag}@{digest}"

    def wait(self) -> None:
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None
        if self._async_err is not None:
            err, self._async_err = self._async_err, None
            raise err

    @staticmethod
    def _digest(flat: Dict[str, np.ndarray]) -> str:
        """Content-true digest: keys, dtypes, shapes AND the tensor bytes.

        The digest is the cache/journal contract for snapshots — a CKPT
        record's ref must be falsifiable against what the store actually
        holds. Hashing only the structure (the pre-fix behaviour) made
        ``resolve()`` blind to corruption and tag swaps with matching shapes.
        """
        h = hashlib.sha256()
        for k in sorted(flat):
            a = flat[k]
            h.update(k.encode())
            h.update(str(a.dtype).encode())
            h.update(str(a.shape).encode())
            h.update(np.ascontiguousarray(a).tobytes())
        return h.hexdigest()[:16]

    def _write(self, tag: str, flat: Dict[str, np.ndarray], tree: Any,
               extra_meta: Optional[dict],
               digest: Optional[str] = None) -> None:
        final = os.path.join(self.root, tag)
        tmp = final + f".tmp.{self.host_index}"
        os.makedirs(tmp, exist_ok=True)
        # shard file for this host
        shard_path = os.path.join(tmp, f"shard-{self.host_index}.npz.zst")
        import io

        buf = io.BytesIO()
        np.savez(buf, **{k.replace("/", "|"): v for k, v in flat.items()})
        comp = compress(buf.getvalue(), level=3)
        atomic_write_bytes(shard_path, comp)
        manifest = {
            "tag": tag,
            "digest": digest if digest is not None else self._digest(flat),
            "digest_kind": "content",  # keys+dtypes+shapes+tensor bytes
            "num_hosts": self.num_hosts,
            "written_by": self.host_index,
            "time": time.time(),  # record timestamp
            "entries": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                        for k, v in flat.items()},
            "meta": extra_meta or {},
        }
        mpath = os.path.join(tmp, "manifest.json")
        atomic_write_bytes(mpath, JsonCodec().encode(manifest, pretty=True))
        # atomic publish
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        """GC by BASE tag: companion tags ('<base>-opt' etc.) live and die
        with their base checkpoint."""
        bases = [t for t in self.list() if "-" not in t]
        for base in bases[: -self.keep]:
            for tag in self.list():
                if tag == base or tag.startswith(base + "-"):
                    shutil.rmtree(os.path.join(self.root, tag),
                                  ignore_errors=True)

    # -- load -------------------------------------------------------------
    def list(self) -> List[str]:
        out = []
        for name in sorted(os.listdir(self.root)):
            if os.path.exists(os.path.join(self.root, name, "manifest.json")):
                out.append(name)
        return out

    def latest(self, companions: Tuple[str, ...] = ()) -> Optional[str]:
        """Newest base tag, optionally requiring its companion tags.

        ``companions`` are tag suffixes (e.g. ``("-opt",)``) that must also
        exist for a base tag to count: a crash between the (sync) params
        save and the (async) optimizer save leaves a half-published pair,
        and recovery must fall back to the newest *complete* one instead of
        failing forever on the missing shard.
        """
        tags = [t for t in self.list() if "-" not in t]
        if companions:
            have = set(self.list())
            tags = [t for t in tags if all(t + c in have for c in companions)]
        return tags[-1] if tags else None

    def manifest(self, tag: str) -> dict:
        with open(os.path.join(self.root, tag, "manifest.json"), "rb") as fh:
            return JsonCodec().decode(fh.read())

    def _load_flat(self, tag: str) -> Dict[str, np.ndarray]:
        """Load this host's full shard file as a flat {path: array} map."""
        path = os.path.join(self.root, tag,
                            f"shard-{self.host_index}.npz.zst")
        with open(path, "rb") as fh:
            raw = decompress(fh.read())
        import io

        npz = np.load(io.BytesIO(raw))
        return {k.replace("|", "/"): npz[k] for k in npz.files}

    def restore(self, tag: str, like: Any, dtype_map: Optional[Callable] = None
                ) -> Any:
        """Restore into the structure of ``like`` (shapes validated)."""
        return self._build(self._load_flat(tag), tag, like)

    @staticmethod
    def _build(flat: Dict[str, np.ndarray], tag: str, like: Any) -> Any:
        """Validate a loaded flat map against ``like`` and unflatten it."""
        like_flat = dict(_flatten(like))
        missing = set(like_flat) - set(flat)
        if missing:
            raise KeyError(f"checkpoint {tag} missing keys: {sorted(missing)[:5]}")
        for k, ref in like_flat.items():
            if tuple(flat[k].shape) != tuple(np.shape(ref)):
                raise ValueError(
                    f"shape mismatch at {k}: ckpt {flat[k].shape} vs "
                    f"model {np.shape(ref)}")
        return _unflatten(flat, like)

    def resolve(self, ref: str, like: Any) -> Any:
        """Resolve a journal ref 'tag@digest' with content verification.

        Two checks, both against the ref's digest: the manifest's recorded
        digest (catches a tag swapped for a different checkpoint) and a
        digest recomputed from the restored bytes (catches on-disk
        corruption or tampering the manifest cannot know about).

        Checkpoints written before digests became content-true (manifest
        lacks ``digest_kind: content``) get only the manifest-level check —
        their structure-only digests can never match a recomputed content
        hash, and wedging an intact legacy run_dir behind a false
        "tampered" error would be worse than the old blindness.
        """
        tag, _, digest = ref.partition("@")
        man = self.manifest(tag)
        if digest and man["digest"] != digest:
            raise ValueError(f"checkpoint digest mismatch for {ref}")
        flat = self._load_flat(tag)  # loaded once: verified AND restored from
        if digest and man.get("digest_kind") == "content":
            # recompute over the FULL stored shard, not the keys ``like``
            # happens to select — partial restores must not mask tampering
            got = self._digest(flat)
            if got != digest:
                raise ValueError(
                    f"checkpoint content mismatch for {ref}: stored bytes "
                    f"hash to {got} (corrupted or tampered shard)")
        return self._build(flat, tag, like)
