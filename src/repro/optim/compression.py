"""Gradient compression for cross-pod reduction: low-precision + error feedback.

At multi-pod scale the gradient reduce-scatter over DCI/ICI is a dominant
collective. Compressing gradients to bf16 (or int8 with per-block scales)
before the reduction halves (quarters) those bytes; ERROR FEEDBACK carries
the quantization residual into the next step so the compression bias does
not accumulate (Seide et al. / 1-bit Adam lineage — convergence-neutral in
expectation for smooth losses).

Usage (wired as an optional stage in the trainer):
    comp = GradCompressor(kind="bf16")      # or "int8"
    cgrads, state = comp.compress(grads, state)   # before psum/reduce
    grads = comp.decompress(cgrads)               # after reduction

The compressed representation is itself a pytree of jax arrays, so it works
under jit/pjit and GSPMD reduces the compressed leaves directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["GradCompressor"]

_BLOCK = 256  # int8 scale granularity (per trailing block)


@dataclass(frozen=True)
class GradCompressor:
    kind: str = "bf16"   # bf16 | int8 | none

    # -- error-feedback state ------------------------------------------------
    def init_state(self, grads) -> Any:
        if self.kind == "none":
            return None
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    # -- compress -------------------------------------------------------------
    def compress(self, grads, err_state) -> Tuple[Any, Any]:
        """(compressed, new_err_state). Residual = (g+e) - Q(g+e)."""
        if self.kind == "none":
            return grads, err_state

        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q = self._quantize(corrected)
            deq = self._dequantize(q)
            return q, corrected - deq

        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(err_state)
        out = [one(g, e) for g, e in zip(flat_g, flat_e, strict=True)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    def decompress(self, compressed) -> Any:
        if self.kind == "none":
            return compressed
        return jax.tree.map(self._dequantize, compressed,
                            is_leaf=self._is_q)

    # -- codecs ----------------------------------------------------------------
    def _quantize(self, x: jax.Array):
        if self.kind == "bf16":
            return x.astype(jnp.bfloat16)
        # int8 with per-block absmax scales
        flat = x.reshape(-1)
        pad = (-flat.size) % _BLOCK
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, _BLOCK)
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32),
                "shape": x.shape, "n": x.size}

    def _dequantize(self, q):
        if self.kind == "bf16" or not self._is_q(q):
            return q.astype(jnp.float32) if hasattr(q, "astype") else q
        flat = (q["q"].astype(jnp.float32) * q["scale"]).reshape(-1)[: q["n"]]
        return flat.reshape(q["shape"])

    @staticmethod
    def _is_q(x) -> bool:
        return isinstance(x, dict) and set(x) == {"q", "scale", "shape", "n"}

    # -- accounting --------------------------------------------------------------
    def bytes_ratio(self) -> float:
        return {"none": 1.0, "bf16": 0.5,
                "int8": 0.25 + 4.0 / _BLOCK}[self.kind]
