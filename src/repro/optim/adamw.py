"""AdamW + global-norm clipping + LR schedules, built from scratch.

Optimizer state shards exactly like the parameters (the sharding engine maps
the same logical axes), giving ZeRO-3-equivalent state partitioning under
FSDP rules. ``state_dtype="bfloat16"`` halves m/v memory (with stochastic-
rounding-free simple cast — documented trade-off for the 671B config).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_warmup_cosine"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"     # or "bfloat16" for the 671B memory mode
    schedule: str = "cosine"         # cosine | constant
    warmup_steps: int = 100
    total_steps: int = 10_000


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


def cosine_schedule(step, base_lr: float, warmup: int, total: int):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(warmup, 1))
    progress = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return base_lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * progress))


def linear_warmup_cosine(cfg: AdamWConfig) -> Callable[[Any], jax.Array]:
    if cfg.schedule == "constant":
        return lambda step: jnp.asarray(cfg.lr, jnp.float32)
    return lambda step: cosine_schedule(step, cfg.lr, cfg.warmup_steps,
                                        cfg.total_steps)


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, cfg: AdamWConfig
                 ) -> Tuple[Any, Any, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics). Pure; jit/pjit-safe."""
    step = state["step"]
    lr = linear_warmup_cosine(cfg)(step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v, strict=True)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
