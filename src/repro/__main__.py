"""Command-line entry point: ``python -m repro``.

Operational tooling for durable stores — no training or execution logic
lives here. The first subcommand family is ``workflows``: inspect pending
interrupt suspensions across a :class:`~repro.workflow.WorkflowStore` and
answer one from a terminal::

    python -m repro workflows list --store ./wf
    python -m repro workflows show --store ./wf order-ab12cd34
    python -m repro workflows resume --store ./wf --registry shop.flows:REGISTRY \\
        order-ab12cd34 --input approve=true

``list`` and ``show`` need only the on-disk store (meta.json + journal);
``resume`` additionally imports the graph-factory registry named by
``--registry module:attr`` so the workflow can actually continue. ``--input``
values are parsed as JSON when possible and fall back to raw strings, so
``--input approve=true`` injects a boolean and ``--input note=hi`` a string.

The journal-lifecycle family (docs/journal-lifecycle.md) operates on a
journal *path* — a run's ``runs/<id>/journal.wal`` or a workflow store's
``<id>/journal.wal`` — while the owning process is stopped::

    python -m repro compact ./state/runs/etl/journal.wal --keep-since 120
    python -m repro lineage ./state/runs/etl/journal.wal --node train --depth 2

``compact`` folds committed history into one digest-chained SNAPSHOT record
(``--keep-since N`` retains logical seqs >= N as addressable suffix
records); ``lineage`` projects and queries the provenance index.

The ``trace`` subcommand (docs/observability.md) reconstructs a run's
per-node timeline and critical path from its journal — compacted or not —
optionally merged with the ``spans.jsonl`` a traced run wrote next to it::

    python -m repro trace ./state/runs/etl
    python -m repro trace ./state/runs/etl --chrome etl.trace.json
    python -m repro trace ./state/runs/etl/journal.wal --json

A run *directory* implies ``journal.wal`` inside it and auto-discovers
``spans.jsonl``; ``--chrome PATH`` additionally writes a Chrome-trace /
Perfetto file (``chrome://tracing``, https://ui.perfetto.dev).

The ``lint`` subcommand (docs/static-analysis.md) runs the static-analysis
suite — replay-safety of task functions and framework invariants — over a
tree, honouring the committed ``.repro-lint-baseline.json``::

    python -m repro lint src/ tests/ benchmarks/
    python -m repro lint src/ --select RS --json
    python -m repro lint --explain RS101
"""

from __future__ import annotations

import argparse
import importlib
import json
import sys
import time
from typing import Any, Dict, List, Optional

from repro.core.durable import Journal
from repro.workflow import WorkflowRunner, WorkflowStore
from repro.workflow.api import WorkflowInterruptTimeout

__all__ = ["main"]


def _pending(store: WorkflowStore, workflow_id: str) -> Optional[Dict[str, Any]]:
    """The unanswered SUSPEND of one workflow, or None (journal may be absent)."""
    try:
        with Journal(store.journal_path(workflow_id), sync="never") as j:
            rec = WorkflowRunner._pending_suspend_from(list(j.records()))
    except FileNotFoundError:
        return None
    if rec is None:
        return None
    info: Dict[str, Any] = {
        "node": rec.node_id,
        "interrupt": str(rec.meta.get("interrupt", "")),
    }
    deadline = rec.meta.get("deadline")
    if deadline is not None:
        info["deadline"] = float(deadline)
        info["on_timeout"] = str(rec.meta.get("on_timeout", ""))
        # wall-clock: deadline is a journaled absolute wall time
        info["expired"] = time.time() >= float(deadline)
    return info


def _row(store: WorkflowStore, workflow_id: str) -> Dict[str, Any]:
    meta = store.meta(workflow_id)
    return {
        "id": workflow_id,
        "workflow": meta.get("workflow", "?"),
        "status": meta.get("status", "?"),
        "pending": _pending(store, workflow_id),
    }


def _describe_pending(pending: Optional[Dict[str, Any]]) -> str:
    if not pending:
        return "-"
    desc = f"{pending['interrupt']}@{pending['node']}"
    if "deadline" in pending:
        state = "EXPIRED" if pending["expired"] else "pending"
        # wall-clock: deadline is a journaled absolute wall time
        remain = pending["deadline"] - time.time()
        desc += f" ({state}, t{remain:+.0f}s, on_timeout={pending['on_timeout']})"
    return desc


def _cmd_list(args: argparse.Namespace) -> int:
    store = WorkflowStore(args.store)
    rows = [_row(store, wid) for wid in store.list()]
    if args.pending:
        rows = [r for r in rows if r["pending"]]
    if args.json:
        print(json.dumps(rows, indent=2, sort_keys=True))
        return 0
    if not rows:
        print("no workflows" + (" with pending interrupts" if args.pending else ""))
        return 0
    width = max(len(r["id"]) for r in rows)
    for r in rows:
        print(
            f"{r['id']:<{width}}  {r['workflow']:<12} {r['status']:<10} "
            f"{_describe_pending(r['pending'])}"
        )
    return 0


def _cmd_show(args: argparse.Namespace) -> int:
    store = WorkflowStore(args.store)
    meta = store.meta(args.workflow_id)
    meta["pending_interrupt"] = _pending(store, args.workflow_id)
    print(json.dumps(meta, indent=2, sort_keys=True, default=str))
    return 0


def _parse_inputs(pairs: List[str]) -> Dict[str, Any]:
    inputs: Dict[str, Any] = {}
    for pair in pairs:
        key, sep, raw = pair.partition("=")
        if not sep or not key:
            raise SystemExit(f"--input expects k=v, got {pair!r}")
        try:
            inputs[key] = json.loads(raw)
        except ValueError:
            inputs[key] = raw  # bare strings need no quoting
    return inputs


def _load_registry(spec: str) -> Any:
    module_name, sep, attr = spec.partition(":")
    if not sep or not attr:
        raise SystemExit(f"--registry expects module:attr, got {spec!r}")
    try:
        module = importlib.import_module(module_name)
    except ImportError as exc:
        raise SystemExit(f"cannot import registry module {module_name!r}: {exc}") from None
    try:
        return getattr(module, attr)
    except AttributeError:
        raise SystemExit(f"module {module_name!r} has no attribute {attr!r}") from None


def _cmd_resume(args: argparse.Namespace) -> int:
    registry = _load_registry(args.registry)
    runner = WorkflowRunner(registry, args.store, journal_sync=args.journal_sync)
    inputs = _parse_inputs(args.input)
    try:
        result = runner.resume(args.workflow_id, inputs=inputs or None)
    except WorkflowInterruptTimeout as exc:
        print(f"escalated: {exc}", file=sys.stderr)
        return 3
    pending = _pending(runner.store, args.workflow_id)
    print(
        json.dumps(
            {
                "id": result.workflow_id,
                "status": result.status,
                "interrupt": result.interrupt or None,
                "node": result.node or None,
                "pending": pending,
            },
            indent=2,
            sort_keys=True,
        )
    )
    return 0


def _cmd_compact(args: argparse.Namespace) -> int:
    from repro.journal import CompactionError, compact_journal

    try:
        stats = compact_journal(
            args.journal, keep_since=args.keep_since, dry_run=args.dry_run
        )
    except CompactionError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 1
    obj = stats.to_obj()
    if args.json:
        print(json.dumps(obj, indent=2, sort_keys=True))
        return 0
    verb = "would fold" if stats.dry_run else "folded"
    print(
        f"{verb} {stats.folded} records into SNAPSHOT "
        f"({stats.state_records} live, base_seq={stats.base_seq}, "
        f"chain={stats.chain}); "
        f"{stats.before_records} -> {stats.after_records} records, "
        f"{stats.bytes_before} -> {stats.bytes_after} bytes"
    )
    return 0


def _cmd_lineage(args: argparse.Namespace) -> int:
    import os

    from repro.journal import LineageIndex

    if not os.path.exists(args.journal):
        print(f"error: no journal at {args.journal!r}", file=sys.stderr)
        return 1
    with Journal(args.journal, sync="never") as j:
        idx = LineageIndex.build(j)
    if args.node:
        out: Any = idx.provenance(args.node, depth=args.depth)
        if args.consumers:
            out = {"provenance": out, "consumers": idx.consumers(args.node)}
    elif args.json:
        out = idx.to_obj()
    else:
        for n in idx.nodes():
            e = idx.entry(n)
            print(
                f"{n}: out={e['output_digest'][:12]} "
                f"ctx={e['context_digest'][:12]} in={e['input_digest'][:12]} "
                f"deps={','.join(e['deps']) or '-'}"
            )
        return 0
    print(json.dumps(out, indent=2, sort_keys=True))
    return 0


def _cmd_trace(args: argparse.Namespace) -> int:
    import os

    from repro.obs.sinks import read_spans
    from repro.obs.timeline import Timeline

    journal = args.target
    spans_path = args.spans
    if os.path.isdir(journal):
        if spans_path is None:
            candidate = os.path.join(journal, "spans.jsonl")
            spans_path = candidate if os.path.exists(candidate) else None
        journal = os.path.join(journal, "journal.wal")
    if not os.path.exists(journal):
        print(f"error: no journal at {journal!r}", file=sys.stderr)
        return 1
    spans = list(read_spans(spans_path)) if spans_path else None
    tl = Timeline.from_journal(journal, spans=spans)
    if args.chrome:
        # Prefer the real span log (run/rpc/task lanes); synthesize from the
        # journal-derived timeline when the run was never live-traced.
        from repro.obs.sinks import chrome_trace

        obj = chrome_trace(spans) if spans else tl.to_chrome()
        with open(args.chrome, "w", encoding="utf-8") as fh:
            json.dump(obj, fh)
        print(f"wrote chrome trace: {args.chrome}", file=sys.stderr)
    if args.json:
        print(json.dumps(tl.to_obj(), indent=2, sort_keys=True))
    else:
        print(tl.render_text())
    return 0


def build_parser() -> argparse.ArgumentParser:
    """The ``python -m repro`` argument parser (exposed for tests)."""
    parser = argparse.ArgumentParser(prog="repro", description=__doc__)
    sub = parser.add_subparsers(dest="command", required=True)

    wf = sub.add_parser("workflows", help="inspect and answer durable workflows")
    wfsub = wf.add_subparsers(dest="workflows_command", required=True)

    p_list = wfsub.add_parser("list", help="list workflows and pending interrupts")
    p_list.add_argument("--store", required=True, help="WorkflowStore base directory")
    p_list.add_argument("--pending", action="store_true", help="only suspended entries")
    p_list.add_argument("--json", action="store_true", help="machine-readable output")
    p_list.set_defaults(fn=_cmd_list)

    p_show = wfsub.add_parser("show", help="full meta + pending interrupt of one id")
    p_show.add_argument("--store", required=True)
    p_show.add_argument("workflow_id")
    p_show.set_defaults(fn=_cmd_show)

    p_resume = wfsub.add_parser("resume", help="answer an interrupt and continue")
    p_resume.add_argument("--store", required=True)
    p_resume.add_argument(
        "--registry",
        required=True,
        help="module:attr naming the WorkflowRegistry with the graph factories",
    )
    p_resume.add_argument(
        "--input",
        action="append",
        default=[],
        metavar="K=V",
        help="interrupt answer (JSON value, falls back to raw string); repeatable",
    )
    p_resume.add_argument(
        "--journal-sync", default="always", choices=("always", "batch", "never")
    )
    p_resume.add_argument("workflow_id")
    p_resume.set_defaults(fn=_cmd_resume)

    p_compact = sub.add_parser(
        "compact", help="fold committed journal history into a SNAPSHOT record"
    )
    p_compact.add_argument("journal", help="path to the journal file (quiescent)")
    p_compact.add_argument(
        "--keep-since",
        type=int,
        default=None,
        metavar="SEQ",
        help="retain logical record seqs >= SEQ as addressable suffix records",
    )
    p_compact.add_argument(
        "--dry-run", action="store_true", help="report what would fold; write nothing"
    )
    p_compact.add_argument("--json", action="store_true", help="machine-readable stats")
    p_compact.set_defaults(fn=_cmd_compact)

    p_lineage = sub.add_parser(
        "lineage", help="project and query the journal's provenance index"
    )
    p_lineage.add_argument("journal", help="path to the journal file")
    p_lineage.add_argument(
        "--node", default=None, help="print this node's provenance tree"
    )
    p_lineage.add_argument(
        "--depth",
        type=int,
        default=None,
        help="bound the provenance traversal depth (default: unbounded)",
    )
    p_lineage.add_argument(
        "--consumers",
        action="store_true",
        help="with --node: also list downstream consumers",
    )
    p_lineage.add_argument(
        "--json", action="store_true", help="full projection as JSON"
    )
    p_lineage.set_defaults(fn=_cmd_lineage)

    p_trace = sub.add_parser(
        "trace", help="reconstruct a run's per-node timeline and critical path"
    )
    p_trace.add_argument(
        "target", help="run directory (runs/<id>) or journal file path"
    )
    p_trace.add_argument(
        "--spans",
        default=None,
        metavar="PATH",
        help="span log to merge (default: spans.jsonl beside a run directory)",
    )
    p_trace.add_argument(
        "--chrome",
        default=None,
        metavar="PATH",
        help="also write a Chrome-trace/Perfetto JSON file",
    )
    p_trace.add_argument("--json", action="store_true", help="timeline as JSON")
    p_trace.set_defaults(fn=_cmd_trace)

    from repro.analysis.cli import add_lint_parser  # pure stdlib, cheap

    add_lint_parser(sub)
    return parser


def main(argv: Optional[List[str]] = None) -> int:
    """Run the CLI; returns the process exit code."""
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    raise SystemExit(main())
