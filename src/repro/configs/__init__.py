"""Config registry: importing this package registers all architectures."""
from . import archs  # noqa: F401  (registration side effect)
from .base import (ModelConfig, ShapeConfig, SHAPES, REGISTRY, get_config,
                   list_archs, smoke_variant)
from .shapes import ALL_CELLS, cell_applicability, input_specs

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "REGISTRY", "get_config",
           "list_archs", "smoke_variant", "ALL_CELLS", "cell_applicability",
           "input_specs"]
