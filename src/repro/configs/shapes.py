"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) cell.

No device allocation — these feed ``jax.jit(...).lower()`` in the dry-run.
Frontend stubs deliver precomputed embeddings per the assignment brief.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeConfig

__all__ = ["input_specs", "cell_applicability", "ALL_CELLS"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def cell_applicability(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped). The 8 long_500k skips live here."""
    if shape.name == "long_500k":
        if not cfg.subquadratic:
            return False, ("pure full-attention arch: O(S²) attention over a "
                           "512k cache — skipped per brief (sub-quadratic only)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: Optional[int] = None) -> Dict[str, Any]:
    """Model inputs for the given cell (WITHOUT params/cache — the launcher
    adds those from eval_shape)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    tok_dtype = jnp.int32

    if shape.kind == "decode":
        batch: Dict[str, Any] = {"token": _sds((B,), tok_dtype)}
        return batch

    if cfg.family == "vlm" and cfg.frontend == "vision_stub":
        n_vis = cfg.num_frontend_tokens
        return {"tokens": _sds((B, S - n_vis), tok_dtype),
                "patch_embeds": _sds((B, n_vis, cfg.frontend_dim), jnp.bfloat16)}
    if cfg.is_encdec:
        # stub speech frontend: precomputed conformer frames, length = S for
        # train/prefill (stress shape), decode uses source_len_for_decode.
        return {"frames": _sds((B, S, cfg.frontend_dim), jnp.bfloat16),
                "tokens": _sds((B, S), tok_dtype)}
    return {"tokens": _sds((B, S), tok_dtype)}


def ALL_CELLS():
    """[(arch, shape)] — the 40 assigned cells, in deterministic order."""
    from .base import list_archs

    graded = [a for a in list_archs() if a != "serpytor-demo-100m"]
    return [(a, s) for a in graded for s in
            ("train_4k", "prefill_32k", "decode_32k", "long_500k")]
