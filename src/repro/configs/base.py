"""ModelConfig / RunConfig: the single config system for every architecture.

No YAML: configs are frozen dataclasses in Python files (one per assigned
architecture), selected by ``--arch <id>`` via the REGISTRY. Reduced
("smoke") variants are derived mechanically for CPU tests.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "REGISTRY", "register",
           "get_config", "list_archs", "smoke_variant"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 ⇒ d_model // num_heads

    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # stablelm partial rotary
    window: int = 0                  # 0 ⇒ global attention; >0 ⇒ local window
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"                # silu | gelu
    glu: bool = True
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden; 0 ⇒ d_ff
    first_k_dense: int = 0           # leading dense layers (DeepSeek-V3)
    router_aux_coef: float = 0.001
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 256        # tokens per dispatch group (GShard style)
    moe_impl: str = "einsum"         # einsum (GShard baseline) | sort (optimized)

    # MLA (DeepSeek)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # multi-token prediction (DeepSeek-V3)
    mtp: bool = False
    mtp_coef: float = 0.3

    # hybrid (RecurrentGemma / Griffin)
    block_pattern: Tuple[str, ...] = ()   # per-layer kinds, len == num_layers
    lru_width: int = 0
    conv1d_width: int = 4

    # ssm (RWKV6)
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # encoder-decoder (Seamless)
    encoder_layers: int = 0          # >0 ⇒ enc-dec; encoder is bidirectional
    source_len_for_decode: int = 4096  # cross-cache length for decode shapes

    # modality frontends (stubs: input_specs() supplies embeddings)
    frontend: str = "none"           # none | vision_stub | audio_stub
    num_frontend_tokens: int = 0     # vlm: patch tokens prepended
    frontend_dim: int = 0            # embedding dim delivered by the stub

    # numerics / training
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # none | full | dots  (activation ckpt policy)
    z_loss_coef: float = 1e-4

    # attention impl selector (ops.py): auto | ref | pallas | dense
    attn_impl: str = "auto"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.moe_d_ff == 0 and self.num_experts:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.block_pattern:
            assert len(self.block_pattern) == self.num_layers, \
                f"block_pattern len {len(self.block_pattern)} != {self.num_layers}"

    # -- derived -----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: no O(S²) global-attention term."""
        if self.family == "ssm":
            return True
        if self.block_pattern:
            return all(k != "attn" or self.window > 0 for k in self.block_pattern)
        return False

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401 (registers all arch modules)

    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]()


def list_archs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401 (registers all arch modules)

    return tuple(sorted(REGISTRY))


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Mechanically reduced same-family config for CPU smoke tests."""
    n_layers = min(cfg.num_layers, 4)
    if cfg.block_pattern:
        pattern = cfg.block_pattern[:n_layers]
        # keep at least one of each kind present in the original pattern
        kinds = []
        for k in cfg.block_pattern:
            if k not in kinds:
                kinds.append(k)
        pattern = tuple((list(pattern) + kinds)[:n_layers]) if len(set(pattern)) < len(kinds) \
            else pattern
    else:
        pattern = ()
    changes = dict(
        num_layers=n_layers,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(max(1, cfg.num_kv_heads * 4 // cfg.num_heads), 4),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=pattern,
        first_k_dense=min(cfg.first_k_dense, 1),
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
    if cfg.num_experts:
        changes.update(num_experts=min(cfg.num_experts, 8),
                       num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
                       moe_d_ff=64, moe_group_size=32)
    if cfg.mla:
        changes.update(q_lora_rank=64, kv_lora_rank=32,
                       qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
    if cfg.lru_width:
        changes.update(lru_width=128)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, source_len_for_decode=32)
    if cfg.num_frontend_tokens:
        changes.update(num_frontend_tokens=8,
                       frontend_dim=min(cfg.frontend_dim, 64) or 64)
    if cfg.window:
        changes.update(window=16)
    return replace(cfg, name=cfg.name + "-smoke", **changes)
