"""The 10 assigned architectures (+ paper-demo config), exact public configs.

Sources per the assignment brief; see DESIGN.md §5 for family notes.
"""
from __future__ import annotations

from .base import ModelConfig, register

__all__ = []


@register("yi-6b")
def yi_6b() -> ModelConfig:
    # llama-arch GQA [arXiv:2403.04652]
    return ModelConfig(
        name="yi-6b", family="dense", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=4, head_dim=128, d_ff=11008,
        vocab_size=64000, rope_theta=5_000_000.0)


@register("qwen1.5-110b")
def qwen15_110b() -> ModelConfig:
    # QKV bias [hf:Qwen/Qwen1.5 family]
    return ModelConfig(
        name="qwen1.5-110b", family="dense", num_layers=80, d_model=8192,
        num_heads=64, num_kv_heads=8, head_dim=128, d_ff=49152,
        vocab_size=152064, qkv_bias=True, rope_theta=1_000_000.0)


@register("stablelm-1.6b")
def stablelm_16b() -> ModelConfig:
    # partial rotary (25%), LayerNorm [hf:stabilityai/stablelm-2-1_6b]
    return ModelConfig(
        name="stablelm-1.6b", family="dense", num_layers=24, d_model=2048,
        num_heads=32, num_kv_heads=32, head_dim=64, d_ff=5632,
        vocab_size=100352, norm="layernorm", norm_eps=1e-5,
        rope_fraction=0.25)


@register("qwen3-1.7b")
def qwen3_17b() -> ModelConfig:
    # qk_norm, GQA [hf:Qwen/Qwen3 family]
    return ModelConfig(
        name="qwen3-1.7b", family="dense", num_layers=28, d_model=2048,
        num_heads=16, num_kv_heads=8, head_dim=128, d_ff=6144,
        vocab_size=151936, qk_norm=True, tie_embeddings=True,
        rope_theta=1_000_000.0)


@register("granite-moe-3b-a800m")
def granite_moe() -> ModelConfig:
    # 40 experts top-8 (assignment header; hf pointer names a 32e sibling)
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", num_layers=32, d_model=1536,
        num_heads=24, num_kv_heads=8, head_dim=64, d_ff=512,
        vocab_size=49155, num_experts=40, num_experts_per_tok=8,
        moe_d_ff=512, tie_embeddings=True, moe_impl="a2a")


@register("deepseek-v3-671b")
def deepseek_v3() -> ModelConfig:
    # MLA, 1 shared + 256 routed top-8, MTP [arXiv:2412.19437]
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", num_layers=61, d_model=7168,
        num_heads=128, num_kv_heads=128, head_dim=128, d_ff=18432,
        vocab_size=129280, num_experts=256, num_experts_per_tok=8,
        num_shared_experts=1, moe_d_ff=2048, first_k_dense=3,
        mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
        mtp=True, rope_theta=10_000.0, moe_impl="a2a")


@register("internvl2-2b")
def internvl2_2b() -> ModelConfig:
    # InternViT (stub) + InternLM2-1.8b backbone [arXiv:2404.16821]
    return ModelConfig(
        name="internvl2-2b", family="vlm", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=8, head_dim=128, d_ff=8192,
        vocab_size=92553, frontend="vision_stub",
        num_frontend_tokens=256, frontend_dim=1024)


@register("recurrentgemma-9b")
def recurrentgemma_9b() -> ModelConfig:
    # Griffin: (rec, rec, attn) pattern, MQA window 2048 [arXiv:2402.19427]
    L = 38
    pattern = tuple(("rec", "rec", "attn")[i % 3] for i in range(L))
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid", num_layers=L, d_model=4096,
        num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
        vocab_size=256000, block_pattern=pattern, lru_width=4096,
        window=2048, act="gelu", logit_softcap=30.0)


@register("rwkv6-7b")
def rwkv6_7b() -> ModelConfig:
    # Finch: data-dependent decay, attention-free [arXiv:2404.05892]
    return ModelConfig(
        name="rwkv6-7b", family="ssm", num_layers=32, d_model=4096,
        num_heads=64, num_kv_heads=64, head_dim=64, d_ff=14336,
        vocab_size=65536, rwkv_head_size=64, norm="layernorm")


@register("seamless-m4t-large-v2")
def seamless_m4t() -> ModelConfig:
    # enc-dec multimodal backbone; speech frontend stubbed [arXiv:2308.11596]
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio", num_layers=24,
        d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64, d_ff=8192,
        vocab_size=256206, encoder_layers=24, frontend="audio_stub",
        frontend_dim=1024, norm="layernorm", act="relu", glu=False,
        source_len_for_decode=4096)


@register("serpytor-demo-100m")
def serpytor_demo() -> ModelConfig:
    """The paper's own end-to-end demo scale (~100M): used by examples/."""
    return ModelConfig(
        name="serpytor-demo-100m", family="dense", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32000, param_dtype="float32", compute_dtype="float32",
        remat="none")
