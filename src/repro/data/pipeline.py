"""Deterministic sharded data pipeline.

Design requirements (the durable-execution contract, §4.2, applied to data):
  - every batch is a pure function of (seed, step, shard) — replays are
    bit-identical, so a restarted run consumes exactly the same tokens;
  - per-host sharding: host h of H draws rows [h·B/H, (h+1)·B/H) of the
    global batch — no coordination, no duplication;
  - background prefetch thread with a bounded queue hides generation latency.

The source here is a synthetic token stream (zipfian unigram mixture with
deterministic per-document seeds) — the paper has no dataset; examples train
on it end-to-end. A real corpus drops in by replacing ``TokenSource``.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np

__all__ = ["DataConfig", "TokenSource", "ShardedLoader", "batch_digest"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0
    zipf_a: float = 1.3
    prefetch: int = 2


class TokenSource:
    """Deterministic synthetic corpus: batch = f(seed, step, host shard)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0, \
            "global batch must divide across hosts"
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        # zipfian unigram table (shared, seed-derived)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** -cfg.zipf_a
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The (host-local) batch for a given global step. Pure."""
        cfg = self.cfg
        row0 = cfg.host_index * self.local_batch
        rows = []
        for r in range(self.local_batch):
            doc_seed = (cfg.seed * 1_000_003 + step) * 100_003 + row0 + r
            rng = np.random.default_rng(doc_seed)
            toks = rng.choice(cfg.vocab_size, size=cfg.seq_len, p=self._probs)
            rows.append(self._perm[toks])
        return {"tokens": np.stack(rows).astype(np.int32)}


class ShardedLoader:
    """Prefetching iterator over a TokenSource, resumable at any step."""

    def __init__(self, source: TokenSource, start_step: int = 0):
        self.source = source
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=source.cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def batch_digest(batch: Dict[str, np.ndarray]) -> str:
    """Digest used by the durable journal to prove replayed data identity."""
    from repro.wire import payload_digest

    return payload_digest(batch)
