"""Cache-key derivation for the content-addressed result cache.

A cached node result is identified by the triple of digests that already
defines replay identity in the durable journal (docs/journal-format.md §2),
plus the *function digest* that replay gets implicitly from the node id:

    (fn digest, input digest, context digest)

All three components are 16-hex-char truncated sha256 values produced by the
existing digest machinery — ``repro.core.graph.fn_digest`` for the callable
or registry task name, ``repro.wire.payload_digest`` for the injected
inputs, and ``Context.digest()`` for the full ξ fact set. Because the
context digest is part of the key, *any* change to a context entry flips the
key and the stale result is simply never found again — invalidation by
construction, no explicit dirty-tracking (see docs/result-cache.md §4).

The string form ``fn/inputs/context`` doubles as the eviction namespace:
``ResultCache.evict(prefix)`` removes every entry whose id starts with the
prefix, so ``evict(fn_digest)`` drops all results of one task implementation
and ``evict(f"{fn_digest}/{input_digest}")`` narrows to one input set.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["CacheKey"]


@dataclass(frozen=True)
class CacheKey:
    """Content-addressed identity of one node result: three 16-hex digests."""

    fn: str
    inputs: str
    context: str

    @property
    def id(self) -> str:
        """The canonical string form ``fn/inputs/context`` (eviction namespace)."""
        return f"{self.fn}/{self.inputs}/{self.context}"

    def relpath(self) -> str:
        """Blob path relative to a cache root: ``<fn>/<inputs>.<context>``."""
        return f"{self.fn}/{self.inputs}.{self.context}"

    @staticmethod
    def parse(key_id: str) -> "CacheKey":
        """Inverse of :attr:`id` — raises ``ValueError`` on malformed ids."""
        fn, inputs, context = key_id.split("/")
        return CacheKey(fn=fn, inputs=inputs, context=context)

    @staticmethod
    def from_relpath(relpath: str) -> "CacheKey":
        """Inverse of :meth:`relpath` — raises ``ValueError`` when malformed."""
        fn, _, leaf = relpath.replace("\\", "/").partition("/")
        inputs, sep, context = leaf.partition(".")
        if not (fn and sep and inputs and context):
            raise ValueError(f"not a cache blob path: {relpath!r}")
        return CacheKey(fn=fn, inputs=inputs, context=context)
