"""repro.cache — content-addressed node-result cache for incremental re-runs.

The durable journal (``repro.core.durable``) makes one *run* replayable; the
result cache makes work reusable *across* runs, journals, and processes: a
node whose function, inputs, and context ξ all digest to the same values as
a previously committed execution is answered from the cache instead of being
re-executed — the cross-run analogue of Spark-style lineage memoization.

Usage::

    from repro.cache import ResultCache
    from repro.core import LocalExecutor

    cache = ResultCache("runs/result-cache", max_bytes=512 << 20)
    report = LocalExecutor(cache=cache).run(graph)   # cold: executes, stores
    report = LocalExecutor(cache=cache).run(graph)   # warm: all cache hits

Cache hits and stores are journaled (``CACHE_HIT`` / ``CACHE_STORE`` record
kinds) so a cache-accelerated run remains fully replayable and auditable.
The on-disk contract is specified in docs/result-cache.md with the same
rigor as docs/journal-format.md.
"""

from .key import CacheKey
from .store import (
    CachedResult,
    CacheView,
    FileCacheBackend,
    MemoryLRU,
    ResultCache,
    TieredCacheBackend,
    atomic_write_bytes,
)

__all__ = [
    "CacheKey",
    "CachedResult",
    "CacheView",
    "FileCacheBackend",
    "MemoryLRU",
    "ResultCache",
    "TieredCacheBackend",
    "atomic_write_bytes",
]
