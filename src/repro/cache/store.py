"""Result-cache storage: filesystem blob backend + in-memory LRU front.

Layout on disk (shared content-addressed shape with the checkpoint store —
one immutable, atomically-published file per digest-derived name):

    <root>/<fn_digest>/<input_digest>.<context_digest>

Each blob is one checksummed frame — the same ``(length: u32, crc32: u32)``
little-endian header the durable journal uses (docs/journal-format.md §1) —
whose body is a ``repro.wire.payload`` envelope::

    {"v": <output pytree>, "f": <WithContext facts or None>, "o": <output digest>}

A blob that fails the length/crc check or the payload decode is *corrupt*:
it is unlinked and reported as a miss, so the executor falls back to
recomputing the node (never a crash, never a wrong value). Writes are
atomic (tmp + rename), so a crash mid-``put`` leaves either the old blob or
no blob — readers can never observe a torn frame under its final name.

Eviction is two-tier:

  - ``evict(prefix)`` — explicit, namespace-addressed (see ``CacheKey``);
  - a byte budget (``max_bytes``) enforced after every put by deleting the
    least-recently-*used* blobs first (mtime is touched on every hit).
"""

from __future__ import annotations

import binascii
import os
import struct
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Mapping, Optional, Tuple

from repro.wire import encode_payload, payload_digest
from repro.wire.payload import PayloadDecodeError, decode_payload

from .key import CacheKey

__all__ = [
    "CachedResult",
    "CacheView",
    "FileCacheBackend",
    "MemoryLRU",
    "ResultCache",
    "TieredCacheBackend",
    "atomic_write_bytes",
]

_FRAME = struct.Struct("<II")  # (length, crc32) — the journal's frame header


def atomic_write_bytes(path: str, data: bytes, fsync: bool = True) -> None:
    """Publish ``data`` at ``path`` atomically (tmp file + rename).

    Readers either see the complete new bytes or whatever was there before —
    never a partial write. Shared by the cache backend and the checkpoint
    store (both publish immutable content-addressed files).
    """
    tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
    with open(tmp, "wb") as fh:
        fh.write(data)
        fh.flush()
        if fsync:
            os.fsync(fh.fileno())
    os.replace(tmp, path)


@dataclass
class CachedResult:
    """A decoded cache entry: the node output plus its journaled identity."""

    value: Any
    facts: Optional[Mapping[str, Any]]
    output_digest: str


class MemoryLRU:
    """Thread-safe in-memory LRU front holding decoded ``CachedResult``s."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._entries: "OrderedDict[str, CachedResult]" = OrderedDict()

    def get(self, key: CacheKey) -> Optional[CachedResult]:
        """Return the entry for ``key`` (refreshing recency) or None."""
        with self._lock:
            ent = self._entries.get(key.id)
            if ent is not None:
                self._entries.move_to_end(key.id)
            return ent

    def put(self, key: CacheKey, ent: CachedResult) -> None:
        """Insert ``ent``, evicting the least-recently-used overflow."""
        with self._lock:
            self._entries[key.id] = ent
            self._entries.move_to_end(key.id)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)

    def evict(self, prefix: str = "") -> int:
        """Drop every entry whose key id starts with ``prefix``; return count."""
        with self._lock:
            doomed = [k for k in self._entries if k.startswith(prefix)]
            for k in doomed:
                del self._entries[k]
            return len(doomed)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


class FileCacheBackend:
    """Content-addressed blob files under ``root`` with a byte budget.

    The budget is enforced with a cheap running byte total (exact-rescanned
    only inside a sweep) and a low watermark: when a put pushes the total
    past ``max_bytes``, least-recently-used blobs are deleted down to ~90%
    of the budget, so sweeps amortize instead of firing on every put at
    capacity.
    """

    def __init__(self, root: str, max_bytes: Optional[int] = None, fsync: bool = False):
        self.root = root
        self.max_bytes = max_bytes
        self.fsync = fsync
        self.corrupt_drops = 0  # frames that failed the length/crc check
        self._approx_bytes: Optional[int] = None  # lazily seeded running total
        os.makedirs(root, exist_ok=True)
        self._sweep_stale_tmp()

    def _sweep_stale_tmp(self, max_age_s: float = 3600.0) -> None:
        """Remove tmp files orphaned by a crash mid-``atomic_write_bytes``.

        Age-gated so a concurrent writer's in-flight tmp file is left alone;
        anything older than ``max_age_s`` is a leak no rename will ever claim.
        """
        # mtimes are wall-based, so the age gate must compare like with
        # wall-clock: 'now' shares os.path.getmtime()'s epoch
        now = time.time()
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                if ".tmp." not in name:
                    continue
                full = os.path.join(dirpath, name)
                try:
                    if now - os.path.getmtime(full) >= max_age_s:
                        os.remove(full)
                except OSError:
                    pass

    # -- paths ---------------------------------------------------------------
    def path_for(self, key: CacheKey) -> str:
        """Absolute blob path for ``key`` (``<root>/<fn>/<inputs>.<context>``)."""
        return os.path.join(self.root, key.fn, f"{key.inputs}.{key.context}")

    def _blobs(self) -> Iterator[Tuple[str, str]]:
        """Yield (relpath, abspath) for every blob file under the root."""
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                if name.endswith(".tmp") or ".tmp." in name:
                    continue
                full = os.path.join(dirpath, name)
                yield os.path.relpath(full, self.root), full

    # -- blob IO -------------------------------------------------------------
    def put(self, key: CacheKey, body: bytes) -> str:
        """Frame, checksum, and atomically publish ``body``; return its path."""
        path = self.path_for(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        frame = _FRAME.pack(len(body), binascii.crc32(body)) + body
        atomic_write_bytes(path, frame, fsync=self.fsync)
        if self.max_bytes is not None:
            if self._approx_bytes is None:
                self._approx_bytes = self.size_bytes()
            else:
                self._approx_bytes += len(frame)
            if self._approx_bytes > self.max_bytes:
                self._enforce_budget(keep=path)
        return path

    def get(self, key: CacheKey) -> Optional[bytes]:
        """Return the verified body for ``key``, or None (missing/corrupt).

        A short, torn, or checksum-failing frame is deleted on sight so the
        slot can be recomputed and re-stored.
        """
        path = self.path_for(key)
        try:
            with open(path, "rb") as fh:
                data = fh.read()
        except OSError:
            return None
        header = _FRAME.size
        if len(data) < header:
            self.corrupt_drops += 1
            self._drop(path)
            return None
        length, crc = _FRAME.unpack_from(data)
        body = data[header:]
        if len(body) != length or binascii.crc32(body) != crc:
            self.corrupt_drops += 1
            self._drop(path)
            return None
        try:
            os.utime(path)  # recency signal for the byte-budget eviction
        except OSError:
            pass
        return body

    def _drop(self, path: str) -> None:
        try:
            size = os.path.getsize(path)
        except OSError:
            size = 0
        try:
            os.remove(path)
        except OSError:
            return
        if self._approx_bytes is not None:
            self._approx_bytes = max(0, self._approx_bytes - size)

    def discard(self, key: CacheKey) -> None:
        """Remove ``key``'s blob (e.g. its envelope failed to decode)."""
        self._drop(self.path_for(key))

    # -- eviction ------------------------------------------------------------
    def evict(self, prefix: str = "") -> int:
        """Delete every blob whose key id starts with ``prefix``; return count."""
        n = 0
        for rel, full in list(self._blobs()):
            try:
                key = CacheKey.from_relpath(rel)
            except ValueError:
                continue
            if key.id.startswith(prefix):
                self._drop(full)
                n += 1
        self._prune_empty_dirs()
        return n

    def size_bytes(self) -> int:
        """Total bytes currently held by blob files."""
        total = 0
        for _rel, full in self._blobs():
            try:
                total += os.path.getsize(full)
            except OSError:
                pass
        return total

    def _enforce_budget(self, keep: str = "") -> int:
        """Delete least-recently-used blobs down to ~90% of ``max_bytes``.

        The just-written blob (``keep``) survives even when it alone exceeds
        the budget — a cache that rejects its newest entry thrashes. The
        exact rescan happens only here, and the running total is re-seeded
        from it.
        """
        assert self.max_bytes is not None
        target = self.max_bytes * 9 // 10  # low watermark: amortize sweeps
        stat: List[Tuple[float, int, str]] = []
        total = 0
        for _rel, full in self._blobs():
            try:
                st = os.stat(full)
            except OSError:
                continue
            stat.append((st.st_mtime, st.st_size, full))
            total += st.st_size
        dropped = 0
        if total > self.max_bytes:
            for _mtime, size, full in sorted(stat):
                if total <= target:
                    break
                if full == keep:
                    continue
                self._drop(full)
                total -= size
                dropped += 1
        self._approx_bytes = total
        if dropped:
            self._prune_empty_dirs()
        return dropped

    def _prune_empty_dirs(self) -> None:
        for dirpath, dirnames, filenames in os.walk(self.root, topdown=False):
            if dirpath != self.root and not dirnames and not filenames:
                try:
                    os.rmdir(dirpath)
                except OSError:
                    pass


class TieredCacheBackend:
    """Local file tier chained to a *shared* remote tier (fleet-wide dedup).

    The remote tier is any filesystem path every host can reach (NFS mount,
    fuse bucket, ...) holding the same content-addressed blob layout. Reads
    go local-first; a remote hit is *promoted* — copied into the local tier —
    so the next read is local. Writes publish to both tiers, remote last and
    best-effort: the same atomic tmp+rename publish means a crash mid-store
    leaves either the previous remote blob or none, never a torn frame, and
    a failed/unreachable remote publish only increments ``remote_errors`` —
    the run itself never fails because the shared tier is down
    (docs/journal-lifecycle.md §4).

    Only the local tier carries the byte budget; the shared tier's retention
    is the fleet operator's policy (``evict`` does propagate, for wholesale
    invalidation of a bad task version).
    """

    def __init__(self, local: FileCacheBackend, remote: FileCacheBackend):
        self.local = local
        self.remote = remote
        self.remote_hits = 0  # reads answered by the shared tier
        self.promotions = 0  # remote hits copied into the local tier
        self.remote_errors = 0  # failed best-effort remote publishes

    @classmethod
    def at(
        cls,
        local_root: str,
        remote_root: str,
        max_bytes: Optional[int] = None,
        fsync: bool = False,
    ) -> "TieredCacheBackend":
        """Build both tiers from their roots (budget applies locally only)."""
        return cls(
            FileCacheBackend(local_root, max_bytes=max_bytes, fsync=fsync),
            FileCacheBackend(remote_root, fsync=fsync),
        )

    @property
    def corrupt_drops(self) -> int:
        """Corrupt frames dropped across both tiers."""
        return self.local.corrupt_drops + self.remote.corrupt_drops

    def path_for(self, key: CacheKey) -> str:
        """The *local* blob path for ``key`` (promotion target)."""
        return self.local.path_for(key)

    def get(self, key: CacheKey) -> Optional[bytes]:
        """Local tier first; on miss, read through to the shared tier.

        A shared-tier hit is promoted into the local tier so subsequent
        reads on this host stay local.
        """
        body = self.local.get(key)
        if body is not None:
            return body
        body = self.remote.get(key)
        if body is None:
            return None
        self.remote_hits += 1
        try:
            self.local.put(key, body)
            self.promotions += 1
        except OSError:
            pass  # a full/broken local disk must not turn a hit into a miss
        return body

    def put(self, key: CacheKey, body: bytes) -> str:
        """Publish to the local tier, then best-effort to the shared tier.

        Any remote failure — unreachable mount, mid-publish crash — only
        increments ``remote_errors``; the local publish already succeeded
        and the run must never fail because the shared tier is down.
        """
        path = self.local.put(key, body)
        try:
            self._remote_put(key, body)
        except Exception:
            self.remote_errors += 1
        return path

    def _remote_put(self, key: CacheKey, body: bytes) -> None:
        # separable so tests can kill the remote publish (fail_remote_store)
        self.remote.put(key, body)

    def discard(self, key: CacheKey) -> None:
        """Drop ``key`` from both tiers.

        Both, because a blob whose *envelope* is corrupt would otherwise be
        re-promoted from the shared tier on the very next read.
        """
        self.local.discard(key)
        self.remote.discard(key)

    def evict(self, prefix: str = "") -> int:
        """Evict from both tiers; returns the count of *local* blobs removed."""
        n = self.local.evict(prefix)
        self.remote.evict(prefix)
        return n

    def size_bytes(self) -> int:
        """Local-tier bytes (the budgeted tier)."""
        return self.local.size_bytes()

    def remote_size_bytes(self) -> int:
        """Shared-tier bytes (operator-managed, unbudgeted)."""
        return self.remote.size_bytes()


class ResultCache:
    """Two-tier content-addressed result cache: LRU front, file-blob back.

    ``root=None`` runs memory-only (useful for tests and single-process
    runs); with a root, entries survive process restarts and are shared by
    every executor pointed at the same directory. ``remote_root`` chains the
    file tier to a shared :class:`TieredCacheBackend` remote so a fleet
    deduplicates across hosts. All methods are safe to call from executor
    worker threads.
    """

    def __init__(
        self,
        root: Optional[str] = None,
        *,
        backend: Optional[Any] = None,
        memory_entries: int = 256,
        max_bytes: Optional[int] = None,
        fsync: bool = False,
        remote_root: Optional[str] = None,
    ):
        if backend is None and remote_root is not None:
            if root is None:
                raise ValueError("remote_root needs a local root to promote into")
            backend = TieredCacheBackend.at(
                root, remote_root, max_bytes=max_bytes, fsync=fsync
            )
        elif backend is None and root is not None:
            backend = FileCacheBackend(root, max_bytes=max_bytes, fsync=fsync)
        self.backend = backend
        self.memory = MemoryLRU(memory_entries)
        self.stats: Dict[str, int] = {
            "hits": 0,
            "misses": 0,
            "stores": 0,
            "corrupt": 0,
            "evicted": 0,
            "uncacheable": 0,
        }

    def get(self, key: CacheKey) -> Optional[CachedResult]:
        """Look ``key`` up (memory first, then disk); None on miss/corruption."""
        ent = self.memory.get(key)
        if ent is not None:
            self.stats["hits"] += 1
            return ent
        if self.backend is not None:
            before = self.backend.corrupt_drops
            body = self.backend.get(key)
            self.stats["corrupt"] += self.backend.corrupt_drops - before
            if body is not None:
                try:
                    env = decode_payload(body)
                    ent = CachedResult(value=env["v"], facts=env["f"], output_digest=env["o"])
                except (PayloadDecodeError, KeyError, TypeError):
                    # frame checksum passed but the envelope didn't decode —
                    # e.g. written by an incompatible future version
                    self.stats["corrupt"] += 1
                    self.backend.discard(key)
                    ent = None
                if ent is not None:
                    self.memory.put(key, ent)
                    self.stats["hits"] += 1
                    return ent
        self.stats["misses"] += 1
        return None

    def put(
        self, key: CacheKey, value: Any, facts: Optional[Mapping[str, Any]] = None
    ) -> CachedResult:
        """Store a node output (and its WithContext facts) under ``key``.

        Raises whatever the payload codec raises for unserializable values —
        executors treat that as "uncacheable" and continue uncached.
        """
        ent = CachedResult(
            value=value,
            facts=dict(facts) if facts else None,
            output_digest=payload_digest(value),
        )
        body = encode_payload({"v": ent.value, "f": ent.facts, "o": ent.output_digest})
        if self.backend is not None:
            self.backend.put(key, body)
        self.memory.put(key, ent)
        self.stats["stores"] += 1
        return ent

    def evict(self, prefix: str = "") -> int:
        """Remove every entry (both tiers) whose key id starts with ``prefix``.

        ``evict(fn_digest)`` invalidates one task implementation wholesale;
        ``evict("")`` clears the cache. Returns the number of *disk* blobs
        removed (memory-tier evictions are not separately counted).
        """
        self.memory.evict(prefix)
        n = self.backend.evict(prefix) if self.backend is not None else 0
        self.stats["evicted"] += n
        return n

    def clear(self) -> int:
        """Drop everything — ``evict("")``."""
        return self.evict("")

    def restricted(self, deny: "set[str] | frozenset[str]") -> "CacheView":
        """A read-restricted facade: ``deny`` key ids always miss.

        Used by workflow ``fork()``: a child branched at record ``at`` must
        re-execute everything the parent committed *after* that point, so the
        parent's post-``at`` cache stores are masked while the shared prefix
        stays cache-served. Writes still land in this cache.
        """
        return CacheView(self, deny)


class CacheView:
    """Deny-list view over a :class:`ResultCache` (see ``restricted``).

    ``get`` filters; ``put``/``evict``/``clear``/``stats`` delegate to the
    parent, so executors can use a view anywhere a cache is accepted.
    """

    def __init__(self, cache: ResultCache, deny: "set[str] | frozenset[str]"):
        self.cache = cache
        self.deny = frozenset(deny)

    @property
    def stats(self) -> Dict[str, int]:
        """The parent cache's (shared) counters."""
        return self.cache.stats

    def get(self, key: CacheKey) -> Optional[CachedResult]:
        """Parent lookup, except denied key ids miss unconditionally."""
        if key.id in self.deny:
            self.cache.stats["misses"] += 1
            return None
        return self.cache.get(key)

    def put(
        self, key: CacheKey, value: Any, facts: Optional[Mapping[str, Any]] = None
    ) -> CachedResult:
        """Store through to the parent cache."""
        return self.cache.put(key, value, facts=facts)

    def evict(self, prefix: str = "") -> int:
        """Delegate eviction to the parent cache."""
        return self.cache.evict(prefix)

    def clear(self) -> int:
        """Delegate to the parent cache."""
        return self.cache.clear()
