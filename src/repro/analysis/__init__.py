"""Static analysis for durable graphs: replay safety + repo invariants.

Two layers (docs/static-analysis.md):

  - **Replay-safety checking of task functions** (``RS1xx``) — AST-walk a
    callable (or every node of a :class:`~repro.core.graph.Graph`) for
    determinism hazards that would break bit-identical replay: wall-clock
    reads, unseeded RNG, ambient I/O, mutation of captured state, and
    iteration over unordered sets. Wired into graph registration via
    ``Graph.add(..., check="warn"|"error"|"off")`` (default from the
    ``REPRO_LINT`` env var), so the contract travels with user code.
  - **Repo-invariant checks** (``INVxxx``) — lint the framework tree
    itself: journal-kind exhaustiveness across the four switch sites,
    the wall-vs-monotonic clock policy, and blocking calls in the asyncio
    control plane. Run via ``python -m repro lint``.

Pure stdlib (``ast``, ``inspect``, ``dis``); importing this package pulls
in none of the runtime.
"""

from .findings import (
    CODES,
    Finding,
    ReplayUnsafeError,
    ReplayUnsafeWarning,
    load_baseline,
    split_baselined,
    write_baseline,
)
from .invariants import (
    KIND_SITES,
    check_async_blocking,
    check_clock_policy,
    check_kind_exhaustiveness,
    known_kinds,
)
from .replay import check_callable, check_graph, check_source_tasks

__all__ = [
    "CODES",
    "Finding",
    "KIND_SITES",
    "ReplayUnsafeError",
    "ReplayUnsafeWarning",
    "check_async_blocking",
    "check_callable",
    "check_clock_policy",
    "check_graph",
    "check_kind_exhaustiveness",
    "check_source_tasks",
    "known_kinds",
    "lint_paths",
    "load_baseline",
    "split_baselined",
    "write_baseline",
]


def lint_paths(*args, **kwargs):
    """Proxy to :func:`repro.analysis.cli.lint_paths` (lazy import)."""
    from .cli import lint_paths as _lint_paths

    return _lint_paths(*args, **kwargs)
