"""Finding model, detector catalog, and baseline handling for ``repro.analysis``.

A :class:`Finding` is one detector hit: a stable ``code`` (``RS1xx`` replay
safety, ``INVxxx`` repo invariants), a human message, and enough location
context to render and to *fingerprint*. Fingerprints deliberately exclude
the line number — a baseline entry survives unrelated edits that shift the
file, and dies exactly when the flagged code itself changes.

The baseline file (``.repro-lint-baseline.json``, committed at the repo
root) grandfathers pre-existing findings so the lint gate can be adopted on
a tree that is not yet clean, then ratchet: new findings fail, baselined
ones are reported as suppressed. See docs/static-analysis.md §5.
"""

from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "CODES",
    "Finding",
    "ReplayUnsafeError",
    "ReplayUnsafeWarning",
    "load_baseline",
    "split_baselined",
    "write_baseline",
]


class ReplayUnsafeWarning(UserWarning):
    """A task function registered with ``check="warn"`` has determinism hazards."""


class ReplayUnsafeError(ValueError):
    """A task function registered with ``check="error"`` has determinism hazards.

    Carries the offending :class:`Finding` list as ``findings``.
    """

    def __init__(self, message: str, findings: Sequence["Finding"] = ()):
        super().__init__(message)
        self.findings: Tuple["Finding", ...] = tuple(findings)


#: Detector catalog: code -> (category, one-line description). The RS1xx
#: family applies to *task functions* (replay-safety contract,
#: docs/durable-workflows.md §1); the INVxxx family lints the framework
#: tree itself (docs/static-analysis.md §3).
CODES: Dict[str, Tuple[str, str]] = {
    "RS101": ("replay-safety", "wall-clock or monotonic-clock read in a task function"),
    "RS102": ("replay-safety", "unseeded random number generation in a task function"),
    "RS103": ("replay-safety", "ambient I/O (file, env, network, process) in a task function"),
    "RS104": ("replay-safety", "mutation of captured closure/global state in a task function"),
    "RS105": ("replay-safety", "iteration over an unordered set feeding a task result"),
    "RS900": ("replay-safety", "possible determinism hazard (bytecode heuristic, no source)"),
    "INV101": ("journal-kinds", "journal kind not handled or declared-ignored at a switch site"),
    "INV102": ("journal-kinds", "stale kind at a switch site (absent from KNOWN_KINDS)"),
    "INV201": ("clock-policy", "time.time() call site without a policy justification comment"),
    "INV301": ("async-blocking", "blocking call inside an async def in the asyncio control plane"),
    "INV302": ("async-blocking", "threaded control-plane entry point constructed in a coroutine"),
    "E999": ("parse", "file could not be parsed (syntax error or unreadable)"),
}


@dataclass(frozen=True)
class Finding:
    """One detector hit — immutable, hashable, JSON-serializable."""

    code: str
    message: str
    path: str = ""  # repo-relative when produced by the CLI walker
    line: int = 0
    symbol: str = ""  # function qualname / invariant site name
    snippet: str = ""  # offending source line, whitespace-stripped

    def fingerprint(self) -> str:
        """Stable identity for baseline matching.

        Hashes ``(code, path, symbol, snippet)`` — NOT the line number, so
        a baseline entry survives unrelated edits above the flagged line
        and expires exactly when the flagged code itself changes.
        """
        basis = "\x00".join((self.code, self.path, self.symbol, self.snippet))
        return hashlib.sha256(basis.encode()).hexdigest()[:16]

    def category(self) -> str:
        """Catalog category for this finding's code."""
        return CODES.get(self.code, ("unknown", ""))[0]

    def to_obj(self) -> Dict[str, Any]:
        """Plain-dict form (CLI ``--json`` output and baseline entries)."""
        return {
            "code": self.code,
            "message": self.message,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "snippet": self.snippet,
            "fingerprint": self.fingerprint(),
        }

    def render(self) -> str:
        """One human-readable line (``path:line: CODE [symbol] message``)."""
        where = f"{self.path}:{self.line}" if self.path else (self.symbol or "<callable>")
        sym = f" [{self.symbol}]" if self.symbol and self.path else ""
        tail = f" :: {self.snippet}" if self.snippet else ""
        return f"{where}: {self.code}{sym} {self.message}{tail}"


# --------------------------------------------------------------------------
# baseline
# --------------------------------------------------------------------------

BASELINE_VERSION = 1


def load_baseline(path: str) -> Set[str]:
    """Load the fingerprint set from a baseline file (empty set if absent)."""
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as fh:
        obj = json.load(fh)
    return {str(e["fingerprint"]) for e in obj.get("findings", ())}


def write_baseline(path: str, findings: Iterable[Finding]) -> int:
    """Write ``findings`` as the new baseline; returns the entry count.

    Entries keep the human-readable context next to each fingerprint so a
    reviewer can audit what exactly is being grandfathered.
    """
    entries = sorted(
        (
            {
                "fingerprint": f.fingerprint(),
                "code": f.code,
                "path": f.path,
                "symbol": f.symbol,
                "message": f.message,
            }
            for f in findings
        ),
        key=lambda e: (e["path"], e["code"], e["fingerprint"]),
    )
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": BASELINE_VERSION, "findings": entries}, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return len(entries)


def split_baselined(
    findings: Sequence[Finding], baseline: Optional[Set[str]]
) -> Tuple[List[Finding], List[Finding]]:
    """Partition ``findings`` into ``(new, suppressed-by-baseline)``."""
    if not baseline:
        return list(findings), []
    new: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        (suppressed if f.fingerprint() in baseline else new).append(f)
    return new, suppressed
