"""Repo-invariant checks: lint the framework against its own conventions.

Three invariant families, each enforcing a contract the code cannot express
in types (docs/static-analysis.md §3):

  - ``INV101``/``INV102`` — **journal-kind exhaustiveness.** Four
    independent readers switch over record kinds: replay
    (``core/durable.py`` :class:`ReplayCache`), compaction
    (``journal/compact.py`` ``_fold``), lineage (``journal/lineage.py``
    ``apply``), and the run timeline (``obs/timeline.py``
    ``from_records``). Each site must account for EVERY kind in
    ``KNOWN_KINDS`` — either by handling it (a literal comparison /
    membership test against the ``kind``) or by naming it in the site's
    declared ignore-set constant. Without this check, a newly added kind
    compiles clean while silently dropping history at whichever sites
    forgot it.
  - ``INV201`` — **wall-vs-monotonic clock policy.** Inside ``src/repro``,
    ``time.time()`` is legal only for *record timestamps* (journal records,
    span logs, mtime comparisons); duration and liveness math must use
    ``time.monotonic()`` (PRs 5 and 9 swept those call sites by hand).
    Every remaining ``time.time()`` must carry a justification comment —
    ``# record timestamp`` or ``# wall-clock: <reason>`` — on its own or
    the preceding line.
  - ``INV301``/``INV302`` — **async blocking calls.** Beyond ruff's ASYNC
    family: inside ``core/aio`` coroutine bodies, flag ``time.sleep``,
    synchronous file/process/network calls, and construction of the
    *threaded* control-plane entry points (``Gateway``, ``WorkerServer``).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from .replay import StaticResolver, _canonical

__all__ = [
    "KIND_SITES",
    "check_async_blocking",
    "check_clock_policy",
    "check_kind_exhaustiveness",
    "collect_kind_coverage",
    "known_kinds",
]

#: The four journal-kind switch sites: (site name, repo-relative path,
#: scope name within the file, declared ignore-set constant in that module).
KIND_SITES: Tuple[Tuple[str, str, str, str], ...] = (
    ("replay", "src/repro/core/durable.py", "ReplayCache", "REPLAY_IGNORED_KINDS"),
    ("compact", "src/repro/journal/compact.py", "_fold", "DROPPABLE_KINDS"),
    ("lineage", "src/repro/journal/lineage.py", "apply", "LINEAGE_IGNORED_KINDS"),
    ("timeline", "src/repro/obs/timeline.py", "from_records", "TIMELINE_IGNORED_KINDS"),
)

#: Justification marker for a wall-clock call site (INV201). Matches the
#: established ``# record timestamp`` convention plus an explicit
#: ``# wall-clock: <reason>`` escape hatch.
CLOCK_JUSTIFICATION = re.compile(r"#\s*(record timestamp|wall[- ]clock)", re.IGNORECASE)

_WALL_CALLS = frozenset({"time.time", "time.time_ns"})

#: Blocking calls that must not appear inside a coroutine body (INV301).
_ASYNC_BLOCKING = frozenset(
    {
        "time.sleep",
        "open",
        "io.open",
        "os.system",
        "os.popen",
        "socket.create_connection",
        "socket.getaddrinfo",
    }
)
_ASYNC_BLOCKING_PREFIXES = ("subprocess.", "requests.", "urllib.request.")

#: Threaded control-plane entry points whose construction inside a
#: coroutine would run a thread-per-dispatch engine on the event loop
#: (INV302). The asyncio twins are the legal spellings there.
_THREADED_ENTRY_POINTS = frozenset(
    {
        "repro.core.gateway.Gateway",
        "repro.core.server.WorkerServer",
    }
)


def known_kinds() -> Set[str]:
    """The journal-kind vocabulary, read from the runtime source of truth.

    Resolved at call time (not import time) so tests can inject a fake kind
    into ``repro.core.durable.KNOWN_KINDS`` and watch every switch site
    light up.
    """
    from repro.core import durable

    return set(durable.KNOWN_KINDS)


# -- kind exhaustiveness ----------------------------------------------------


def _module_set_constants(tree: ast.Module) -> Dict[str, Set[str]]:
    """Module-level ``NAME = frozenset({...})`` / set / tuple / list of str."""
    consts: Dict[str, Set[str]] = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        if not isinstance(target, ast.Name):
            continue
        values = _literal_strings(node.value)
        if values is not None:
            consts[target.id] = values
    return consts


def _literal_strings(node: ast.AST) -> Optional[Set[str]]:
    """The string elements of a literal set/tuple/list/frozenset(...), else None."""
    if isinstance(node, ast.Call):
        func = node.func
        name = func.id if isinstance(func, ast.Name) else getattr(func, "attr", "")
        if name in ("frozenset", "set") and len(node.args) == 1:
            return _literal_strings(node.args[0])
        return None
    if isinstance(node, (ast.Set, ast.Tuple, ast.List)):
        out: Set[str] = set()
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.add(elt.value)
            else:
                return None  # non-literal element: not a kind vocabulary
        return out
    return None


def _find_scope(tree: ast.Module, scope_name: str) -> Optional[ast.AST]:
    """The first ClassDef/FunctionDef named ``scope_name`` anywhere in the file."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef)):
            if node.name == scope_name:
                return node
    return None


def _mentions_kind(node: ast.AST) -> bool:
    """True if an expression reads a ``kind`` (``rec.kind`` or a kind var)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "kind":
            return True
        if isinstance(sub, ast.Name) and sub.id.endswith("kind"):
            return True
    return False


def _handled_kinds(scope: ast.AST, consts: Dict[str, Set[str]]) -> Set[str]:
    """String literals a scope compares (or membership-tests) a kind against."""
    handled: Set[str] = set()
    for node in ast.walk(scope):
        if not isinstance(node, ast.Compare):
            continue
        operands = [node.left, *node.comparators]
        if not any(_mentions_kind(op) for op in operands):
            continue
        for op, comparator in zip(node.ops, node.comparators, strict=True):
            if isinstance(op, (ast.Eq, ast.NotEq)):
                for cand in (node.left, comparator):
                    if isinstance(cand, ast.Constant) and isinstance(cand.value, str):
                        handled.add(cand.value)
            elif isinstance(op, (ast.In, ast.NotIn)):
                literal = _literal_strings(comparator)
                if literal is not None:
                    handled.update(literal)
                elif isinstance(comparator, ast.Name) and comparator.id in consts:
                    handled.update(consts[comparator.id])
    return handled


def collect_kind_coverage(
    text: str, scope_name: str, ignore_const: str
) -> Tuple[Set[str], Set[str]]:
    """``(handled, declared_ignored)`` kind sets for one switch site's file."""
    tree = ast.parse(text)
    consts = _module_set_constants(tree)
    scope = _find_scope(tree, scope_name)
    handled = _handled_kinds(scope, consts) if scope is not None else set()
    return handled, consts.get(ignore_const, set())


def check_kind_exhaustiveness(
    repo_root: str, sites: Sequence[Tuple[str, str, str, str]] = KIND_SITES
) -> List[Finding]:
    """INV101/INV102 findings across the journal-kind switch sites."""
    import os

    vocabulary = known_kinds()
    findings: List[Finding] = []
    for site, rel_path, scope_name, ignore_const in sites:
        path = os.path.join(repo_root, rel_path)
        if not os.path.exists(path):
            findings.append(
                Finding(
                    code="INV101",
                    message=f"switch site file missing: {rel_path}",
                    path=rel_path,
                    symbol=site,
                )
            )
            continue
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        handled, ignored = collect_kind_coverage(text, scope_name, ignore_const)
        covered = handled | ignored
        for kind in sorted(vocabulary - covered):
            findings.append(
                Finding(
                    code="INV101",
                    message=(
                        f"journal kind {kind!r} is neither handled in "
                        f"{scope_name} nor declared in {ignore_const} — a "
                        f"record of this kind would be silently dropped by "
                        f"the {site} reader"
                    ),
                    path=rel_path,
                    symbol=f"{site}:{kind}",
                    snippet=kind,
                )
            )
        for kind in sorted(covered - vocabulary):
            findings.append(
                Finding(
                    code="INV102",
                    message=(
                        f"kind {kind!r} at the {site} site is not in "
                        "KNOWN_KINDS — stale vocabulary or a typo"
                    ),
                    path=rel_path,
                    symbol=f"{site}:{kind}",
                    snippet=kind,
                )
            )
    return findings


# -- clock policy -----------------------------------------------------------


def check_clock_policy(
    text: str, path: str = "", package: Sequence[str] = ()
) -> List[Finding]:
    """INV201 findings: unjustified ``time.time()`` call sites in one file."""
    try:
        tree = ast.parse(text)
    except SyntaxError:
        return []
    resolver = StaticResolver(tree, package=package)
    lines = text.splitlines()
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        canon = _canonical(resolver, node.func, set())
        if canon not in _WALL_CALLS:
            continue
        lineno = node.lineno
        window = lines[max(0, lineno - 2) : lineno]  # the line + the one above
        if any(CLOCK_JUSTIFICATION.search(ln) for ln in window):
            continue
        findings.append(
            Finding(
                code="INV201",
                message=(
                    f"{canon}() without a policy justification — annotate "
                    "'# record timestamp' (or '# wall-clock: <reason>') if "
                    "this feeds a record, or switch to time.monotonic() if "
                    "it feeds duration/liveness math"
                ),
                path=path,
                line=lineno,
                symbol=canon,
                snippet=lines[lineno - 1].strip() if lineno <= len(lines) else "",
            )
        )
    return findings


# -- async blocking ---------------------------------------------------------


def _async_bodies(tree: ast.Module) -> Iterable[Tuple[str, ast.AsyncFunctionDef]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.AsyncFunctionDef):
            yield node.name, node


def check_async_blocking(
    text: str, path: str = "", package: Sequence[str] = ()
) -> List[Finding]:
    """INV301/INV302 findings for one ``core/aio`` file."""
    try:
        tree = ast.parse(text)
    except SyntaxError:
        return []
    resolver = StaticResolver(tree, package=package)
    lines = text.splitlines()
    findings: List[Finding] = []

    def emit(code: str, message: str, node: ast.AST, symbol: str) -> None:
        lineno = getattr(node, "lineno", 0)
        findings.append(
            Finding(
                code=code,
                message=message,
                path=path,
                line=lineno,
                symbol=symbol,
                snippet=lines[lineno - 1].strip() if 0 < lineno <= len(lines) else "",
            )
        )

    for name, fn_node in _async_bodies(tree):
        for node in ast.walk(fn_node):
            if not isinstance(node, ast.Call):
                continue
            canon = _canonical(resolver, node.func, set())
            if canon is None:
                continue
            if canon in _ASYNC_BLOCKING or canon.startswith(_ASYNC_BLOCKING_PREFIXES):
                emit(
                    "INV301",
                    f"blocking call {canon}() inside coroutine {name!r} — "
                    "stalls the event loop; use the asyncio equivalent or "
                    "offload to a thread",
                    node,
                    name,
                )
            elif canon in _THREADED_ENTRY_POINTS:
                emit(
                    "INV302",
                    f"threaded entry point {canon}(...) constructed inside "
                    f"coroutine {name!r} — use the asyncio twin",
                    node,
                    name,
                )
    return findings
