"""``python -m repro lint`` — run the static-analysis suite over a tree.

Walks the given paths (default: ``src``) for Python files and runs:

  - the replay-safety detectors (RS1xx) over every *task-decorated*
    function found statically (``@atomic_task``, ``@graph.task(...)``, and
    callables passed to ``Graph.add``/``add_stream``);
  - the clock-policy check (INV201) over files inside ``src/repro``;
  - the async-blocking checks (INV301/INV302) over ``src/repro/core/aio``;
  - the journal-kind exhaustiveness check (INV101/INV102) once per
    invocation, against the repo's four switch sites.

Findings already recorded in the committed baseline
(``.repro-lint-baseline.json`` at the repo root) are reported as
suppressed and do not fail the run; anything new exits 1. See
docs/static-analysis.md §5 for the ratchet workflow.
"""

from __future__ import annotations

import argparse
import ast
import json
import os
import sys
from typing import Iterable, List, Optional, Sequence, Tuple

from .findings import CODES, Finding, load_baseline, split_baselined, write_baseline
from .invariants import (
    check_async_blocking,
    check_clock_policy,
    check_kind_exhaustiveness,
)
from .replay import check_source_tasks

__all__ = ["add_lint_parser", "cmd_lint", "find_repo_root", "lint_paths", "main"]

BASELINE_NAME = ".repro-lint-baseline.json"

_SKIP_DIRS = frozenset({"__pycache__", ".git", ".ruff_cache", ".pytest_cache", "build"})


def find_repo_root(start: Optional[str] = None) -> str:
    """Nearest ancestor of ``start`` (default: cwd) holding a pyproject.toml."""
    cur = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.exists(os.path.join(cur, "pyproject.toml")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start or os.getcwd())
        cur = parent


def _iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    """Every ``.py`` file under ``paths`` (files pass through, dirs walk)."""
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py"):
                yield path
            continue
        for dirpath, dirnames, filenames in os.walk(path):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    yield os.path.join(dirpath, fname)


def _package_of(rel_path: str) -> Tuple[str, ...]:
    """Dotted package tuple for a file path like ``src/repro/core/graph.py``."""
    parts = rel_path.replace(os.sep, "/").split("/")
    if "src" in parts:
        parts = parts[parts.index("src") + 1 :]
    if parts and parts[-1].endswith(".py"):
        parts = parts[:-1] if parts[-1] != "__init__.py" else parts[:-1]
    return tuple(p for p in parts if p)


def _rel(path: str, root: str) -> str:
    """Repo-relative, forward-slash form of ``path`` (stable fingerprints)."""
    rel = os.path.relpath(os.path.abspath(path), root)
    return rel.replace(os.sep, "/")


def lint_paths(
    paths: Sequence[str],
    repo_root: Optional[str] = None,
    select: Optional[Sequence[str]] = None,
    kind_checks: bool = True,
) -> List[Finding]:
    """Run every applicable detector over ``paths``; returns raw findings.

    ``select`` filters by code prefix (``["RS"]``, ``["INV201"]``, ...).
    ``kind_checks=False`` skips the repo-level INV101/INV102 pass (used by
    tests that lint synthetic trees with no switch sites).
    """
    root = repo_root or find_repo_root(paths[0] if paths else None)
    findings: List[Finding] = []
    for path in _iter_py_files(paths):
        rel = _rel(path, root)
        try:
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
        except OSError as exc:
            findings.append(
                Finding(code="E999", message=f"unreadable: {exc}", path=rel)
            )
            continue
        try:
            ast.parse(text)
        except SyntaxError as exc:
            findings.append(
                Finding(
                    code="E999",
                    message=f"syntax error: {exc.msg}",
                    path=rel,
                    line=exc.lineno or 0,
                )
            )
            continue
        package = _package_of(rel)
        findings.extend(check_source_tasks(text, path=rel, package=package))
        if rel.startswith("src/repro/"):
            findings.extend(check_clock_policy(text, path=rel, package=package))
        if rel.startswith("src/repro/core/aio/"):
            findings.extend(check_async_blocking(text, path=rel, package=package))
    if kind_checks and os.path.isdir(os.path.join(root, "src", "repro")):
        # repo-level pass: only meaningful when the framework tree itself
        # is under this root (out-of-tree user code has no switch sites)
        findings.extend(check_kind_exhaustiveness(root))
    if select:
        prefixes = tuple(s.strip() for s in select if s.strip())
        findings = [f for f in findings if f.code.startswith(prefixes)]
    findings.sort(key=lambda f: (f.path, f.line, f.code, f.symbol))
    return findings


def add_lint_parser(subparsers: "argparse._SubParsersAction") -> None:
    """Register the ``lint`` subcommand on ``python -m repro``'s parser."""
    p = subparsers.add_parser(
        "lint",
        help="run the replay-safety and repo-invariant static analysis",
        description=(
            "Static analysis for durable graphs: replay-safety of task "
            "functions (RS1xx) and framework invariants (INVxxx). "
            "Exit 0 = clean modulo baseline, 1 = new findings, 2 = error."
        ),
    )
    p.add_argument(
        "paths",
        nargs="*",
        default=["src"],
        help="files or directories to lint (default: src)",
    )
    p.add_argument(
        "--select",
        action="append",
        default=None,
        metavar="PREFIX",
        help="only report codes matching these prefixes (repeatable, "
        "comma-separated; e.g. --select RS --select INV201)",
    )
    p.add_argument("--json", action="store_true", help="machine-readable output")
    p.add_argument(
        "--baseline",
        default=None,
        metavar="PATH",
        help=f"baseline file (default: <repo-root>/{BASELINE_NAME})",
    )
    p.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore the baseline: report and fail on every finding",
    )
    p.add_argument(
        "--write-baseline",
        action="store_true",
        help="write current findings to the baseline file and exit 0",
    )
    p.add_argument(
        "--explain",
        metavar="CODE",
        default=None,
        help="print the catalog entry for one code and exit",
    )
    p.set_defaults(fn=cmd_lint)


def cmd_lint(args: "argparse.Namespace") -> int:
    """Entry point for the ``lint`` subcommand; returns the exit code."""
    if args.explain:
        entry = CODES.get(args.explain)
        if entry is None:
            print(f"unknown code {args.explain!r}", file=sys.stderr)
            return 2
        print(f"{args.explain} [{entry[0]}] {entry[1]}")
        return 0

    select: Optional[List[str]] = None
    if args.select:
        select = [s for chunk in args.select for s in chunk.split(",") if s]

    repo_root = find_repo_root()
    missing = [p for p in args.paths if not os.path.exists(p)]
    if missing:
        print(f"lint: no such path(s): {', '.join(missing)}", file=sys.stderr)
        return 2
    findings = lint_paths(args.paths, repo_root=repo_root, select=select)

    baseline_path = args.baseline or os.path.join(repo_root, BASELINE_NAME)
    if args.write_baseline:
        n = write_baseline(baseline_path, findings)
        print(f"wrote {n} baseline entries to {baseline_path}")
        return 0

    baseline = None if args.no_baseline else load_baseline(baseline_path)
    new, suppressed = split_baselined(findings, baseline)

    if args.json:
        print(
            json.dumps(
                {
                    "findings": [f.to_obj() for f in new],
                    "suppressed": [f.to_obj() for f in suppressed],
                    "counts": {"new": len(new), "suppressed": len(suppressed)},
                },
                sort_keys=True,
            )
        )
    else:
        for f in new:
            print(f.render())
        tail = f"{len(new)} finding(s)"
        if suppressed:
            tail += f", {len(suppressed)} suppressed by baseline"
        print(tail)
    return 1 if new else 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Standalone entry (``python -m repro.analysis.cli``)."""
    parser = argparse.ArgumentParser(prog="repro-lint")
    sub = parser.add_subparsers(dest="cmd", required=True)
    add_lint_parser(sub)
    args = parser.parse_args(["lint", *(argv if argv is not None else sys.argv[1:])])
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
