"""Replay-safety checker: AST determinism-hazard detectors for task functions.

Durable replay (docs/durable-workflows.md §1) assumes a task function is a
*pure function of its injected inputs and context*: re-executing it with the
same ``(ctx, **inputs)`` must reproduce the journaled output digest. This
module walks a task function's AST and flags the ways user code commonly
breaks that contract:

  - ``RS101`` — clock reads (``time.time``, ``datetime.now``, monotonic /
    perf counters): any clock read is a nondeterministic *value*. Sleeping
    is fine (no value); reading the time is not.
  - ``RS102`` — unseeded randomness (``random.*`` module-level, legacy
    ``np.random.*`` global state, ``default_rng()`` / ``Random()`` called
    without a seed, ``uuid4``, ``os.urandom``). The sanctioned idiom is the
    seeded generator ``np.random.default_rng(seed)`` that
    ``data/pipeline.py`` uses.
  - ``RS103`` — ambient I/O: ``open``, env reads, network, subprocesses,
    ``input``. Ambient state is invisible to the ``(ξ, inputs)`` digests,
    so a replay can silently read different data.
  - ``RS104`` — mutation of captured closure/global state (``global`` /
    top-level ``nonlocal`` writes, ``.append``/``.update``/item assignment
    on names the function does not bind): cross-call state leaks make the
    second execution see different inputs than the digest recorded.
  - ``RS105`` — iterating an unordered ``set`` expression: iteration order
    is salted per process, so results fed from it replay differently.
  - ``RS900`` — bytecode-heuristic fallback when source is unavailable,
    the same degradation path ``fn_digest`` in ``core/graph.py`` takes.

Two resolvers feed the same detectors: a *dynamic* one for live callables
(registration-time checks resolve names through ``fn.__globals__`` and the
closure), and a *static* one for linted files (an import-alias table built
from the module AST). Both reduce a call like ``np.random.rand(3)`` to the
canonical dotted name ``numpy.random.rand`` before the hazard tables apply.
"""

from __future__ import annotations

import ast
import builtins
import inspect
import textwrap
from types import CodeType, FunctionType, ModuleType
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .findings import Finding

__all__ = [
    "check_callable",
    "check_graph",
    "check_source_tasks",
]

# -- hazard tables (canonical dotted names) ---------------------------------

_WALL_CLOCK = frozenset(
    {
        "time.time",
        "time.time_ns",
        "time.monotonic",
        "time.monotonic_ns",
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.process_time",
        "time.localtime",
        "time.gmtime",
        "time.ctime",
        "datetime.datetime.now",
        "datetime.datetime.utcnow",
        "datetime.datetime.today",
        "datetime.date.today",
    }
)

_UNSEEDED_RNG = frozenset(
    {
        "random.random",
        "random.randint",
        "random.randrange",
        "random.choice",
        "random.choices",
        "random.shuffle",
        "random.sample",
        "random.uniform",
        "random.gauss",
        "random.normalvariate",
        "random.getrandbits",
        "random.randbytes",
        "random.betavariate",
        "random.expovariate",
        "random.seed",
        "numpy.random.rand",
        "numpy.random.randn",
        "numpy.random.randint",
        "numpy.random.random",
        "numpy.random.random_sample",
        "numpy.random.ranf",
        "numpy.random.sample",
        "numpy.random.choice",
        "numpy.random.shuffle",
        "numpy.random.permutation",
        "numpy.random.normal",
        "numpy.random.uniform",
        "numpy.random.standard_normal",
        "numpy.random.seed",
        "os.urandom",
        "uuid.uuid1",
        "uuid.uuid4",
        "secrets.token_bytes",
        "secrets.token_hex",
        "secrets.token_urlsafe",
        "secrets.randbelow",
        "secrets.choice",
    }
)

#: RNG factories that are replay-safe *only when seeded*: a zero-argument
#: call falls back to OS entropy and is flagged; ``default_rng(seed)`` is
#: the sanctioned idiom.
_SEEDED_RNG_FACTORIES = frozenset({"numpy.random.default_rng", "random.Random"})

_AMBIENT_IO = frozenset(
    {
        "open",
        "io.open",
        "input",
        "os.getenv",
        "os.putenv",
        "os.listdir",
        "os.scandir",
        "os.walk",
        "os.system",
        "os.popen",
        "os.uname",
        "socket.gethostname",
        "socket.getfqdn",
        "platform.node",
        "getpass.getuser",
    }
)

#: Call prefixes that are ambient I/O wholesale (network + process spawn).
_AMBIENT_IO_PREFIXES = (
    "socket.",
    "subprocess.",
    "requests.",
    "urllib.",
    "http.client.",
)

#: Reads of the process environment (attribute/subscript access, not calls).
_AMBIENT_ATTRS = frozenset({"os.environ", "sys.stdin"})

_MUTATING_METHODS = frozenset(
    {
        "append",
        "extend",
        "insert",
        "add",
        "update",
        "pop",
        "popitem",
        "remove",
        "discard",
        "clear",
        "setdefault",
        "sort",
        "reverse",
        "appendleft",
        "extendleft",
        "popleft",
    }
)

#: Root names whose presence in a sourceless function's co_names is
#: suspicious enough to surface under the RS900 bytecode heuristic.
_BYTECODE_SUSPECTS = frozenset(
    {
        "time",
        "random",
        "secrets",
        "uuid",
        "socket",
        "subprocess",
        "requests",
        "urlopen",
        "urandom",
        "environ",
        "getenv",
        "open",
        "input",
    }
)
#: co_names entries too generic to flag on their own — ``time`` is imported
#: for the (harmless) ``time.sleep`` by many task bodies.
_BYTECODE_NEEDS_ATTR = frozenset({"time"})
_BYTECODE_TIME_ATTRS = frozenset(
    {"time", "time_ns", "monotonic", "perf_counter", "localtime", "gmtime", "ctime"}
)


# -- name resolution --------------------------------------------------------

_UNRESOLVED = object()


def _canonical_root_obj(obj: Any) -> Optional[str]:
    """Canonical dotted prefix for a resolved root object."""
    if isinstance(obj, ModuleType):
        return obj.__name__
    qualname = getattr(obj, "__qualname__", None) or getattr(obj, "__name__", None)
    if qualname is None:
        return None
    module = getattr(obj, "__module__", None)
    full = qualname if module in (None, "builtins") else f"{module}.{qualname}"
    # numpy's legacy global RNG surface lives on a hidden RandomState
    # singleton in numpy.random.mtrand — normalize to the public path
    full = full.replace("numpy.random.mtrand.RandomState.", "numpy.random.")
    return full.replace("numpy.random.mtrand.", "numpy.random.")


class DynamicResolver:
    """Resolve root names of a *live* function through globals + closure."""

    def __init__(self, fn: Callable[..., Any]):
        self._names: Dict[str, Any] = dict(vars(builtins))
        self._names.update(getattr(fn, "__globals__", None) or {})
        code = getattr(fn, "__code__", None)
        closure = getattr(fn, "__closure__", None) or ()
        if code is not None and closure:
            for var, cell in zip(code.co_freevars, closure, strict=True):
                try:
                    self._names[var] = cell.cell_contents
                except ValueError:
                    pass  # empty cell: still being defined
    def canonical_root(self, name: str) -> Optional[str]:
        """Canonical dotted prefix for ``name``, or None if unresolvable."""
        if name not in self._names:
            return None
        return _canonical_root_obj(self._names[name])

    def is_module(self, name: str) -> bool:
        """True when ``name`` resolves to a module object."""
        return isinstance(self._names.get(name), ModuleType)

    def treats_as_captured(self, name: str) -> bool:
        """True when ``name`` is a captured *data value* (mutation hazard).

        Modules, classes, and callables are excluded: calling ``.append``
        on ``numpy`` is a function call, not captured-state mutation.
        """
        obj = self._names.get(name, _UNRESOLVED)
        if obj is _UNRESOLVED or isinstance(obj, ModuleType):
            return False
        return not (callable(obj) and hasattr(obj, "__name__"))


class StaticResolver:
    """Resolve root names through a module AST's import-alias table."""

    def __init__(self, tree: ast.Module, package: Sequence[str] = ()):
        self._table: Dict[str, str] = {}
        self._package = tuple(package)
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    local = alias.asname or alias.name.split(".", 1)[0]
                    target = alias.name if alias.asname else alias.name.split(".", 1)[0]
                    self._table[local] = target
            elif isinstance(node, ast.ImportFrom):
                base = self._from_base(node)
                if base is None:
                    continue
                for alias in node.names:
                    if alias.name == "*":
                        continue
                    self._table[alias.asname or alias.name] = f"{base}.{alias.name}"

    def _from_base(self, node: ast.ImportFrom) -> Optional[str]:
        if not node.level:
            return node.module
        if not self._package or node.level > len(self._package):
            return None  # relative import with unknown package context
        parts = list(self._package[: len(self._package) - (node.level - 1)])
        if node.module:
            parts.append(node.module)
        return ".".join(parts)

    def canonical_root(self, name: str) -> Optional[str]:
        """Canonical dotted prefix for ``name`` (imports, then builtins)."""
        if name in self._table:
            return self._table[name]
        if hasattr(builtins, name):
            return name
        return None

    def is_module(self, name: str) -> bool:
        """True when ``name`` plausibly resolves to a module (any import)."""
        return name in self._table

    def treats_as_captured(self, name: str) -> bool:
        """True when mutating ``name`` is a captured-state hazard.

        Statically, an unbound name that is neither an import nor a builtin
        must come from the module (or an enclosing) scope — exactly the
        ambient state the replay contract forbids mutating.
        """
        return name not in self._table and not hasattr(builtins, name)


def _dotted(node: ast.AST) -> Optional[Tuple[str, List[str]]]:
    """Decompose ``a.b.c`` into ``("a", ["b", "c"])``; None if not a chain."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    parts.reverse()
    return cur.id, parts


def _canonical(resolver: Any, node: ast.AST, local_names: Set[str]) -> Optional[str]:
    """Canonical dotted name for an expression, or None."""
    decomposed = _dotted(node)
    if decomposed is None:
        return None
    root, rest = decomposed
    if root in local_names:
        return None  # rebound locally: not the imported thing anymore
    prefix = resolver.canonical_root(root)
    if prefix is None:
        return None
    # a from-import of datetime's class: "datetime.datetime" + ["now"]
    return ".".join([prefix, *rest]) if rest else prefix


# -- the detector engine ----------------------------------------------------


class _FunctionChecker:
    """Run every RS detector over one function's AST."""

    def __init__(
        self,
        resolver: Any,
        qualname: str,
        path: str = "",
        src_lines: Optional[Sequence[str]] = None,
        line_offset: int = 0,
    ):
        self._resolver = resolver
        self._qualname = qualname
        self._path = path
        self._src_lines = src_lines or []
        self._line_offset = line_offset
        self.findings: List[Finding] = []
        self._flagged: Set[Tuple[str, int, str]] = set()  # (code, line, msg) dedupe
        self._local_imports: Dict[str, str] = {}  # in-function import aliases

    # -- helpers ------------------------------------------------------------
    def _snippet(self, node: ast.AST) -> str:
        lineno = getattr(node, "lineno", 0)
        if 1 <= lineno <= len(self._src_lines):
            return self._src_lines[lineno - 1].strip()
        return ""

    def _emit(self, code: str, message: str, node: ast.AST) -> None:
        line = getattr(node, "lineno", 0) + self._line_offset
        if (code, line, message) in self._flagged:
            return
        self._flagged.add((code, line, message))
        self.findings.append(
            Finding(
                code=code,
                message=message,
                path=self._path,
                line=line,
                symbol=self._qualname,
                snippet=self._snippet(node),
            )
        )

    def _canon(self, node: ast.AST, visible: Set[str]) -> Optional[str]:
        """Canonical dotted name, consulting in-function imports first."""
        decomposed = _dotted(node)
        if decomposed is None:
            return None
        root, rest = decomposed
        prefix = self._local_imports.get(root)
        if prefix is not None:
            return ".".join([prefix, *rest]) if rest else prefix
        return _canonical(self._resolver, node, visible)

    @staticmethod
    def _scope_bindings(fn_node: ast.AST) -> Set[str]:
        """Names bound inside ``fn_node``'s own scope (nested defs excluded)."""
        bound: Set[str] = set()
        args = getattr(fn_node, "args", None)
        if args is not None:
            for a in [
                *args.posonlyargs,
                *args.args,
                *args.kwonlyargs,
                *([args.vararg] if args.vararg else []),
                *([args.kwarg] if args.kwarg else []),
            ]:
                bound.add(a.arg)

        def visit(node: ast.AST) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                    bound.add(child.name)
                    continue  # nested scope: its bindings are not ours
                if isinstance(child, ast.Lambda):
                    continue
                if isinstance(child, ast.Name) and isinstance(child.ctx, ast.Store):
                    bound.add(child.id)
                elif isinstance(child, ast.ExceptHandler) and child.name:
                    bound.add(child.name)
                elif isinstance(child, ast.alias):
                    bound.add((child.asname or child.name).split(".", 1)[0])
                elif isinstance(child, ast.comprehension):
                    # comprehension targets live in their own scope, but
                    # treating them as local only ever *suppresses* RS104
                    for n in ast.walk(child.target):
                        if isinstance(n, ast.Name):
                            bound.add(n.id)
                visit(child)

        body = getattr(fn_node, "body", None)
        if isinstance(body, list):
            for stmt in body:
                visit(stmt)
                if isinstance(stmt, ast.Name) and isinstance(stmt.ctx, ast.Store):
                    bound.add(stmt.id)
        elif body is not None:  # Lambda
            visit(body)
        return bound

    # -- entry --------------------------------------------------------------
    def check(self, fn_node: ast.AST) -> List[Finding]:
        """Check one function/lambda node; returns the findings."""
        self._walk_scope(fn_node, scope_stack=[], top=True)
        return self.findings

    def _walk_scope(
        self, fn_node: ast.AST, scope_stack: List[Set[str]], top: bool
    ) -> None:
        bound = self._scope_bindings(fn_node)
        stack = scope_stack + [bound]
        visible: Set[str] = set().union(*stack)
        globals_declared: Set[str] = set()
        escaping_nonlocals: Set[str] = set()

        body = getattr(fn_node, "body", None)
        stmts = body if isinstance(body, list) else [body]
        for stmt in stmts:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Global):
                    globals_declared.update(node.names)
                elif isinstance(node, ast.Nonlocal) and top:
                    # a top-level nonlocal reaches OUTSIDE the task function
                    escaping_nonlocals.update(node.names)

        escaping = globals_declared | escaping_nonlocals

        # function-local imports rebind a name *to a known module/symbol* —
        # resolvable for hazard tables even though the name is scope-bound
        imports = dict(self._local_imports)
        for stmt in stmts:
            for node in ast.walk(stmt):
                if isinstance(node, ast.Import):
                    for alias in node.names:
                        if alias.asname:
                            imports[alias.asname] = alias.name
                        else:
                            imports[alias.name.split(".", 1)[0]] = alias.name.split(".", 1)[0]
                elif isinstance(node, ast.ImportFrom) and not node.level and node.module:
                    for alias in node.names:
                        if alias.name != "*":
                            imports[alias.asname or alias.name] = f"{node.module}.{alias.name}"

        def handle(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                self._walk_scope(node, stack, top=False)
                return
            self._check_node(node, visible, escaping)
            for child in ast.iter_child_nodes(node):
                handle(child)

        prev_imports = self._local_imports
        self._local_imports = imports
        try:
            for stmt in stmts:
                handle(stmt)
        finally:
            self._local_imports = prev_imports

    # -- per-node detectors --------------------------------------------------
    def _check_node(self, node: ast.AST, visible: Set[str], escaping: Set[str]) -> None:
        if isinstance(node, ast.Call):
            self._check_call(node, visible)
        if isinstance(node, (ast.Attribute, ast.Subscript)):
            target = node.value if isinstance(node, ast.Subscript) else node
            canon = self._canon(target, visible)
            if canon in _AMBIENT_ATTRS:
                self._emit("RS103", f"ambient read of {canon}", node)
        if isinstance(node, (ast.For, ast.AsyncFor)):
            self._check_set_iter(node.iter, visible)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)):
            for gen in node.generators:
                self._check_set_iter(gen.iter, visible)
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            self._check_assign(node, visible, escaping)
        if isinstance(node, ast.Delete):
            for tgt in node.targets:
                self._check_mutation_target(tgt, visible, "del on")

    def _check_call(self, node: ast.Call, visible: Set[str]) -> None:
        canon = self._canon(node.func, visible)
        if canon is None:
            self._check_method_mutation(node, visible)
            return
        if canon in _WALL_CLOCK:
            self._emit("RS101", f"clock read via {canon}()", node)
        elif canon in _UNSEEDED_RNG:
            self._emit("RS102", f"unseeded RNG call {canon}()", node)
        elif canon in _SEEDED_RNG_FACTORIES and not node.args and not node.keywords:
            self._emit(
                "RS102",
                f"{canon}() without a seed falls back to OS entropy — pass "
                "an explicit seed derived from the context",
                node,
            )
        elif canon in _AMBIENT_IO or canon.startswith(_AMBIENT_IO_PREFIXES):
            self._emit("RS103", f"ambient I/O call {canon}()", node)

    def _check_method_mutation(self, node: ast.Call, visible: Set[str]) -> None:
        func = node.func
        if not isinstance(func, ast.Attribute) or func.attr not in _MUTATING_METHODS:
            return
        receiver = func.value
        if (
            isinstance(receiver, ast.Name)
            and receiver.id not in visible
            and self._resolver.treats_as_captured(receiver.id)
        ):
            self._emit(
                "RS104",
                f"mutates captured state: {receiver.id}.{func.attr}(...) on a "
                "name the task does not bind",
                node,
            )

    def _check_set_iter(self, iter_node: ast.AST, visible: Set[str]) -> None:
        is_set = isinstance(iter_node, (ast.Set, ast.SetComp))
        if isinstance(iter_node, ast.Call):
            canon = self._canon(iter_node.func, visible)
            is_set = canon in ("set", "frozenset")
        if is_set:
            self._emit(
                "RS105",
                "iterates an unordered set — per-process hash salting makes "
                "the order (and anything built from it) replay-unstable; "
                "sort it first",
                iter_node,
            )

    def _check_assign(self, node: ast.AST, visible: Set[str], escaping: Set[str]) -> None:
        targets = (
            node.targets
            if isinstance(node, ast.Assign)
            else [node.target]  # AugAssign | AnnAssign
        )
        for tgt in targets:
            if isinstance(tgt, ast.Name) and tgt.id in escaping:
                self._emit(
                    "RS104",
                    f"writes escaping state: {tgt.id} is declared "
                    "global/nonlocal — cross-call state breaks replay",
                    node,
                )
            else:
                self._check_mutation_target(tgt, visible, "assignment through")

    def _check_mutation_target(self, tgt: ast.AST, visible: Set[str], verb: str) -> None:
        base: Optional[ast.AST] = None
        if isinstance(tgt, (ast.Subscript, ast.Attribute)):
            base = tgt.value
        if (
            isinstance(base, ast.Name)
            and base.id not in visible
            and (
                self._resolver.treats_as_captured(base.id)
                # setting an attribute ON a module is global-state mutation
                or (isinstance(tgt, ast.Attribute) and self._resolver.is_module(base.id))
            )
        ):
            self._emit(
                "RS104",
                f"mutates captured state: {verb} {base.id} — a name the "
                "task does not bind",
                tgt,
            )


# -- bytecode fallback ------------------------------------------------------


def _code_names(code: CodeType, seen: Set[int]) -> Set[str]:
    if id(code) in seen:
        return set()
    seen.add(id(code))
    names = set(code.co_names)
    for const in code.co_consts:
        if isinstance(const, CodeType):
            names |= _code_names(const, seen)
    return names


def _bytecode_findings(fn: Callable[..., Any], qualname: str) -> List[Finding]:
    """Heuristic scan of ``co_names`` when source is unavailable.

    The same degradation path :func:`repro.core.graph.fn_digest` takes:
    structural code-object inspection instead of source. Matches are
    *possible* hazards only — the names prove the function touches a
    suspicious module, not which attribute it reads.
    """
    code = getattr(fn, "__code__", None)
    if code is None:
        return []
    names = _code_names(code, set())
    hits = sorted(
        n
        for n in names & _BYTECODE_SUSPECTS
        if n not in _BYTECODE_NEEDS_ATTR or names & _BYTECODE_TIME_ATTRS
    )
    if not hits:
        return []
    return [
        Finding(
            code="RS900",
            message=(
                "possible determinism hazard (source unavailable; bytecode "
                f"references: {', '.join(hits)})"
            ),
            line=code.co_firstlineno,
            symbol=qualname,
        )
    ]


# -- public entry points ----------------------------------------------------


def _find_target_node(tree: ast.Module, fn: Callable[..., Any]) -> Optional[ast.AST]:
    """The def/lambda node in ``tree`` matching the live callable ``fn``."""
    name = getattr(fn, "__name__", "")
    if name == "<lambda>":
        lambdas = [n for n in ast.walk(tree) if isinstance(n, ast.Lambda)]
        return lambdas[0] if lambdas else None
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and node.name == name:
            return node
    return None


def check_callable(fn: Callable[..., Any], name: str = "") -> List[Finding]:
    """Replay-safety findings for one live callable (RS1xx, RS900).

    Resolves names through the function's real globals and closure, so
    aliased imports (``import numpy as anything``) and from-imports are
    seen through. Falls back to the RS900 bytecode heuristic when source
    is unavailable (builtins, REPL definitions, ``exec`` products).
    """
    target = fn
    while hasattr(target, "__wrapped__"):
        target = target.__wrapped__
    if not isinstance(target, FunctionType):
        return []  # builtins / callable instances: nothing to parse
    qualname = name or getattr(target, "__qualname__", "") or "<task>"
    try:
        src_lines, start_line = inspect.getsourcelines(target)
        src = textwrap.dedent("".join(src_lines))
        tree = ast.parse(src)
    except (OSError, TypeError, IndentationError, SyntaxError, ValueError):
        return _bytecode_findings(target, qualname)
    fn_node = _find_target_node(tree, target)
    if fn_node is None:
        return _bytecode_findings(target, qualname)
    path = ""
    try:
        path = inspect.getsourcefile(target) or ""
    except TypeError:
        pass
    checker = _FunctionChecker(
        DynamicResolver(target),
        qualname,
        path=path,
        src_lines=src.splitlines(),
        line_offset=start_line - 1,
    )
    return checker.check(fn_node)


def check_graph(graph: Any) -> List[Finding]:
    """Replay-safety findings for every callable task in a ``ContextGraph``.

    Registry-named tasks (string ``fn``) are skipped — their implementations
    live worker-side and are checked where they are defined.
    """
    findings: List[Finding] = []
    for node in getattr(graph, "nodes", {}).values():
        fn = getattr(node, "fn", None)
        if fn is None or isinstance(fn, str):
            continue
        findings.extend(check_callable(fn, name=f"{node.id}:{getattr(fn, '__name__', 'fn')}"))
    return findings


# -- static (file) mode -----------------------------------------------------


def _is_task_decorator(dec: ast.AST) -> bool:
    """True for ``@atomic_task`` / ``@something.task("id", ...)`` decorators."""
    if isinstance(dec, ast.Name) and dec.id == "atomic_task":
        return True
    if isinstance(dec, ast.Attribute) and dec.attr == "atomic_task":
        return True
    if isinstance(dec, ast.Call):
        func = dec.func
        if isinstance(func, ast.Attribute) and func.attr == "task":
            return True
        if isinstance(func, ast.Name) and func.id == "atomic_task":
            return True
    return False


def _task_nodes(tree: ast.Module) -> List[Tuple[str, ast.AST]]:
    """(qualname, node) for every statically identifiable task function.

    A function is a task if it is decorated ``@atomic_task`` or
    ``@graph.task(...)``, or passed (by name, lambda, or def) as the ``fn``
    argument of an ``.add(...)`` / ``.add_stream(...)`` call.
    """
    defs: Dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, node)
    tasks: List[Tuple[str, ast.AST]] = []
    seen: Set[int] = set()

    def take(name: str, node: ast.AST) -> None:
        if id(node) not in seen:
            seen.add(id(node))
            tasks.append((name, node))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_task_decorator(d) for d in node.decorator_list):
                take(node.name, node)
        elif isinstance(node, ast.Call):
            func = node.func
            if not isinstance(func, ast.Attribute) or func.attr not in ("add", "add_stream"):
                continue
            candidates: List[ast.AST] = list(node.args[1:2])
            candidates += [kw.value for kw in node.keywords if kw.arg == "fn"]
            for cand in candidates:
                if isinstance(cand, ast.Lambda):
                    take("<lambda>", cand)
                elif isinstance(cand, ast.Name) and cand.id in defs:
                    take(cand.id, defs[cand.id])
    return tasks


def check_source_tasks(
    text: str, path: str = "", package: Sequence[str] = ()
) -> List[Finding]:
    """Replay-safety findings for the task functions of one source file.

    Only statically identifiable task functions are checked (see
    :func:`_task_nodes`) — framework/helper code in the same file is the
    INV detectors' jurisdiction, not RS's.
    """
    try:
        tree = ast.parse(text)
    except SyntaxError:
        return []  # the CLI reports parse failures separately (E999)
    resolver = StaticResolver(tree, package=package)
    src_lines = text.splitlines()
    findings: List[Finding] = []
    for qualname, node in _task_nodes(tree):
        checker = _FunctionChecker(resolver, qualname, path=path, src_lines=src_lines)
        findings.extend(checker.check(node))
    return findings
