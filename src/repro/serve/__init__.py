"""Serving engine: continuous batching over model replicas."""

from .batcher import ContinuousBatcher, Generation, Request

__all__ = ["ContinuousBatcher", "Generation", "Request"]
