"""Continuous-batching serving engine (vLLM-style, TPU-shaped).

The unit of compute is a fixed-shape decode step over a slot matrix:
``B_slots`` sequences decode one token per step; finished slots are refilled
from the admission queue by PREFILLING into the slot's cache region. Fixed
shapes mean the jitted decode step never recompiles — the TPU requirement —
and slot refill is where the Gateway/context-affinity semantics plug in.

Components:
  - ``Request``: prompt + max_new_tokens (+ deterministic request digest —
    the durable-execution identity used for replay-safe resubmission);
  - ``SlotState``: per-slot request bookkeeping;
  - ``ContinuousBatcher``: admission queue → slot assignment → step loop.

The batcher is model-agnostic: it takes (prefill_fn, decode_fn, init_cache)
from models.build(), so every assigned decoder arch can serve through it.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.stream import Channel
from repro.wire import payload_digest

__all__ = ["Request", "Generation", "ContinuousBatcher"]


@dataclass
class Request:
    rid: str
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    submitted_at: float = field(default_factory=time.monotonic)

    def digest(self) -> str:
        return payload_digest({"p": self.prompt, "n": self.max_new_tokens})


@dataclass
class Generation:
    rid: str
    tokens: List[int]
    prompt_len: int
    queued_s: float
    prefill_s: float
    decode_s: float

    @property
    def total_s(self) -> float:
        return self.queued_s + self.prefill_s + self.decode_s


@dataclass
class _Slot:
    active: bool = False
    rid: str = ""
    produced: int = 0
    budget: int = 0
    tokens: List[int] = field(default_factory=list)
    prompt_len: int = 0
    t_admit: float = 0.0
    t_prefill_done: float = 0.0
    queued_s: float = 0.0


class ContinuousBatcher:
    """Slot-matrix continuous batching over a single model replica.

    ``max_len`` bounds prompt+generation; each slot owns a cache of
    ``max_len``. Prefill writes a fresh per-request cache and SPLICES it
    into the slot's region of the batched cache (dynamic_update along the
    batch axis) — decode then advances all active slots in lockstep with
    one fixed-shape jitted step.
    """

    def __init__(
        self, model, params, *, slots: int = 4, max_len: int = 128, eos_id: Optional[int] = None
    ):
        self.model = model
        self.params = params
        self.n_slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = model.init_cache(slots, max_len)
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._slots = [_Slot() for _ in range(slots)]
        self._next_token = np.zeros((slots,), np.int32)
        self._done: Dict[str, Generation] = {}
        self._streams: Dict[str, Channel] = {}
        self._lock = threading.Lock()
        self.steps = 0
        self.slot_steps_busy = 0

    # -- public API ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.put(req)

    def submit_stream(self, req: Request, capacity: int = 64) -> Channel:
        """Submit a request whose tokens stream out as they decode.

        Returns a bounded :class:`repro.stream.Channel` of ``(seq, token)``
        pairs: the first token lands at prefill time, one more per decode
        step, and the channel closes when the request finishes — consumers
        iterate instead of waiting for the whole generation. Backpressure
        is real: a consumer more than ``capacity`` tokens behind blocks the
        engine step loop, so size ``capacity`` to cover the consumer's
        worst stall (or ``max_new_tokens`` to decouple entirely).
        """
        ch = Channel(capacity, name=f"tokens:{req.rid}")
        with self._lock:
            self._streams[req.rid] = ch
        self._queue.put(req)
        return ch

    def run_until_drained(self, max_steps: int = 100_000) -> Dict[str, Generation]:
        """Drive the loop until queue + slots are empty (batch-mode serving)."""
        while (not self._queue.empty() or self._any_active()) and self.steps < max_steps:
            self.step()
        return dict(self._done)

    def results(self) -> Dict[str, Generation]:
        return dict(self._done)

    # -- internals ------------------------------------------------------------
    def _any_active(self) -> bool:
        return any(s.active for s in self._slots)

    def _admit(self) -> None:
        """Fill free slots: prefill the request and splice its cache in."""
        for i, slot in enumerate(self._slots):
            if slot.active:
                continue
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                return
            t0 = time.monotonic()
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, fresh = self.model.prefill(self.params, {"tokens": toks}, pad_to=self.max_len)
            self.cache = _splice_cache(self.cache, fresh, i)
            first = int(jnp.argmax(logits, axis=-1)[0])
            self._next_token[i] = first
            slot.active = True
            slot.rid = req.rid
            slot.produced = 1
            slot.budget = req.max_new_tokens
            slot.tokens = [first]
            slot.prompt_len = len(req.prompt)
            slot.queued_s = t0 - req.submitted_at
            slot.t_admit = t0
            slot.t_prefill_done = time.monotonic()
            ch = self._streams.get(req.rid)
            if ch is not None:
                ch.put(0, first)  # first token streams out at prefill time

    def step(self) -> None:
        """One engine iteration: admit, decode one token for active slots."""
        self._admit()
        if not self._any_active():
            return
        tok = jnp.asarray(self._next_token)
        logits, self.cache = self._decode(self.params, self.cache, {"token": tok})
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.steps += 1
        for i, slot in enumerate(self._slots):
            if not slot.active:
                continue
            self.slot_steps_busy += 1
            t = int(nxt[i])
            done = (
                slot.produced >= slot.budget
                or (self.eos_id is not None and t == self.eos_id)
                or slot.prompt_len + slot.produced + 1 >= self.max_len
            )
            if done:
                now = time.monotonic()
                self._done[slot.rid] = Generation(
                    rid=slot.rid,
                    tokens=list(slot.tokens),
                    prompt_len=slot.prompt_len,
                    queued_s=slot.queued_s,
                    prefill_s=slot.t_prefill_done - slot.t_admit,
                    decode_s=now - slot.t_prefill_done,
                )
                ch = self._streams.pop(slot.rid, None)
                if ch is not None:
                    ch.close()  # EOS: the consumer's iteration ends
                self._slots[i] = _Slot()
                self._next_token[i] = 0
            else:
                slot.tokens.append(t)
                slot.produced += 1
                self._next_token[i] = t
                ch = self._streams.get(slot.rid)
                if ch is not None:
                    ch.put(len(slot.tokens) - 1, t)

    def utilization(self) -> float:
        """Mean fraction of slots busy per decode step."""
        if self.steps == 0:
            return 0.0
        return self.slot_steps_busy / (self.steps * self.n_slots)


def _splice_cache(batched, fresh, slot: int):
    """Write the (batch=1) fresh cache into row ``slot`` of the batched one.

    'pos' scalars are shared across slots: decode masks per-slot validity by
    position, and all slots share the engine step clock; we keep the max.
    """

    def walk(b, f):
        if isinstance(b, dict):
            return {k: walk(b[k], f[k]) for k in b}
        if b.ndim == 0 or b.shape == f.shape:  # pos scalars & stacked pos
            return jnp.maximum(b, f)
        # leaves: (..., B_slots, ...) vs (..., 1, ...): find the batch axis
        ax = _batch_axis(b.shape, f.shape)
        idx = [0] * b.ndim
        idx[ax] = slot
        return jax.lax.dynamic_update_slice(b, f.astype(b.dtype), tuple(idx))

    return walk(batched, fresh)


def _batch_axis(bs: Tuple[int, ...], fs: Tuple[int, ...]) -> int:
    for i, (a, b) in enumerate(zip(bs, fs, strict=False)):
        if a != b and b == 1:
            return i
    raise ValueError(f"no batch axis between {bs} and {fs}")
