"""Pallas TPU kernel for the RWKV6 WKV recurrence (chunked, data-dep decay).

TPU adaptation of the (inherently sequential) WKV scan:
  - grid (B, H, T/chunk); the chunk axis is LAST = sequential ("arbitrary"),
    so the per-(batch, head) state S ∈ R^{K×V} f32 lives in VMEM scratch and
    flows across chunk steps without HBM round trips.
  - inside a chunk the recurrence is re-associated into MXU matmuls
    (the rank-1-factorized chunked form of kernels/ref.wkv6_chunked_ref,
    same f32 range contract: |Σ_chunk log w| ≤ 80 ⇒ chunk=16 with the
    model-side clamp log w ≥ −4).
  - K, V = head_size (64): blocks are (chunk, 64) — the matmuls are small
    but batched across the (B, H) parallel grid dims, which is where v5e's
    8 parallel sublanes earn their keep; the win over a per-step scan is
    ~chunk× fewer sequential dependencies.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names it TPUCompilerParams; the kwargs are the same either way
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["wkv6_pallas"]


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 o_ref, sT_ref, S_scr, *, chunk: int, nt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        S_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # (c, K)
    k = k_ref[0, 0].astype(jnp.float32)          # (c, K)
    v = v_ref[0, 0].astype(jnp.float32)          # (c, V)
    w = w_ref[0, 0].astype(jnp.float32)          # (c, K)
    u = u_ref[0].astype(jnp.float32)             # (K,)
    S = S_scr[...]                                # (K, V)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(logw, axis=0)               # (c, K)
    Dt = jnp.exp(cum)
    Dt_prev = jnp.exp(cum - logw)
    r_hat = r * Dt_prev
    k_hat = k / jnp.maximum(Dt, 1e-30)

    cross = jax.lax.dot_general(r_hat, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (c, V)
    att = jax.lax.dot_general(r_hat, k_hat, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)     # (c, c)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    intra = jax.lax.dot_general(att * tri, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    diag = ((r * u[None, :]) * k).sum(axis=1, keepdims=True) * v
    o_ref[0, 0] = (cross + intra + diag).astype(o_ref.dtype)

    D_last = Dt[-1, :]                            # (K,)
    k_scaled = k * jnp.exp(cum[-1:, :] - cum)     # (c, K)
    S_new = D_last[:, None] * S + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (K, V)
    S_scr[...] = S_new

    @pl.when(it == nt - 1)
    def _write_state():
        sT_ref[0, 0] = S_new


def wkv6_pallas(r, k, v, w, u, *, initial_state=None, chunk: int = 16,
                interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,w: (B,H,T,K); v: (B,H,T,V); u: (H,K). T % chunk == 0 (ops pads)."""
    B, H, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, "ops.wkv6 pads T to the chunk size"
    nt = T // chunk
    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, nt=nt)
    out, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, K), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, V), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, sT
