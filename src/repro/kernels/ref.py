"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the kernels are validated against AND the CPU
execution path of the model (ops.py dispatches here off-TPU). They are
written in the same *blocked/online* form as the kernels so that memory
behaviour under compilation (dry-run) is sane at 32k+ sequence lengths:
full S×S score materialization never happens.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "flash_attention_dense_ref", "wkv6_ref",
           "wkv6_chunked_ref", "rglru_ref", "rglru_scan_ref"]

_NEG_INF = -1e30


# ===========================================================================
# flash attention (causal / local-window, GQA)
# ===========================================================================

def flash_attention_dense_ref(q, k, v, *, causal: bool = True,
                              window: Optional[int] = None,
                              scale: Optional[float] = None) -> jnp.ndarray:
    """O(S²)-memory oracle — ONLY for small test shapes.

    q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D). GQA: Hq % Hkv == 0.
    ``window``: each query attends to keys in (pos-window, pos] (local attn).
    """
    B, Hq, Sq, D = q.shape       # note: v may have a different head dim (MLA)
    Hkv = k.shape[1]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kx = jnp.repeat(k, g, axis=1)
    vx = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    Sk = k.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned (decode-friendly)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None,
                        block_k: int = 512) -> jnp.ndarray:
    """Blocked online-softmax flash attention, pure jnp (the kernel oracle).

    Memory is O(Sq·D + block_k·D) per head — safe to *compile* at 32k/500k.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]             # may differ from D (MLA)
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    nblk = (Sk + block_k - 1) // block_k
    pad = nblk * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(B, Hkv, nblk, block_k, D)
    vb = v.reshape(B, Hkv, nblk, block_k, Dv)

    qf = q.astype(jnp.float32)
    qpos = jnp.arange(Sq) + (Sk - Sq)  # right-aligned positions

    def body(carry, blk):
        m, l, acc = carry
        kblk, vblk, start = blk  # (B,Hkv,bk,D), (B,Hkv,bk,D), scalar
        kpos = start + jnp.arange(block_k)
        kq = jnp.repeat(kblk, g, axis=1).astype(jnp.float32)
        vq = jnp.repeat(vblk, g, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kq) * scale
        valid = kpos[None, :] < Sk
        msk = jnp.broadcast_to(valid, (Sq, block_k))
        if causal:
            msk &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            msk &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(msk[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vq)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hq, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hq, Sq, Dv), jnp.float32)
    starts = jnp.arange(nblk) * block_k
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), starts))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.astype(q.dtype)


# ===========================================================================
# RWKV6 WKV: data-dependent-decay linear attention (Finch)
# ===========================================================================

def wkv6_ref(r, k, v, w, u, *, initial_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle. Shapes:
      r,k,w: (B, H, T, K);  v: (B, H, T, V);  u: (H, K)
    Recurrence per head (S ∈ R^{K×V}):
      o_t = (r_t ⊙ u)ᵀ (k_t v_tᵀ)  +  r_tᵀ S_{t-1}
      S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    w is the *decay multiplier* in (0,1]: w_t = exp(-exp(log_w_t)).
    Returns (out (B,H,T,V), final_state (B,H,K,V)).
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    S0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt * uf[None], kv) \
            + jnp.einsum("bhk,bhkv->bhv", rt, S)
        S_new = wt[..., :, None] * S + kv
        return S_new, out

    xs = (jnp.moveaxis(rf, 2, 0), jnp.moveaxis(kf, 2, 0),
          jnp.moveaxis(vf, 2, 0), jnp.moveaxis(wf, 2, 0))
    S, outs = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(outs, 0, 2).astype(r.dtype), S


def wkv6_chunked_ref(r, k, v, w, u, *, chunk: int = 16,
                     initial_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked-parallel form (what the TPU kernel computes): intra-chunk via
    masked matmuls (MXU-friendly), inter-chunk via carried state. Exactly
    equal to wkv6_ref in f32 (same order of ops per chunk).

    RANGE CONTRACT: the rank-1 factorization exp(cum_prev[c])·exp(-cum_s)
    is exact in f32 only while |Σ_chunk log w| ≲ 80. With the model-side
    clamp log w ≥ -4 (see models/rwkv.py) and chunk=16 the worst factored
    exponent is 4·15 = 60 — inside f32 range. Do not raise ``chunk`` without
    tightening the clamp.
    """
    B, H, T, K = r.shape
    V = v.shape[-1]
    assert T % chunk == 0, "pad T to a multiple of chunk"
    n = T // chunk
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    # per-chunk views: (n, B, H, c, ·)
    rc = jnp.moveaxis(rf.reshape(B, H, n, chunk, K), 2, 0)
    kc = jnp.moveaxis(kf.reshape(B, H, n, chunk, K), 2, 0)
    vc = jnp.moveaxis(vf.reshape(B, H, n, chunk, V), 2, 0)
    wc = jnp.moveaxis(wf.reshape(B, H, n, chunk, K), 2, 0)
    S0 = (jnp.zeros((B, H, K, V), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    tri_strict = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)

    def chunk_step(S, inp):
        rt, kt, vt, wt = inp                       # (B,H,c,·)
        logw = jnp.log(jnp.maximum(wt, 1e-38))
        cum = jnp.cumsum(logw, axis=2)             # D_t = Π_{τ≤t} w  (log)
        Dt = jnp.exp(cum)                          # (B,H,c,K)
        Dt_prev = jnp.exp(cum - logw)              # D_{t-1} = D_t / w_t
        r_hat = rt * Dt_prev                       # r_t ⊙ D_{t-1}
        k_hat = kt / jnp.maximum(Dt, 1e-30)        # k_s / D_s
        # cross-chunk: r̂ᵀ S0
        cross = jnp.einsum("bhck,bhkv->bhcv", r_hat, S)
        # intra-chunk strict-lower attention: (r̂ b̂ᵀ) masked
        att = jnp.einsum("bhck,bhsk->bhcs", r_hat, k_hat) * tri_strict[None, None]
        intra = jnp.einsum("bhcs,bhsv->bhcv", att, vt)
        # diagonal (bonus u) term
        diag = jnp.einsum("bhck,bhck,bhcv->bhcv", rt * uf[None, :, None, :], kt, vt) \
            if False else (rt * uf[None, :, None, :] * kt).sum(-1, keepdims=True) * vt
        out = cross + intra + diag
        # state update: S' = diag(D_c) S + Σ_s (D_c / D_s) k_s v_sᵀ
        D_last = Dt[:, :, -1, :]                   # (B,H,K)
        k_scaled = kt * jnp.exp(cum[:, :, -1:, :] - cum)  # (D_c / D_s) k_s
        S_new = D_last[..., :, None] * S + jnp.einsum("bhsk,bhsv->bhkv", k_scaled, vt)
        return S_new, out

    S, outs = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    out = jnp.moveaxis(outs, 0, 2).reshape(B, H, T, V)
    return out.astype(r.dtype), S


# ===========================================================================
# RG-LRU (RecurrentGemma / Griffin)
# ===========================================================================

def rglru_ref(x, a, *, initial_state=None, reset_first: bool = False
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle for the RG-LRU diagonal recurrence.

    x: (B, T, D) gated input  (already i_t ⊙ x_t);
    a: (B, T, D) per-step decay in (0,1)  (already a^{c·r_t});
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ x_t
    Returns (h (B,T,D), final_state (B,D)).
    """
    xf, af = x.astype(jnp.float32), a.astype(jnp.float32)
    h0 = (jnp.zeros(x.shape[::2], jnp.float32).reshape(x.shape[0], x.shape[2])
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(h, inp):
        xt, at = inp
        h_new = at * h + jnp.sqrt(jnp.maximum(1.0 - at * at, 0.0)) * xt
        return h_new, h_new

    S, hs = jax.lax.scan(step, h0, (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), S


def rglru_scan_ref(x, a, *, initial_state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Parallel associative-scan form (the kernel's math): identical result."""
    xf, af = x.astype(jnp.float32), a.astype(jnp.float32)
    gated = jnp.sqrt(jnp.maximum(1.0 - af * af, 0.0)) * xf
    if initial_state is not None:
        # fold h0 in as a virtual step 0: h_t = (Π a) h0 + scan(gated)
        pass

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    A, Bc = jax.lax.associative_scan(combine, (af, gated), axis=1)
    h = Bc
    if initial_state is not None:
        h = h + A * initial_state.astype(jnp.float32)[:, None, :]
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)
