"""Pallas TPU kernel for the RG-LRU diagonal gated recurrence.

h_t = a_t ⊙ h_{t-1} + sqrt(1−a_t²) ⊙ x_t        (x already input-gated)

TPU adaptation: the recurrence is diagonal (pure VPU, no MXU), so the kernel
is bandwidth-bound by design. Layout:
  - grid (B, W/bw, T/chunk); T sequential (last, "arbitrary"), carrying the
    h state (1, bw) in VMEM f32 scratch — one HBM read of x/a and one write
    of h per element, the bandwidth floor.
  - channel blocks bw = 512 lanes keep the VPU vectorized; within a chunk a
    fori_loop steps the recurrence (chunk × elementwise ops, no HBM traffic).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names it TPUCompilerParams; the kwargs are the same either way
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["rglru_pallas"]


def _rglru_kernel(x_ref, a_ref, h0_ref, h_ref, hT_ref, h_scr, *,
                  chunk: int, nt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)[None, :]

    x = x_ref[0].astype(jnp.float32)             # (chunk, bw)
    a = a_ref[0].astype(jnp.float32)             # (chunk, bw)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x

    def step(t, carry):
        h, out = carry
        h = a[t][None, :] * h + gated[t][None, :]
        out = jax.lax.dynamic_update_slice(out, h, (t, 0))
        return h, out

    h0 = h_scr[...]                               # (1, bw)
    out0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h_last, outs = jax.lax.fori_loop(0, chunk, step, (h0, out0))
    h_ref[0] = outs.astype(h_ref.dtype)
    h_scr[...] = h_last

    @pl.when(it == nt - 1)
    def _write_state():
        hT_ref[0] = h_last[0]


def rglru_pallas(x, a, *, initial_state=None, chunk: int = 256,
                 block_w: int = 512, interpret: bool = False
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, a: (B, T, W) → (h (B,T,W), final state (B, W) f32)."""
    B, T, W = x.shape
    bw = min(block_w, W)
    padw = (-W) % bw
    padt = (-T) % chunk
    if padw:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, padw)))
        a = jnp.pad(a, ((0, 0), (0, 0), (0, padw)), constant_values=1.0)
    if padt:
        x = jnp.pad(x, ((0, 0), (0, padt), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, padt), (0, 0)), constant_values=1.0)
    Wp, Tp = x.shape[2], x.shape[1]
    nw, nt = Wp // bw, Tp // chunk
    h0 = (jnp.zeros((B, Wp), jnp.float32) if initial_state is None
          else jnp.pad(initial_state.astype(jnp.float32), ((0, 0), (0, padw)))
          if padw else initial_state.astype(jnp.float32))

    kernel = functools.partial(_rglru_kernel, chunk=chunk, nt=nt)
    h, hT = pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, chunk, bw), lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, chunk, bw), lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, bw), lambda b, iw, it: (b, iw)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bw), lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, bw), lambda b, iw, it: (b, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, Wp), x.dtype),
            jax.ShapeDtypeStruct((B, Wp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, h0)
    return h[:, :T, :W], hT[:, :W]
