"""Public kernel ops: jit'd wrappers that dispatch TPU→Pallas, CPU→reference.

Models import ONLY from this module. The dispatch decision is made once per
call site from the default backend (or forced via ``impl=``):

  impl="auto"    : pallas on TPU, blocked-jnp reference elsewhere
  impl="pallas"  : force the Pallas kernel (interpret=True off-TPU — tests)
  impl="ref"     : force the blocked reference
  impl="dense"   : O(S²) dense oracle (tiny test shapes only)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref as _ref

__all__ = ["flash_attention", "wkv6", "rglru", "default_impl"]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(impl: str) -> str:
    return default_impl() if impl == "auto" else impl


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, impl: str = "auto",
                    block_q: int = 128, block_k: int = 128) -> jnp.ndarray:
    """Causal/local GQA attention. q:(B,Hq,Sq,D) k,v:(B,Hkv,Sk,D) → (B,Hq,Sq,D)."""
    impl = _resolve(impl)
    if impl == "dense":
        return _ref.flash_attention_dense_ref(q, k, v, causal=causal, window=window,
                                              scale=scale)
    if impl == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        scale=scale)
    from .flash_attention import flash_attention_pallas

    interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(q, k, v, causal=causal, window=window, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)


# --------------------------------------------------------------------------
# RWKV6 WKV
# --------------------------------------------------------------------------

def wkv6(r, k, v, w, u, *, initial_state=None, chunk: int = 16,
         impl: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Data-dependent-decay linear attention (RWKV6 'Finch').

    r,k,w:(B,H,T,K) v:(B,H,T,V) u:(H,K) → (out (B,H,T,V), state (B,H,K,V)).
    Callers must guarantee log(w) ≥ -4 per step (see ref.wkv6_chunked_ref).
    """
    impl = _resolve(impl)
    if impl == "dense":
        return _ref.wkv6_ref(r, k, v, w, u, initial_state=initial_state)

    # pad T to a chunk multiple: r=k=0, w=1 pads are exact no-ops for both
    # the outputs (discarded) and the carried state.
    T = r.shape[2]
    pad = (-T) % chunk
    if pad:
        padT = lambda x, cval: jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)),
                                       constant_values=cval)
        r, k, v = padT(r, 0), padT(k, 0), padT(v, 0)
        w = padT(w, 1)
    if impl == "ref":
        out, state = _ref.wkv6_chunked_ref(r, k, v, w, u, chunk=chunk,
                                           initial_state=initial_state)
    else:
        from .rwkv6 import wkv6_pallas

        interpret = jax.default_backend() != "tpu"
        out, state = wkv6_pallas(r, k, v, w, u, initial_state=initial_state,
                                 chunk=chunk, interpret=interpret)
    if pad:
        out = out[:, :, :T, :]
    return out, state


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------

def rglru(x, a, *, initial_state=None, impl: str = "auto",
          chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RG-LRU diagonal recurrence. x,a:(B,T,D) → (h (B,T,D), state (B,D))."""
    impl = _resolve(impl)
    if impl == "dense":
        return _ref.rglru_ref(x, a, initial_state=initial_state)
    if impl == "ref":
        return _ref.rglru_scan_ref(x, a, initial_state=initial_state)
    from .rglru import rglru_pallas

    interpret = jax.default_backend() != "tpu"
    return rglru_pallas(x, a, initial_state=initial_state, chunk=chunk,
                        interpret=interpret)
