"""Pallas TPU flash attention (fwd) — causal / local-window, GQA, MLA-ready.

TPU-native design (not a CUDA port):
  - grid (B, Hq, Sq/bq, Sk/bk); the LAST grid dim is sequential on TPU
    ("arbitrary" semantics) so the online-softmax state lives in VMEM
    scratch across k-blocks — the accumulator never round-trips to HBM.
  - q/k/v blocks are MXU-aligned (bq, bk multiples of 128; D is the head
    dim, 64-256) and double-buffered by the Pallas pipeline from HBM.
  - GQA is an index_map trick: the kv block index is h // group, so kv
    tiles are fetched once per group from HBM (VMEM reuse across the group
    comes from the pipeline cache, no repeat() materialization).
  - causal/local masking is positional (right-aligned), enabling the same
    kernel for prefill (Sq == Sk) and windowed hybrids.

Backward: custom_vjp with a blocked pure-jnp recompute (flash-style, no S²
materialization). A fused bwd kernel is a possible further step; the fwd
kernel is where the roofline lives for the 32k prefill shapes.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 names it TPUCompilerParams; the kwargs are the same either way
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from . import ref as _ref

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: Optional[int],
                block_q: int, block_k: int, sq: int, sk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                    # (bk, Dv)

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (sk - sq)                                        # right-aligned
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = kpos < sk
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                    # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _write():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def _fwd_impl(q, k, v, *, causal, window, scale, block_q, block_k, interpret):
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    padq = (-Sq) % bq
    padk = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, padq), (0, 0))) if padq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, padk), (0, 0))) if padk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, padk), (0, 0))) if padk else v
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bk

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, sq=Sq, sk=Sk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, iq, ik, g=g: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, nq * bq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_pallas(q, k, v, causal=True, window=None, scale=None,
                           block_q=128, block_k=128, interpret=False):
    return _fwd_impl(q, k, v, causal=causal, window=window, scale=scale,
                     block_q=block_q, block_k=block_k, interpret=interpret)


def _vjp_fwd(q, k, v, causal, window, scale, block_q, block_k, interpret):
    out = _fwd_impl(q, k, v, causal=causal, window=window, scale=scale,
                    block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v)


def _vjp_bwd(causal, window, scale, block_q, block_k, interpret, res, dout):
    q, k, v = res
    # blocked recompute bwd (pure jnp, flash-style memory profile)
    f = lambda q_, k_, v_: _ref.flash_attention_ref(
        q_, k_, v_, causal=causal, window=window, scale=scale,
        block_k=max(block_k, 128))
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(dout)


flash_attention_pallas.defvjp(_vjp_fwd, _vjp_bwd)
