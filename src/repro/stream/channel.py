"""Bounded, backpressured chunk channels — the transport of `repro.stream`.

A :class:`Channel` is a thread-safe bounded queue of ``(seq, chunk)`` pairs
with explicit end-of-stream and error propagation. ``put`` blocks while the
channel is full — that block *is* the backpressure contract: a fast producer
cannot buffer more than ``capacity`` chunks ahead of a slow consumer, so
pipeline memory stays bounded no matter how skewed the stage speeds are
(see docs/streaming.md §2).

A :class:`StreamHandle` is the producer-side fan-out view: one bounded
channel per statically-known subscriber with broadcast ``put``. A consumer
resolved from the journal (replayed — it will never read) calls
``subscribe(...).abandon()`` so the producer never blocks against a
channel nobody will drain.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

__all__ = ["Channel", "ChannelClosed", "StreamHandle"]

DEFAULT_CAPACITY = 8


class ChannelClosed(RuntimeError):
    """Put after close, or get on a channel closed with an upstream error."""


class Channel:
    """A bounded FIFO of ``(seq, chunk)`` pairs with blocking backpressure.

    Producer side: :meth:`put` (blocks while full), :meth:`close` (EOS, or
    error propagation when ``error`` is given). Consumer side: iterate —
    iteration ends at EOS and re-raises a producer error. ``stats`` records
    puts/gets, the high-watermark depth, and the total seconds producers
    spent blocked on backpressure.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, name: str = ""):
        if capacity < 1:
            raise ValueError("channel capacity must be >= 1")
        self.capacity = capacity
        self.name = name
        self._items: deque = deque()
        self._cv = threading.Condition()
        self._closed = False
        self._abandoned = False
        self._error: Optional[BaseException] = None
        self.stats: Dict[str, float] = {
            "puts": 0,
            "gets": 0,
            "dropped": 0,
            "high_watermark": 0,
            "put_blocked_s": 0.0,
        }

    # -- producer side ------------------------------------------------------
    def put(self, seq: int, chunk: Any, timeout: Optional[float] = None) -> bool:
        """Append one chunk; block while full (backpressure).

        Returns False when the consumer abandoned the channel (the chunk is
        dropped — the producer should keep going; its durability does not
        depend on any consumer). Raises :class:`ChannelClosed` on a closed
        channel and TimeoutError if ``timeout`` elapses while blocked.
        """
        import time

        with self._cv:
            if self._abandoned:
                self.stats["dropped"] += 1
                return False
            if self._closed:
                raise ChannelClosed(f"put on closed channel {self.name!r}")
            if len(self._items) >= self.capacity:
                t0 = time.perf_counter()
                ok = self._cv.wait_for(
                    lambda: len(self._items) < self.capacity
                    or self._closed
                    or self._abandoned,
                    timeout=timeout,
                )
                self.stats["put_blocked_s"] += time.perf_counter() - t0
                if not ok:
                    raise TimeoutError(
                        f"backpressure timeout on channel {self.name!r}"
                    )
                if self._abandoned:
                    self.stats["dropped"] += 1
                    return False
                if self._closed:
                    raise ChannelClosed(f"put on closed channel {self.name!r}")
            self._items.append((seq, chunk))
            self.stats["puts"] += 1
            self.stats["high_watermark"] = max(
                self.stats["high_watermark"], len(self._items)
            )
            self._cv.notify_all()
            return True

    def close(self, error: Optional[BaseException] = None) -> None:
        """End of stream. With ``error``, consumers re-raise it on get."""
        with self._cv:
            self._closed = True
            if error is not None and self._error is None:
                self._error = error
            self._cv.notify_all()

    # -- consumer side ------------------------------------------------------
    def abandon(self) -> None:
        """Consumer walks away: pending and future puts are dropped, never
        blocked — the producer-side contract survives a dead consumer."""
        with self._cv:
            self._abandoned = True
            self._items.clear()
            self._cv.notify_all()

    def get(self, timeout: Optional[float] = None) -> Optional[Tuple[int, Any]]:
        """Next ``(seq, chunk)`` or None at EOS; re-raises a producer error."""
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._items or self._closed, timeout=timeout
            )
            if not ok:
                raise TimeoutError(f"get timeout on channel {self.name!r}")
            if self._items:
                self.stats["gets"] += 1
                item = self._items.popleft()
                self._cv.notify_all()
                return item
            if self._error is not None:
                raise ChannelClosed(
                    f"upstream of channel {self.name!r} failed: {self._error}"
                ) from self._error
            return None

    def __iter__(self) -> Iterator[Tuple[int, Any]]:
        while True:
            item = self.get()
            if item is None:
                return
            yield item

    def depth(self) -> int:
        """Chunks currently buffered (0..capacity)."""
        with self._cv:
            return len(self._items)

    @property
    def closed(self) -> bool:
        """True once the producer has signalled EOS (or failed)."""
        with self._cv:
            return self._closed


class StreamHandle:
    """Producer-side broadcast over per-subscriber bounded channels.

    Built by the scheduler with the *static* set of stream-consumer node
    ids, before the producer emits anything, so no early chunk can be
    missed. Each subscriber later calls :meth:`subscribe` for its dedicated
    channel — and, if it was resolved from the journal (it will never
    read), immediately abandons it so broadcast never blocks on it.
    Backpressure is driven by the *slowest* live subscriber.
    """

    def __init__(
        self,
        node_id: str,
        subscribers: Iterable[str] = (),
        capacity: int = DEFAULT_CAPACITY,
    ):
        self.node_id = node_id
        self.capacity = capacity
        self._channels: Dict[str, Channel] = {
            sub: Channel(capacity, name=f"{node_id}->{sub}") for sub in subscribers
        }
        self._lock = threading.Lock()
        self._closed = False

    def subscribe(self, consumer_id: str) -> Channel:
        """The dedicated channel pre-created for ``consumer_id``."""
        with self._lock:
            try:
                return self._channels[consumer_id]
            except KeyError:
                raise KeyError(
                    f"{consumer_id!r} is not a declared subscriber of "
                    f"stream {self.node_id!r}"
                ) from None

    def put(self, seq: int, chunk: Any) -> None:
        """Broadcast one chunk to every non-abandoned subscriber channel."""
        with self._lock:
            channels = list(self._channels.values())
        for ch in channels:
            ch.put(seq, chunk)

    def close(self, error: Optional[BaseException] = None) -> None:
        """Broadcast EOS (or an error) to every subscriber channel."""
        with self._lock:
            if self._closed and error is None:
                return
            self._closed = True
            channels = list(self._channels.values())
        for ch in channels:
            ch.close(error)

    def channels(self) -> List[Channel]:
        """The per-subscriber channels (introspection/tests)."""
        with self._lock:
            return list(self._channels.values())
