"""Durable stream-stage runtime shared by both executors.

A *stream stage* (a ``source`` or ``map`` node) emits a sequence of chunks;
this module owns everything about that emission that must be identical
between :class:`~repro.core.executor.LocalExecutor` and
:class:`~repro.core.executor.ClusterExecutor`:

  - **chunk-granular durability** — every chunk is journaled as a
    ``CHUNK_COMMIT`` (sequence-numbered, digest-chained) *before* it is
    broadcast downstream, and the stream ends with ``STREAM_EOS`` plus a
    summary ``NODE_COMMIT`` so the standalone-journal invariant extends to
    streams (docs/streaming.md §4);
  - **replay** — chunks already committed by an earlier (possibly killed)
    run are re-emitted from the journal with zero producer re-execution;
  - **resume** — a partially-committed producer restarts from its last
    committed offset (``start=next_seq``), and a map stage skips upstream
    chunks its committed prefix already covers;
  - **failure containment** — a failing stage closes its downstream
    channels with the error (consumers re-raise) and a run-level cancel
    event stops sibling stages from committing past a doomed run.

The executors differ only in *how a stage's function is invoked* (in
process vs. through the Gateway); they inject that as callables.
"""

from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Set, Tuple

from repro.obs.metrics import metrics
from repro.wire import DIGEST_HEX_LEN, payload_digest

from .channel import Channel, StreamHandle

__all__ = [
    "StreamCancelled",
    "StreamPlan",
    "plan_streams",
    "stream_input_marker",
    "ChunkLog",
    "run_source_stage",
    "run_map_stage",
    "reduce_iter",
]


class StreamCancelled(RuntimeError):
    """The run failed elsewhere; this stage stopped without committing more."""


def chain_digest(prev_chain: str, output_digest: str) -> str:
    """Digest-chain step: each chunk's chain head commits to all its
    predecessors, so a journal's chunk prefix is tamper-evident."""
    h = hashlib.sha256()
    h.update(prev_chain.encode())
    h.update(b":")
    h.update(output_digest.encode())
    return h.hexdigest()[:DIGEST_HEX_LEN]


def stream_input_marker(dep_gid: str, up_ctx_digest: str,
                        up_input_digest: str) -> Dict[str, Any]:
    """Deterministic stand-in for a stream-typed input when digesting.

    A consumer's ``input_digest`` cannot hash the stream's *values* (they
    are unbounded and arrive over time), so the stream input contributes
    its upstream *identity* — the ``(node, ξ-digest, input-digest)`` triple
    that names the chunk sequence in the journal. Same upstream identity ⇒
    same chunk sequence ⇒ replay-safe consumer identity.
    """
    return {"__stream__": [dep_gid, up_ctx_digest, up_input_digest]}


# ---------------------------------------------------------------------------
# static stream topology of a scheduled graph
# ---------------------------------------------------------------------------


@dataclass
class StreamPlan:
    """Which exec nodes stream, who feeds whom, and which edges pipeline.

    ``stream_edges`` are the (upstream, consumer) pairs satisfied at
    upstream *start* (the consumer attaches to a channel); every other edge
    keeps batch semantics (satisfied at upstream commit).
    """

    kinds: Dict[str, str] = field(default_factory=dict)
    stream_dep: Dict[str, str] = field(default_factory=dict)
    subscribers: Dict[str, List[str]] = field(default_factory=dict)
    stream_edges: Set[Tuple[str, str]] = field(default_factory=set)

    def is_stage(self, gid: str) -> bool:
        """True for chunk *emitters* (source/map) — they get a StreamHandle."""
        return self.kinds.get(gid, "") in ("source", "map")


def plan_streams(exec_nodes: Dict[str, Any]) -> StreamPlan:
    """Derive the stream topology from contracted exec nodes.

    Stream nodes are guaranteed (by ``ContextGraph.contract``) never to be
    union members, so their group id is their node id.
    """
    plan = StreamPlan()
    for gid, node in exec_nodes.items():
        plan.kinds[gid] = getattr(node, "stream", "") or ""
    for gid, node in exec_nodes.items():
        kind = plan.kinds[gid]
        if kind not in ("map", "reduce"):
            continue
        stream_deps = [d for d in node.deps if plan.is_stage(d)]
        if len(stream_deps) != 1:
            raise ValueError(
                f"stream {kind} node {gid!r} needs exactly one stream-stage "
                f"dependency, has {len(stream_deps)}"
            )
        dep = stream_deps[0]
        plan.stream_dep[gid] = dep
        plan.subscribers.setdefault(dep, []).append(gid)
        plan.stream_edges.add((dep, gid))
    return plan


# ---------------------------------------------------------------------------
# chunk-granular journal interaction
# ---------------------------------------------------------------------------


class ChunkLog:
    """The durable chunk ledger of ONE stream identity ``(node, ξ, inputs)``.

    Wraps the journal + replay oracle: knows how many chunks are already
    committed (``next_seq``), the digest-chain head, and whether EOS was
    reached; commits new chunks and the terminal EOS/NODE_COMMIT pair.
    Thread-confined to its stage's thread.
    """

    def __init__(self, journal: Any, replay: Any, node_id: str,
                 ctx_digest: str, input_digest: str,
                 deps: Optional[List[str]] = None):
        self.journal = journal
        self.replay = replay
        self.node_id = node_id
        self.ctx_digest = ctx_digest
        self.input_digest = input_digest
        # upstream node ids, stamped on the summary NODE_COMMIT for the
        # lineage index (repro.journal.lineage)
        self.deps = sorted(set(deps)) if deps else []
        self.next_seq, self.chain, self.eos = replay.stream_progress(
            node_id, ctx_digest, input_digest
        )
        # instruments are resolved once here, then bumped lock-cheap per
        # chunk — commit_chunk is the hot path
        reg = metrics()
        self._metric_chunks = reg.counter("repro_stream_chunks_committed_total")
        self._metric_eos = reg.counter("repro_stream_eos_total")

    def replayed_values(self) -> List[Any]:
        """Payloads of the committed chunk prefix (seq 0..next_seq-1)."""
        return [
            rec.payload
            for rec in self.replay.stream_chunks(
                self.node_id, self.ctx_digest, self.input_digest
            )
        ]

    def commit_chunk(self, value: Any) -> int:
        """Durably commit the next chunk; returns its sequence number."""
        from repro.core.durable import JournalRecord

        seq = self.next_seq
        out_d = payload_digest(value)
        self.chain = chain_digest(self.chain, out_d)
        rec = JournalRecord(
            kind="CHUNK_COMMIT",
            node_id=self.node_id,
            context_digest=self.ctx_digest,
            input_digest=self.input_digest,
            output_digest=out_d,
            payload=value,
            meta={"seq": seq, "chain": self.chain},
        )
        if self.journal is not None:
            self.journal.append(rec)
        self.replay.record_chunk(rec)
        self.next_seq = seq + 1
        self._metric_chunks.inc()
        return seq

    def commit_eos(self) -> None:
        """Terminal pair: ``STREAM_EOS`` marker + summary ``NODE_COMMIT``.

        The NODE_COMMIT carries no payload (the chunks ARE the payload,
        already journaled); its ``meta.stream``/``meta.chain`` let the
        replay oracle materialize the full sequence from the chunk records.
        """
        from repro.core.durable import JournalRecord

        eos = JournalRecord(
            kind="STREAM_EOS",
            node_id=self.node_id,
            context_digest=self.ctx_digest,
            input_digest=self.input_digest,
            output_digest=self.chain,
            meta={"chunks": self.next_seq, "chain": self.chain},
        )
        meta: Dict[str, Any] = {"stream": self.next_seq, "chain": self.chain}
        if self.deps:
            meta["deps"] = self.deps
        commit = JournalRecord(
            kind="NODE_COMMIT",
            node_id=self.node_id,
            context_digest=self.ctx_digest,
            input_digest=self.input_digest,
            output_digest=self.chain,
            payload=None,
            meta=meta,
        )
        if self.journal is not None:
            self.journal.append(eos)
            self.journal.append(commit)
        self.replay.record_eos(eos)
        self.replay.record(commit)
        self.eos = True
        self._metric_eos.inc()


# ---------------------------------------------------------------------------
# stage loops
# ---------------------------------------------------------------------------


def _check_cancel(cancel: Optional[threading.Event], node_id: str) -> None:
    if cancel is not None and cancel.is_set():
        raise StreamCancelled(f"run cancelled; stage {node_id!r} stopping")


def run_source_stage(
    node_id: str,
    log: ChunkLog,
    handle: StreamHandle,
    invoke: Callable[[int], Iterable[Any]],
    cancel: Optional[threading.Event] = None,
    retries: int = 0,
) -> Tuple[List[Any], str]:
    """Run a producer durably: replay the committed prefix from the journal,
    then resume the generator from its last committed offset.

    ``invoke(start)`` must return an iterable yielding chunks from index
    ``start`` on. A mid-stream failure is retried up to ``retries`` times,
    each retry resuming from the *new* committed offset — chunks that made
    it to the journal are never asked of the producer again.

    Returns ``(all chunk values, "replayed"|"executed")``.
    """
    values = log.replayed_values()
    try:
        for seq, value in enumerate(values):
            _check_cancel(cancel, node_id)
            handle.put(seq, value)  # re-emit from the journal, not the producer
        if log.eos:
            handle.close()
            return values, "replayed"
        attempt = 0
        while True:
            _check_cancel(cancel, node_id)
            chunks = invoke(log.next_seq)
            try:
                for value in chunks:
                    _check_cancel(cancel, node_id)
                    seq = log.commit_chunk(value)  # durable BEFORE visible
                    handle.put(seq, value)
                    values.append(value)
                break
            except StreamCancelled:
                raise
            except Exception:
                attempt += 1
                if attempt > retries:
                    raise
            finally:
                # a remote chunk iterator (the async transport's bridge, or a
                # WorkerClient stream) holds a live connection — release it
                # deterministically on cancel/failure instead of waiting on GC
                close = getattr(chunks, "close", None)
                if close is not None:
                    try:
                        close()
                    except Exception:
                        pass
        log.commit_eos()
        handle.close()
    except BaseException as exc:
        handle.close(error=exc)
        raise
    return values, "executed"


def run_map_stage(
    node_id: str,
    log: ChunkLog,
    upstream: Channel,
    handle: StreamHandle,
    invoke_chunk: Callable[[int, Any], Any],
    cancel: Optional[threading.Event] = None,
    retries: int = 0,
) -> Tuple[List[Any], str]:
    """Run a per-chunk mapper durably, pipelined against its producer.

    The committed output prefix is re-emitted from the journal and the
    corresponding upstream chunks are *consumed and dropped* (they were
    mapped in a previous life); every fresh upstream chunk is mapped,
    committed, then broadcast. Output seq k corresponds 1:1 to input seq k.
    A failing chunk call is retried up to ``retries`` times (per chunk —
    committed chunks are never at risk).
    """
    values = log.replayed_values()
    try:
        for seq, value in enumerate(values):
            _check_cancel(cancel, node_id)
            handle.put(seq, value)
        if log.eos:
            upstream.abandon()  # nothing more needed from the producer
            handle.close()
            return values, "replayed"
        skip = log.next_seq
        for seq, chunk in upstream:
            _check_cancel(cancel, node_id)
            if seq < skip:
                continue  # our committed prefix already covers this chunk
            attempt = 0
            while True:
                _check_cancel(cancel, node_id)
                try:
                    out = invoke_chunk(seq, chunk)
                    break
                except StreamCancelled:
                    raise
                except Exception:
                    attempt += 1
                    if attempt > retries:
                        raise
            committed_seq = log.commit_chunk(out)
            if committed_seq != seq:
                raise RuntimeError(
                    f"map {node_id!r} seq misalignment: upstream {seq}, "
                    f"committed {committed_seq}"
                )
            handle.put(seq, out)
            values.append(out)
        log.commit_eos()
        handle.close()
    except BaseException as exc:
        upstream.abandon()
        handle.close(error=exc)
        raise
    return values, "executed"


def reduce_iter(upstream: Channel,
                cancel: Optional[threading.Event] = None) -> Iterator[Any]:
    """Chunk-value iterator handed to a reduce fn (seq numbers stripped)."""
    for _seq, chunk in upstream:
        if cancel is not None and cancel.is_set():
            raise StreamCancelled("run cancelled; reduce stopping")
        yield chunk
