"""repro.stream — chunked, pipelined, durable dataflow (docs/streaming.md).

The streaming subsystem lets a graph node be a *stream producer* (a
generator yielding chunks) whose consumers start on the **first chunk**
instead of the last: per-chunk ``map`` stages and whole-stream ``reduce``
stages are wired through bounded, backpressured channels, so a fast
producer can never buffer more than a channel's capacity ahead of its
slowest consumer.

Durability is chunk-granular: every chunk is a sequence-numbered,
digest-chained ``CHUNK_COMMIT`` journal record, streams end with
``STREAM_EOS``, and a run killed mid-stream replays its committed chunks
from the journal and resumes the producer from its last committed offset —
the standalone-journal invariant, extended to unbounded outputs.

Public surface:
  - :class:`Channel` / :class:`StreamHandle` — bounded backpressured
    chunk transport with per-subscriber fan-out;
  - ``Node(stream="source"|"map"|"reduce")`` declarations via
    :meth:`repro.core.ContextGraph.add_stream` / ``add(..., stream=...)``;
  - the executors in :mod:`repro.core.executor` pick the declarations up
    automatically — no separate streaming executor.
"""

from .channel import Channel, ChannelClosed, StreamHandle
from .runtime import (
    ChunkLog,
    StreamCancelled,
    StreamPlan,
    plan_streams,
    reduce_iter,
    run_map_stage,
    run_source_stage,
    stream_input_marker,
)

__all__ = [
    "Channel",
    "ChannelClosed",
    "StreamHandle",
    "ChunkLog",
    "StreamCancelled",
    "StreamPlan",
    "plan_streams",
    "reduce_iter",
    "run_map_stage",
    "run_source_stage",
    "stream_input_marker",
]
