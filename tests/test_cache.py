"""Content-addressed result cache (repro.cache) — correctness contract.

Covers the docs/result-cache.md guarantees:
  - cold run executes and stores; warm run hits without executing, including
    across a full process restart (disk tier, fresh interpreter);
  - any context-entry change flips the key (invalidation by construction);
  - a corrupted blob is dropped and the node recomputed — never a crash,
    never a stale value;
  - a cache-accelerated run's journal is a complete standalone record: it
    replays with zero re-execution and CACHE_HIT records in kinds();
  - explicit eviction (prefix namespace) and the byte-budget LRU sweep.
"""

import os
import subprocess
import sys
import time

import pytest
from _faults import faults  # noqa: F401 — fixture

from repro.cache import CacheKey, FileCacheBackend, MemoryLRU, ResultCache
from repro.core import (
    ClusterExecutor,
    Context,
    ContextGraph,
    Gateway,
    InProcWorker,
    Journal,
    LocalExecutor,
    TaskRegistry,
    WithContext,
)
from repro.core.graph import fn_digest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

# Call accounting lives in a module GLOBAL on purpose: fn_digest hashes
# closure cell values (capturing a mutating accumulator would — correctly,
# conservatively — flip the cache key between runs; see result-cache.md §3),
# so the tasks must reference their counter globally, not via a closure.
CALLS: list = []


def _src(ctx):
    CALLS.append("src")
    return 10


def _emit(ctx, src):
    CALLS.append("emit")
    return WithContext(src + 1, {"flavor": "durian"})


def _sink(ctx, emit):
    CALLS.append("sink")
    return [emit, ctx.get("flavor")]


def build_graph(origin=None):
    """Three-node chain with a WithContext fact emitted in the middle."""
    g = ContextGraph(origin=origin or Context.origin({"env": "test"}), name="g")
    g.add("src", _src)
    g.add("emit", _emit, deps=["src"])
    g.add("sink", _sink, deps=["emit"])
    return g


# --------------------------------------------------------------------------
# key derivation
# --------------------------------------------------------------------------


def test_fn_digest_distinguishes_code_and_names():
    assert fn_digest("work") != fn_digest("work2")
    assert len(fn_digest("work")) == 16

    f = lambda ctx, x: x + 1  # noqa: E731
    g = lambda ctx, x: x + 2  # noqa: E731
    h = lambda ctx, x: x + 1  # noqa: E731  (same code as f)
    assert fn_digest(f) != fn_digest(g)
    assert fn_digest(f) == fn_digest(h)
    assert fn_digest(None) != fn_digest("work")


def test_fn_digest_sees_closure_values():
    def make(n):
        def task(ctx):
            return n
        return task

    assert fn_digest(make(1)) != fn_digest(make(2))
    assert fn_digest(make(3)) == fn_digest(make(3))


def test_fn_digest_cycle_safe_for_corecursive_closures():
    def make():
        def a(x):
            return b(x)

        def b(x):
            return a(x - 1) if x else 0

        return a

    assert fn_digest(make()) == fn_digest(make())  # no RecursionError, stable


def test_fn_digest_stable_across_processes_with_nested_lambda():
    """Nested code objects must hash structurally, not by repr (addresses)."""
    script = (
        "from repro.core.graph import fn_digest\n"
        "def task(ctx, xs):\n"
        "    pick = lambda v: v * 2\n"
        "    return [pick(v) for v in xs]\n"
        "print('DIGEST', fn_digest(task))\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC)

    def digest_in_subprocess():
        proc = subprocess.run(
            [sys.executable, "-c", script],
            env=env,
            capture_output=True,
            text=True,
            timeout=120,
        )
        assert proc.returncode == 0, proc.stderr
        return proc.stdout.strip()

    assert digest_in_subprocess() == digest_in_subprocess()


def test_fn_digest_opaque_capture_never_hits():
    """Captures without canonical bytes digest as opaque: miss, never stale."""

    class Config:
        threshold = 1

    cfg = Config()

    def make():
        def task(ctx):
            return cfg.threshold

        return task

    # unique per digest: a mutated cfg can never be answered with a stale hit
    assert fn_digest(make()) != fn_digest(make())


def test_cache_key_id_and_relpath_roundtrip():
    k = CacheKey(fn="a" * 16, inputs="b" * 16, context="c" * 16)
    assert CacheKey.parse(k.id) == k
    assert CacheKey.from_relpath(k.relpath()) == k
    assert k.id.startswith(k.fn)


# --------------------------------------------------------------------------
# executor integration: cold → warm → replay
# --------------------------------------------------------------------------


def test_local_cold_stores_then_warm_hits(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()

    with Journal(str(tmp_path / "cold.wal"), sync="batch") as j:
        r1 = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r1.executed) == {"src", "emit", "sink"}
    assert r1.cached == () and r1.replayed == ()
    assert r1.outputs["sink"] == [11, "durian"]
    assert len(CALLS) == 3

    with Journal(str(tmp_path / "cold.wal"), sync="never") as j:
        kinds = j.kinds()
    assert kinds["CACHE_STORE"] == 3 and kinds["NODE_COMMIT"] == 3

    # warm: fresh journal, nothing executes, facts re-emitted downstream
    with Journal(str(tmp_path / "warm.wal"), sync="batch") as j:
        r2 = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r2.cached) == {"src", "emit", "sink"}
    assert r2.executed == () and len(CALLS) == 3
    assert r2.outputs["sink"] == [11, "durian"]


def test_hit_miss_across_subprocess_restart(tmp_path):
    """Warm hits must survive a full interpreter restart (disk tier)."""
    script = (
        "import sys\n"
        "from repro.cache import ResultCache\n"
        "from repro.core import Context, ContextGraph, LocalExecutor\n"
        "cache = ResultCache(sys.argv[1])\n"
        "g = ContextGraph(origin=Context.origin({'env': 'sub'}), name='sub')\n"
        "g.add('a', lambda ctx: 2)\n"
        "g.add('b', lambda ctx, a: a * 21, deps=['a'])\n"
        "rep = LocalExecutor(cache=cache).run(g)\n"
        "print('EXECUTED', len(rep.executed), 'CACHED', len(rep.cached),\n"
        "      'OUT', rep.outputs['b'])\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    root = str(tmp_path / "cache")

    def run_once():
        proc = subprocess.run(
            [sys.executable, "-c", script, root],
            env=env,
            capture_output=True,
            text=True,
            timeout=120,
        )
        assert proc.returncode == 0, proc.stderr
        return proc.stdout

    assert "EXECUTED 2 CACHED 0 OUT 42" in run_once()  # cold process
    assert "EXECUTED 0 CACHED 2 OUT 42" in run_once()  # restarted process


def test_context_change_invalidates(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()
    LocalExecutor(cache=cache).run(build_graph())
    assert len(CALLS) == 3

    # same graph, different origin context ⇒ different ξ digests ⇒ misses
    changed = Context.origin({"env": "CHANGED"})
    r = LocalExecutor(cache=cache).run(build_graph(origin=changed))
    assert set(r.executed) == {"src", "emit", "sink"}
    assert len(CALLS) == 6

    # original context still hits — the old entries were not clobbered
    r2 = LocalExecutor(cache=cache).run(build_graph())
    assert set(r2.cached) == {"src", "emit", "sink"}
    assert len(CALLS) == 6


def test_corrupted_blob_falls_back_to_recompute(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()
    LocalExecutor(cache=cache).run(build_graph())
    assert len(CALLS) == 3

    blobs = []
    for dirpath, _dirs, files in os.walk(cache.backend.root):
        blobs.extend(os.path.join(dirpath, f) for f in files)
    assert len(blobs) == 3
    for path in blobs:
        with open(path, "r+b") as fh:
            raw = fh.read()
            fh.seek(len(raw) // 2)
            fh.write(b"\xff\xff\xff\xff")

    # fresh cache object over the same root: disk is the only tier that hits
    fresh = ResultCache(str(tmp_path / "cache"))
    r = LocalExecutor(cache=fresh).run(build_graph())
    assert set(r.executed) == {"src", "emit", "sink"}  # recompute, no crash
    assert len(CALLS) == 6
    assert fresh.stats["corrupt"] == 3
    assert r.outputs["sink"] == [11, "durian"]

    # the corrupt blobs were dropped and re-stored; next run hits again
    again = ResultCache(str(tmp_path / "cache"))
    r2 = LocalExecutor(cache=again).run(build_graph())
    assert set(r2.cached) == {"src", "emit", "sink"}
    assert len(CALLS) == 6


def test_cache_scarred_journal_replays_clean(tmp_path):
    """The warm journal is a standalone durable record: replays, no cache."""
    cache = ResultCache(str(tmp_path / "cache"))
    LocalExecutor(cache=cache).run(build_graph())
    warm = str(tmp_path / "warm.wal")
    with Journal(warm, sync="batch") as j:
        r_warm = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r_warm.cached) == {"src", "emit", "sink"}

    with Journal(warm, sync="never") as j:
        kinds = j.kinds()
    assert kinds["CACHE_HIT"] == 3 and kinds["NODE_COMMIT"] == 3

    CALLS.clear()
    with Journal(warm, sync="batch") as j:
        r_replay = LocalExecutor(journal=j).run(build_graph())
    assert set(r_replay.replayed) == {"src", "emit", "sink"}
    assert r_replay.executed == () and r_replay.cached == ()
    assert CALLS == []
    assert r_replay.outputs["sink"] == [11, "durian"]

    # with journal AND cache, the journal (replay) wins — no double counting
    with Journal(warm, sync="batch") as j:
        r_both = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r_both.replayed) == {"src", "emit", "sink"}
    assert r_both.cached == ()


def test_cluster_warm_run_never_dispatches(tmp_path):
    reg = TaskRegistry()
    calls = []

    @reg.task("work")
    def work(ctx, **kw):
        calls.append(1)
        return sum(v for v in kw.values() if isinstance(v, int)) + 1

    def build():
        g = ContextGraph(name="cl")
        g.add("a", "work")
        g.add("b", "work", deps=["a"])
        g.add("c", "work", deps=["a", "b"])
        return g

    cache = ResultCache(str(tmp_path / "cache"))
    with Journal(str(tmp_path / "cold.wal"), sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r1 = ClusterExecutor(gw, journal=j, cache=cache, speculative=False).run(build())
    assert len(r1.executed) == 3 and len(calls) == 3

    with Journal(str(tmp_path / "warm.wal"), sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r2 = ClusterExecutor(gw, journal=j, cache=cache, speculative=False).run(build())
    assert set(r2.cached) == {"a", "b", "c"} and r2.executed == ()
    assert len(calls) == 3  # no task reached a worker
    assert r2.outputs == r1.outputs

    with Journal(str(tmp_path / "warm.wal"), sync="never") as j:
        kinds = j.kinds()
    assert kinds["CACHE_HIT"] == 3 and kinds["NODE_COMMIT"] == 3
    assert "NODE_START" not in kinds  # hits resolve before dispatch

    # the cache-scarred cluster journal replays clean on a cacheless executor
    with Journal(str(tmp_path / "warm.wal"), sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r3 = ClusterExecutor(gw, journal=j, speculative=False).run(build())
    assert set(r3.replayed) == {"a", "b", "c"}
    assert r3.executed == () and r3.cached == ()


def test_uncacheable_output_skipped_not_fatal(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    g = ContextGraph(name="unc")
    g.add("fn_factory", lambda ctx: (lambda x: x))  # not payload-encodable
    r = LocalExecutor(cache=cache).run(g)
    assert r.executed == ("fn_factory",)
    assert cache.stats["uncacheable"] == 1
    assert cache.stats["stores"] == 0


# --------------------------------------------------------------------------
# eviction
# --------------------------------------------------------------------------


def test_evict_prefix_namespace(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    fn_a, fn_b = fn_digest("task_a"), fn_digest("task_b")
    in_1, in_2, ctx = "1" * 16, "2" * 16, "c" * 16
    cache.put(CacheKey(fn_a, in_1, ctx), "a1")
    cache.put(CacheKey(fn_a, in_2, ctx), "a2")
    cache.put(CacheKey(fn_b, in_1, ctx), "b1")

    assert cache.evict(fn_a) == 2  # whole-function invalidation
    assert cache.get(CacheKey(fn_a, in_1, ctx)) is None
    assert cache.get(CacheKey(fn_a, in_2, ctx)) is None
    assert cache.get(CacheKey(fn_b, in_1, ctx)).value == "b1"

    assert cache.evict("") == 1  # clear() semantics
    assert cache.get(CacheKey(fn_b, in_1, ctx)) is None


def test_file_backend_byte_budget_evicts_lru(tmp_path):
    backend = FileCacheBackend(str(tmp_path / "cache"), max_bytes=400)
    cache = ResultCache(backend=backend)
    ctx = "c" * 16
    keys = [CacheKey(fn_digest(f"t{i}"), "i" * 16, ctx) for i in range(8)]
    for k in keys:
        cache.put(k, list(range(40)))
        time.sleep(0.01)  # distinct mtimes for LRU ordering
    assert backend.size_bytes() <= 400
    # oldest entries were swept, the newest survives
    assert backend.get(keys[0]) is None
    assert backend.get(keys[-1]) is not None


def test_memory_lru_bounded_and_recency_ordered():
    lru = MemoryLRU(max_entries=2)
    k = [CacheKey(str(i) * 16, "i" * 16, "c" * 16) for i in range(3)]
    lru.put(k[0], "v0")
    lru.put(k[1], "v1")
    assert lru.get(k[0]) == "v0"  # refresh k0 ⇒ k1 becomes the eviction victim
    lru.put(k[2], "v2")
    assert len(lru) == 2
    assert lru.get(k[1]) is None
    assert lru.get(k[0]) == "v0" and lru.get(k[2]) == "v2"


def test_stale_tmp_files_swept_on_open(tmp_path):
    root = str(tmp_path / "cache")
    os.makedirs(root)
    stale = os.path.join(root, "aa.bb.tmp.123.456")
    fresh = os.path.join(root, "cc.dd.tmp.789.012")
    for path in (stale, fresh):
        with open(path, "wb") as fh:
            fh.write(b"orphan")
    old = time.time() - 7200
    os.utime(stale, (old, old))

    FileCacheBackend(root)  # opening the root sweeps aged-out orphans
    assert not os.path.exists(stale)
    assert os.path.exists(fresh)  # could be a live writer's in-flight file


def test_memory_only_cache_requires_no_root():
    cache = ResultCache()  # no backend: single-process memoization still works
    key = CacheKey("f" * 16, "i" * 16, "c" * 16)
    assert cache.get(key) is None
    cache.put(key, {"x": 1})
    assert cache.get(key).value == {"x": 1}
    assert cache.evict("") == 0  # nothing on disk to count


def _union_a(ctx, b=None):
    CALLS.append("a")
    return 1 if b is None else b + 1


def _union_b(ctx, a=None):
    CALLS.append("b")
    return 0 if a is None else a * 2


def test_union_node_results_are_cacheable(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()

    def build():
        g = ContextGraph(name="u")
        g.add("a", _union_a, deps=["b"])
        g.add("b", _union_b, deps=["a"])
        return g

    r1 = LocalExecutor(cache=cache).run(build())
    n_cold = len(CALLS)
    assert len(r1.executed) == 1  # the contracted union node
    r2 = LocalExecutor(cache=cache).run(build())
    assert len(CALLS) == n_cold  # members did not re-run
    assert len(r2.cached) == 1
    assert r2.outputs == r1.outputs


@pytest.mark.parametrize("executor", ["local", "cluster"])
def test_warm_outputs_bitwise_equal_cold(tmp_path, executor):
    """Cache round-trip must preserve payload values exactly."""
    import numpy as np

    cache = ResultCache(str(tmp_path / "cache"))

    def make_local():
        g = ContextGraph(name="eq")
        g.add("arr", lambda ctx: np.arange(6, dtype=np.float32).reshape(2, 3))
        return g

    if executor == "local":
        run = lambda: LocalExecutor(cache=cache).run(make_local())  # noqa: E731
    else:
        reg = TaskRegistry()

        @reg.task("arr")
        def arr(ctx):
            return np.arange(6, dtype=np.float32).reshape(2, 3)

        def run():
            g = ContextGraph(name="eq")
            g.add("arr", "arr")
            with Gateway([InProcWorker("w0", reg)]) as gw:
                return ClusterExecutor(gw, cache=cache, speculative=False).run(g)

    r1, r2 = run(), run()
    assert r2.cached == ("arr",)
    np.testing.assert_array_equal(r1.outputs["arr"], r2.outputs["arr"])
    assert r1.outputs["arr"].dtype == r2.outputs["arr"].dtype


# --------------------------------------------------------------------------
# tiered backend: local tier + shared remote tier (docs/journal-lifecycle.md §4)
# --------------------------------------------------------------------------


def _tiered(tmp_path, host="hostA"):
    from repro.cache import TieredCacheBackend

    return TieredCacheBackend.at(
        str(tmp_path / host), str(tmp_path / "shared")
    )


def _key(i=0):
    return CacheKey(str(i % 10) * 16, "i" * 16, "c" * 16)


def test_tiered_put_publishes_to_both_tiers_atomically(tmp_path):
    be = _tiered(tmp_path)
    be.put(_key(), b"blob-body")
    assert be.local.get(_key()) == b"blob-body"
    assert be.remote.get(_key()) == b"blob-body"
    assert be.remote_errors == 0
    # atomic publish: no tmp litter under either root
    for root in (be.local.root, be.remote.root):
        for _dir, _sub, files in os.walk(root):
            assert not any(".tmp." in f for f in files), files


def test_tiered_remote_hit_promotes_into_local_tier(tmp_path):
    a = _tiered(tmp_path, "hostA")
    a.put(_key(), b"published")
    b = _tiered(tmp_path, "hostB")  # fresh host, same shared tier
    assert b.local.get(_key()) is None
    assert b.get(_key()) == b"published"  # read-through
    assert b.remote_hits == 1 and b.promotions == 1
    assert b.local.get(_key()) == b"published"  # promoted
    b.remote.discard(_key())
    assert b.get(_key()) == b"published"  # now served locally
    assert b.remote_hits == 1  # no second remote read


def test_tiered_discard_and_evict_hit_both_tiers(tmp_path):
    be = _tiered(tmp_path)
    be.put(_key(1), b"one")
    be.put(_key(2), b"two")
    be.discard(_key(1))  # both tiers: a corrupt blob must not re-promote
    assert be.local.get(_key(1)) is None and be.remote.get(_key(1)) is None
    assert be.get(_key(2)) == b"two"
    assert be.evict() == 1  # local count; remote swept too
    assert be.remote.get(_key(2)) is None


def test_fail_remote_store_never_leaves_torn_final_blob(tmp_path, faults):
    """Kill point ``remote-store``: the local tier still hits, and the torn
    partial exists only under a tmp name — never the final blob name."""
    be = _tiered(tmp_path)
    faults.fail_remote_store(be)
    be.put(_key(), b"x" * 64)  # best-effort remote: the put itself succeeds
    assert be.remote_errors == 1
    assert be.get(_key()) == b"x" * 64  # local tier is intact
    final = be.remote.path_for(_key())
    assert not os.path.exists(final)  # no torn blob under the final name
    assert os.path.exists(final + ".tmp.fault")  # the crash left only a tmp
    other = _tiered(tmp_path, "hostB")
    assert other.get(_key()) is None  # fleet misses; it never sees torn data

    be.put(_key(), b"x" * 64)  # fault fires once; the retry publishes
    assert be.remote.get(_key()) == b"x" * 64
    assert other.get(_key()) == b"x" * 64


def test_result_cache_remote_root_deduplicates_across_hosts(tmp_path):
    """End-to-end: host B's cold executor is served by host A's publishes."""
    shared = str(tmp_path / "shared")
    CALLS.clear()
    rep_a = LocalExecutor(
        cache=ResultCache(str(tmp_path / "hostA"), remote_root=shared)
    ).run(build_graph())
    assert len(rep_a.executed) == 3
    n_cold = len(CALLS)

    cache_b = ResultCache(str(tmp_path / "hostB"), remote_root=shared)
    rep_b = LocalExecutor(cache=cache_b).run(build_graph())
    assert rep_b.executed == () and len(rep_b.cached) == 3
    assert len(CALLS) == n_cold  # zero re-execution on the second host
    assert rep_b.outputs == rep_a.outputs
    assert cache_b.backend.remote_hits == cache_b.backend.promotions == 3


def test_result_cache_remote_root_requires_local_root():
    with pytest.raises(ValueError, match="remote_root"):
        ResultCache(None, remote_root="/tmp/shared")
