"""Content-addressed result cache (repro.cache) — correctness contract.

Covers the docs/result-cache.md guarantees:
  - cold run executes and stores; warm run hits without executing, including
    across a full process restart (disk tier, fresh interpreter);
  - any context-entry change flips the key (invalidation by construction);
  - a corrupted blob is dropped and the node recomputed — never a crash,
    never a stale value;
  - a cache-accelerated run's journal is a complete standalone record: it
    replays with zero re-execution and CACHE_HIT records in kinds();
  - explicit eviction (prefix namespace) and the byte-budget LRU sweep.
"""

import os
import subprocess
import sys
import time

import pytest

from repro.cache import CacheKey, FileCacheBackend, MemoryLRU, ResultCache
from repro.core import (
    ClusterExecutor,
    Context,
    ContextGraph,
    Gateway,
    InProcWorker,
    Journal,
    LocalExecutor,
    TaskRegistry,
    WithContext,
)
from repro.core.graph import fn_digest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

# Call accounting lives in a module GLOBAL on purpose: fn_digest hashes
# closure cell values (capturing a mutating accumulator would — correctly,
# conservatively — flip the cache key between runs; see result-cache.md §3),
# so the tasks must reference their counter globally, not via a closure.
CALLS: list = []


def _src(ctx):
    CALLS.append("src")
    return 10


def _emit(ctx, src):
    CALLS.append("emit")
    return WithContext(src + 1, {"flavor": "durian"})


def _sink(ctx, emit):
    CALLS.append("sink")
    return [emit, ctx.get("flavor")]


def build_graph(origin=None):
    """Three-node chain with a WithContext fact emitted in the middle."""
    g = ContextGraph(origin=origin or Context.origin({"env": "test"}), name="g")
    g.add("src", _src)
    g.add("emit", _emit, deps=["src"])
    g.add("sink", _sink, deps=["emit"])
    return g


# --------------------------------------------------------------------------
# key derivation
# --------------------------------------------------------------------------


def test_fn_digest_distinguishes_code_and_names():
    assert fn_digest("work") != fn_digest("work2")
    assert len(fn_digest("work")) == 16

    f = lambda ctx, x: x + 1  # noqa: E731
    g = lambda ctx, x: x + 2  # noqa: E731
    h = lambda ctx, x: x + 1  # noqa: E731  (same code as f)
    assert fn_digest(f) != fn_digest(g)
    assert fn_digest(f) == fn_digest(h)
    assert fn_digest(None) != fn_digest("work")


def test_fn_digest_sees_closure_values():
    def make(n):
        def task(ctx):
            return n
        return task

    assert fn_digest(make(1)) != fn_digest(make(2))
    assert fn_digest(make(3)) == fn_digest(make(3))


def test_fn_digest_cycle_safe_for_corecursive_closures():
    def make():
        def a(x):
            return b(x)

        def b(x):
            return a(x - 1) if x else 0

        return a

    assert fn_digest(make()) == fn_digest(make())  # no RecursionError, stable


def test_fn_digest_stable_across_processes_with_nested_lambda():
    """Nested code objects must hash structurally, not by repr (addresses)."""
    script = (
        "from repro.core.graph import fn_digest\n"
        "def task(ctx, xs):\n"
        "    pick = lambda v: v * 2\n"
        "    return [pick(v) for v in xs]\n"
        "print('DIGEST', fn_digest(task))\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC)

    def digest_in_subprocess():
        proc = subprocess.run(
            [sys.executable, "-c", script],
            env=env,
            capture_output=True,
            text=True,
            timeout=120,
        )
        assert proc.returncode == 0, proc.stderr
        return proc.stdout.strip()

    assert digest_in_subprocess() == digest_in_subprocess()


def test_fn_digest_opaque_capture_never_hits():
    """Captures without canonical bytes digest as opaque: miss, never stale."""

    class Config:
        threshold = 1

    cfg = Config()

    def make():
        def task(ctx):
            return cfg.threshold

        return task

    # unique per digest: a mutated cfg can never be answered with a stale hit
    assert fn_digest(make()) != fn_digest(make())


def test_cache_key_id_and_relpath_roundtrip():
    k = CacheKey(fn="a" * 16, inputs="b" * 16, context="c" * 16)
    assert CacheKey.parse(k.id) == k
    assert CacheKey.from_relpath(k.relpath()) == k
    assert k.id.startswith(k.fn)


# --------------------------------------------------------------------------
# executor integration: cold → warm → replay
# --------------------------------------------------------------------------


def test_local_cold_stores_then_warm_hits(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()

    with Journal(str(tmp_path / "cold.wal"), sync="batch") as j:
        r1 = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r1.executed) == {"src", "emit", "sink"}
    assert r1.cached == () and r1.replayed == ()
    assert r1.outputs["sink"] == [11, "durian"]
    assert len(CALLS) == 3

    with Journal(str(tmp_path / "cold.wal"), sync="never") as j:
        kinds = j.kinds()
    assert kinds["CACHE_STORE"] == 3 and kinds["NODE_COMMIT"] == 3

    # warm: fresh journal, nothing executes, facts re-emitted downstream
    with Journal(str(tmp_path / "warm.wal"), sync="batch") as j:
        r2 = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r2.cached) == {"src", "emit", "sink"}
    assert r2.executed == () and len(CALLS) == 3
    assert r2.outputs["sink"] == [11, "durian"]


def test_hit_miss_across_subprocess_restart(tmp_path):
    """Warm hits must survive a full interpreter restart (disk tier)."""
    script = (
        "import sys\n"
        "from repro.cache import ResultCache\n"
        "from repro.core import Context, ContextGraph, LocalExecutor\n"
        "cache = ResultCache(sys.argv[1])\n"
        "g = ContextGraph(origin=Context.origin({'env': 'sub'}), name='sub')\n"
        "g.add('a', lambda ctx: 2)\n"
        "g.add('b', lambda ctx, a: a * 21, deps=['a'])\n"
        "rep = LocalExecutor(cache=cache).run(g)\n"
        "print('EXECUTED', len(rep.executed), 'CACHED', len(rep.cached),\n"
        "      'OUT', rep.outputs['b'])\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    root = str(tmp_path / "cache")

    def run_once():
        proc = subprocess.run(
            [sys.executable, "-c", script, root],
            env=env,
            capture_output=True,
            text=True,
            timeout=120,
        )
        assert proc.returncode == 0, proc.stderr
        return proc.stdout

    assert "EXECUTED 2 CACHED 0 OUT 42" in run_once()  # cold process
    assert "EXECUTED 0 CACHED 2 OUT 42" in run_once()  # restarted process


def test_context_change_invalidates(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()
    LocalExecutor(cache=cache).run(build_graph())
    assert len(CALLS) == 3

    # same graph, different origin context ⇒ different ξ digests ⇒ misses
    changed = Context.origin({"env": "CHANGED"})
    r = LocalExecutor(cache=cache).run(build_graph(origin=changed))
    assert set(r.executed) == {"src", "emit", "sink"}
    assert len(CALLS) == 6

    # original context still hits — the old entries were not clobbered
    r2 = LocalExecutor(cache=cache).run(build_graph())
    assert set(r2.cached) == {"src", "emit", "sink"}
    assert len(CALLS) == 6


def test_corrupted_blob_falls_back_to_recompute(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()
    LocalExecutor(cache=cache).run(build_graph())
    assert len(CALLS) == 3

    blobs = []
    for dirpath, _dirs, files in os.walk(cache.backend.root):
        blobs.extend(os.path.join(dirpath, f) for f in files)
    assert len(blobs) == 3
    for path in blobs:
        with open(path, "r+b") as fh:
            raw = fh.read()
            fh.seek(len(raw) // 2)
            fh.write(b"\xff\xff\xff\xff")

    # fresh cache object over the same root: disk is the only tier that hits
    fresh = ResultCache(str(tmp_path / "cache"))
    r = LocalExecutor(cache=fresh).run(build_graph())
    assert set(r.executed) == {"src", "emit", "sink"}  # recompute, no crash
    assert len(CALLS) == 6
    assert fresh.stats["corrupt"] == 3
    assert r.outputs["sink"] == [11, "durian"]

    # the corrupt blobs were dropped and re-stored; next run hits again
    again = ResultCache(str(tmp_path / "cache"))
    r2 = LocalExecutor(cache=again).run(build_graph())
    assert set(r2.cached) == {"src", "emit", "sink"}
    assert len(CALLS) == 6


def test_cache_scarred_journal_replays_clean(tmp_path):
    """The warm journal is a standalone durable record: replays, no cache."""
    cache = ResultCache(str(tmp_path / "cache"))
    LocalExecutor(cache=cache).run(build_graph())
    warm = str(tmp_path / "warm.wal")
    with Journal(warm, sync="batch") as j:
        r_warm = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r_warm.cached) == {"src", "emit", "sink"}

    with Journal(warm, sync="never") as j:
        kinds = j.kinds()
    assert kinds["CACHE_HIT"] == 3 and kinds["NODE_COMMIT"] == 3

    CALLS.clear()
    with Journal(warm, sync="batch") as j:
        r_replay = LocalExecutor(journal=j).run(build_graph())
    assert set(r_replay.replayed) == {"src", "emit", "sink"}
    assert r_replay.executed == () and r_replay.cached == ()
    assert CALLS == []
    assert r_replay.outputs["sink"] == [11, "durian"]

    # with journal AND cache, the journal (replay) wins — no double counting
    with Journal(warm, sync="batch") as j:
        r_both = LocalExecutor(journal=j, cache=cache).run(build_graph())
    assert set(r_both.replayed) == {"src", "emit", "sink"}
    assert r_both.cached == ()


def test_cluster_warm_run_never_dispatches(tmp_path):
    reg = TaskRegistry()
    calls = []

    @reg.task("work")
    def work(ctx, **kw):
        calls.append(1)
        return sum(v for v in kw.values() if isinstance(v, int)) + 1

    def build():
        g = ContextGraph(name="cl")
        g.add("a", "work")
        g.add("b", "work", deps=["a"])
        g.add("c", "work", deps=["a", "b"])
        return g

    cache = ResultCache(str(tmp_path / "cache"))
    with Journal(str(tmp_path / "cold.wal"), sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r1 = ClusterExecutor(gw, journal=j, cache=cache, speculative=False).run(build())
    assert len(r1.executed) == 3 and len(calls) == 3

    with Journal(str(tmp_path / "warm.wal"), sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r2 = ClusterExecutor(gw, journal=j, cache=cache, speculative=False).run(build())
    assert set(r2.cached) == {"a", "b", "c"} and r2.executed == ()
    assert len(calls) == 3  # no task reached a worker
    assert r2.outputs == r1.outputs

    with Journal(str(tmp_path / "warm.wal"), sync="never") as j:
        kinds = j.kinds()
    assert kinds["CACHE_HIT"] == 3 and kinds["NODE_COMMIT"] == 3
    assert "NODE_START" not in kinds  # hits resolve before dispatch

    # the cache-scarred cluster journal replays clean on a cacheless executor
    with Journal(str(tmp_path / "warm.wal"), sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r3 = ClusterExecutor(gw, journal=j, speculative=False).run(build())
    assert set(r3.replayed) == {"a", "b", "c"}
    assert r3.executed == () and r3.cached == ()


def test_uncacheable_output_skipped_not_fatal(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    g = ContextGraph(name="unc")
    g.add("fn_factory", lambda ctx: (lambda x: x))  # not payload-encodable
    r = LocalExecutor(cache=cache).run(g)
    assert r.executed == ("fn_factory",)
    assert cache.stats["uncacheable"] == 1
    assert cache.stats["stores"] == 0


# --------------------------------------------------------------------------
# eviction
# --------------------------------------------------------------------------


def test_evict_prefix_namespace(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    fn_a, fn_b = fn_digest("task_a"), fn_digest("task_b")
    in_1, in_2, ctx = "1" * 16, "2" * 16, "c" * 16
    cache.put(CacheKey(fn_a, in_1, ctx), "a1")
    cache.put(CacheKey(fn_a, in_2, ctx), "a2")
    cache.put(CacheKey(fn_b, in_1, ctx), "b1")

    assert cache.evict(fn_a) == 2  # whole-function invalidation
    assert cache.get(CacheKey(fn_a, in_1, ctx)) is None
    assert cache.get(CacheKey(fn_a, in_2, ctx)) is None
    assert cache.get(CacheKey(fn_b, in_1, ctx)).value == "b1"

    assert cache.evict("") == 1  # clear() semantics
    assert cache.get(CacheKey(fn_b, in_1, ctx)) is None


def test_file_backend_byte_budget_evicts_lru(tmp_path):
    backend = FileCacheBackend(str(tmp_path / "cache"), max_bytes=400)
    cache = ResultCache(backend=backend)
    ctx = "c" * 16
    keys = [CacheKey(fn_digest(f"t{i}"), "i" * 16, ctx) for i in range(8)]
    for i, k in enumerate(keys):
        cache.put(k, list(range(40)))
        time.sleep(0.01)  # distinct mtimes for LRU ordering
    assert backend.size_bytes() <= 400
    # oldest entries were swept, the newest survives
    assert backend.get(keys[0]) is None
    assert backend.get(keys[-1]) is not None


def test_memory_lru_bounded_and_recency_ordered():
    lru = MemoryLRU(max_entries=2)
    k = [CacheKey(str(i) * 16, "i" * 16, "c" * 16) for i in range(3)]
    lru.put(k[0], "v0")
    lru.put(k[1], "v1")
    assert lru.get(k[0]) == "v0"  # refresh k0 ⇒ k1 becomes the eviction victim
    lru.put(k[2], "v2")
    assert len(lru) == 2
    assert lru.get(k[1]) is None
    assert lru.get(k[0]) == "v0" and lru.get(k[2]) == "v2"


def test_stale_tmp_files_swept_on_open(tmp_path):
    root = str(tmp_path / "cache")
    os.makedirs(root)
    stale = os.path.join(root, "aa.bb.tmp.123.456")
    fresh = os.path.join(root, "cc.dd.tmp.789.012")
    for path in (stale, fresh):
        with open(path, "wb") as fh:
            fh.write(b"orphan")
    old = time.time() - 7200
    os.utime(stale, (old, old))

    FileCacheBackend(root)  # opening the root sweeps aged-out orphans
    assert not os.path.exists(stale)
    assert os.path.exists(fresh)  # could be a live writer's in-flight file


def test_memory_only_cache_requires_no_root():
    cache = ResultCache()  # no backend: single-process memoization still works
    key = CacheKey("f" * 16, "i" * 16, "c" * 16)
    assert cache.get(key) is None
    cache.put(key, {"x": 1})
    assert cache.get(key).value == {"x": 1}
    assert cache.evict("") == 0  # nothing on disk to count


def _union_a(ctx, b=None):
    CALLS.append("a")
    return 1 if b is None else b + 1


def _union_b(ctx, a=None):
    CALLS.append("b")
    return 0 if a is None else a * 2


def test_union_node_results_are_cacheable(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    CALLS.clear()

    def build():
        g = ContextGraph(name="u")
        g.add("a", _union_a, deps=["b"])
        g.add("b", _union_b, deps=["a"])
        return g

    r1 = LocalExecutor(cache=cache).run(build())
    n_cold = len(CALLS)
    assert len(r1.executed) == 1  # the contracted union node
    r2 = LocalExecutor(cache=cache).run(build())
    assert len(CALLS) == n_cold  # members did not re-run
    assert len(r2.cached) == 1
    assert r2.outputs == r1.outputs


@pytest.mark.parametrize("executor", ["local", "cluster"])
def test_warm_outputs_bitwise_equal_cold(tmp_path, executor):
    """Cache round-trip must preserve payload values exactly."""
    import numpy as np

    cache = ResultCache(str(tmp_path / "cache"))

    def make_local():
        g = ContextGraph(name="eq")
        g.add("arr", lambda ctx: np.arange(6, dtype=np.float32).reshape(2, 3))
        return g

    if executor == "local":
        run = lambda: LocalExecutor(cache=cache).run(make_local())  # noqa: E731
    else:
        reg = TaskRegistry()

        @reg.task("arr")
        def arr(ctx):
            return np.arange(6, dtype=np.float32).reshape(2, 3)

        def run():
            g = ContextGraph(name="eq")
            g.add("arr", "arr")
            with Gateway([InProcWorker("w0", reg)]) as gw:
                return ClusterExecutor(gw, cache=cache, speculative=False).run(g)

    r1, r2 = run(), run()
    assert r2.cached == ("arr",)
    np.testing.assert_array_equal(r1.outputs["arr"], r2.outputs["arr"])
    assert r1.outputs["arr"].dtype == r2.outputs["arr"].dtype
