"""Barrier-free dataflow scheduling + worker-failure recovery (ClusterExecutor).

Covers the post-level-barrier contract:
  - a ready node dispatches the moment its deps commit, even while unrelated
    same-level nodes are still running (no stage barrier),
  - the wait path is event-driven (no sleep-polling),
  - a worker killed mid-graph (fast-crash or silent hang) does not fail the
    run: orphaned work is requeued on survivors, requeues are journaled with
    attempt counts, and the dead worker is evicted from the gateway pool,
  - a journal produced by a failure-scarred run replays cleanly.
"""

import inspect
import threading
import time

from _faults import faults  # noqa: F401 — fixture

from repro.core import (
    ClusterExecutor,
    ContextGraph,
    Gateway,
    InProcWorker,
    Journal,
    TaskRegistry,
    WithContext,
)


def test_child_dispatches_before_unrelated_sibling_finishes():
    """The defining dataflow property: dependency-ready beats level-complete.

    "slow" and "quick" share a toposort level. "dependent" needs only
    "quick" — and is itself what *unblocks* "slow". A level-barrier
    scheduler would wait out the 10 s block; the dataflow scheduler runs
    "dependent" while "slow" is still parked.
    """
    reg = TaskRegistry()
    release = threading.Event()
    order = []

    @reg.task("blocker")
    def blocker(ctx):
        release.wait(10.0)
        order.append("blocker")
        return "blocker-done"

    @reg.task("fast")
    def fast(ctx):
        return "fast-done"

    @reg.task("child")
    def child(ctx, **kw):
        order.append("child")
        release.set()
        return "child-done"

    workers = [InProcWorker(f"w{i}", reg) for i in range(3)]
    g = ContextGraph(name="barrier-free")
    g.add("slow", "blocker")
    g.add("quick", "fast")
    g.add("dependent", "child", deps=["quick"])
    t0 = time.time()
    with Gateway(workers) as gw:
        rep = ClusterExecutor(gw, speculative=False).run(g)
    assert order[0] == "child"  # ran while same-level "slow" was still blocked
    assert rep.outputs["dependent"] == "child-done"
    assert rep.outputs["slow"] == "blocker-done"
    assert time.time() - t0 < 9.0  # would be ~10 s under a level barrier


def test_cluster_wait_path_has_no_sleep_polling():
    src = inspect.getsource(ClusterExecutor)
    assert "time.sleep" not in src  # completions arrive via Condition.wait


def test_worker_killed_mid_graph_run_completes(tmp_path, faults):
    """Fast-crash death: the first task landing on w0 kills it mid-flight."""
    reg = TaskRegistry()

    @reg.task("work")
    def work(ctx, **kw):
        time.sleep(0.005)
        return sum(v for v in kw.values() if isinstance(v, int)) + 1

    flaky = faults.flaky_worker("w0", reg, after=1)
    workers = [flaky, InProcWorker("w1", reg), InProcWorker("w2", reg)]
    g = ContextGraph(name="kill-mid-run")
    for i in range(8):
        g.add(f"a{i}", "work")
        g.add(f"b{i}", "work", deps=[f"a{i}"])
    path = str(tmp_path / "kill.wal")
    with Journal(path, sync="batch") as j:
        with Gateway(workers, heartbeat_interval_s=0.05) as gw:
            rep = ClusterExecutor(gw, journal=j, speculative=False).run(g)
            # eviction from the pool: the dead worker is no longer allocatable
            assert "w0" not in [h.name for h in gw.live_workers()]
        assert flaky.starts >= 1  # it really did accept work before dying
        assert all(rep.outputs[f"b{i}"] == 2 for i in range(8))
        # requeues are journaled with attempt counts
        requeues = [r for r in j.records() if r.kind == "NODE_REQUEUE"]
        assert requeues, "worker death must journal at least one NODE_REQUEUE"
        assert all(r.attempt >= 1 for r in requeues)
        assert all(r.node_id and r.meta.get("reason") for r in requeues)
        kinds = j.kinds()
        assert kinds["NODE_COMMIT"] == 16
        assert kinds["RUN_END"] == 1


def test_hung_worker_recovered_by_heartbeat_eviction(faults):
    """Silent-partition death: the task hangs, only the heartbeat can tell."""
    reg = TaskRegistry()

    @reg.task("work")
    def work(ctx):
        time.sleep(0.005)
        return 1

    flaky = faults.flaky_worker("w0", reg, after=1, mode="hang", hang_timeout_s=5.0)
    workers = [flaky, InProcWorker("w1", reg)]
    g = ContextGraph(name="hang-recovery")
    for i in range(6):
        g.add(f"t{i}", "work")
    with Gateway(workers, heartbeat_interval_s=0.05) as gw:
        rep = ClusterExecutor(gw, speculative=False).run(g)
        flaky.release()  # unpark the stuck dispatch thread before shutdown
    assert all(rep.outputs[f"t{i}"] == 1 for i in range(6))
    assert gw.metrics["evicted"] >= 1  # recovery came from the heartbeat path


def test_failure_scarred_journal_replays_clean(tmp_path, faults):
    """A run that survived a worker death leaves a fully replayable journal."""
    reg = TaskRegistry()

    @reg.task("work")
    def work(ctx, **kw):
        return sum(v for v in kw.values() if isinstance(v, int)) + 1

    g = ContextGraph(name="replay-after-failure")
    for i in range(5):
        g.add(f"a{i}", "work")
        g.add(f"b{i}", "work", deps=[f"a{i}"])
    path = str(tmp_path / "scarred.wal")

    flaky = faults.flaky_worker("w0", reg, after=1)
    workers = [flaky, InProcWorker("w1", reg)]
    with Journal(path, sync="batch") as j:
        with Gateway(workers, heartbeat_interval_s=0.05) as gw:
            r1 = ClusterExecutor(gw, journal=j, speculative=False).run(g)

    survivors = [InProcWorker("w1", reg)]
    with Journal(path, sync="batch") as j:
        with Gateway(survivors) as gw:
            r2 = ClusterExecutor(gw, journal=j, speculative=False).run(g)
    assert r2.executed == ()  # zero re-execution
    assert set(r2.replayed) == set(r1.executed)
    assert r2.outputs == r1.outputs


def test_callable_withcontext_facts_survive_replay(tmp_path):
    """Gateway-side WithContext facts are journaled and re-emitted on replay,
    keeping downstream ξ digests identical (zero re-execution)."""
    reg = TaskRegistry()

    @reg.task("consume")
    def consume(ctx, **kw):
        return ctx.get("flavor", "missing")

    def emit(ctx):
        return WithContext("out", {"flavor": "durian"})

    g = ContextGraph(name="facts-replay")
    g.add("emitter", emit)
    g.add("reader", "consume", deps=["emitter"])
    path = str(tmp_path / "facts.wal")
    with Journal(path, sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r1 = ClusterExecutor(gw, journal=j).run(g)
    with Journal(path, sync="batch") as j:
        with Gateway([InProcWorker("w0", reg)]) as gw:
            r2 = ClusterExecutor(gw, journal=j).run(g)
    assert r1.outputs["reader"] == "durian"
    assert r2.executed == ()  # facts re-emitted, digests identical, all replayed
    assert r2.outputs == r1.outputs


def test_global_speculation_covers_cross_level_straggler():
    """Speculation is global: a straggler deep in the graph still gets a copy
    while unrelated shallow nodes keep committing around it."""
    reg = TaskRegistry()
    calls = {"n": 0}
    lock = threading.Lock()

    @reg.task("work")
    def work(ctx, **kw):
        with lock:
            calls["n"] += 1
            n = calls["n"]
        time.sleep(2.0 if n == 7 else 0.01)  # one pathological straggler
        return sum(v for v in kw.values() if isinstance(v, int)) + 1

    workers = [InProcWorker(f"w{i}", reg) for i in range(3)]
    g = ContextGraph(name="global-speculation")
    for i in range(6):
        g.add(f"a{i}", "work")
        g.add(f"b{i}", "work", deps=[f"a{i}"])
    with Gateway(workers) as gw:
        ex = ClusterExecutor(gw, speculative=True, speculation_tick_s=0.02)
        ex.straggler.threshold = 3.0
        t0 = time.time()
        rep = ex.run(g)
        wall = time.time() - t0
    assert all(rep.outputs[f"b{i}"] == 2 for i in range(6))
    # the run returned well before the 2 s straggler could have finished,
    # and an extra (speculative) task execution was dispatched to cover it
    assert wall < 1.5
    assert calls["n"] >= 13
