"""Streaming dataflow subsystem (repro.stream): channels, pipelined
execution, chunk-granular durability, and stream replay/resume.

Covers the docs/streaming.md contract:
  - bounded channels backpressure a fast producer against a slow consumer,
  - consumers start on the FIRST chunk (pipelining, not batch barriers),
  - every chunk is a digest-chained CHUNK_COMMIT before it is visible,
  - a run killed mid-stream replays committed chunks from the journal with
    zero producer re-emission and resumes from the last committed offset,
  - streams cross the HTTP worker boundary incrementally, with typed
    mid-stream failure and resume.
"""

import io
import threading
import time

import pytest
from _faults import InjectedFault, faults  # noqa: F401 — fixture

from repro.core import (
    ClusterExecutor,
    ContextGraph,
    CycleError,
    Gateway,
    InProcWorker,
    Journal,
    LocalExecutor,
    TaskRegistry,
    WorkerClient,
    WorkerServer,
)
from repro.stream import Channel, ChannelClosed, StreamHandle
from repro.stream.runtime import chain_digest
from repro.wire import PayloadDecodeError, encode_frame, read_frames


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------


def test_channel_put_get_eos():
    ch = Channel(capacity=4)
    ch.put(0, "a")
    ch.put(1, "b")
    ch.close()
    assert list(ch) == [(0, "a"), (1, "b")]
    with pytest.raises(ChannelClosed):
        ch.put(2, "c")


def test_channel_backpressure_blocks_and_measures():
    ch = Channel(capacity=2)
    ch.put(0, 0)
    ch.put(1, 1)
    done = threading.Event()

    def producer():
        ch.put(2, 2)  # must block until the consumer drains one
        done.set()

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not done.is_set()  # bounded: the third put is parked
    assert ch.get() == (0, 0)
    assert done.wait(2.0)
    assert ch.stats["put_blocked_s"] > 0.0
    assert ch.stats["high_watermark"] <= 2


def test_channel_error_propagates_to_consumer():
    ch = Channel(capacity=2)
    ch.put(0, "x")
    ch.close(error=RuntimeError("producer died"))
    assert ch.get() == (0, "x")
    with pytest.raises(ChannelClosed, match="producer died"):
        ch.get()


def test_channel_abandon_drops_instead_of_blocking():
    ch = Channel(capacity=1)
    ch.abandon()
    for i in range(10):  # would deadlock on a capacity-1 channel otherwise
        assert ch.put(i, i) is False
    assert ch.stats["dropped"] == 10


def test_stream_handle_broadcast_and_abandoned_subscriber():
    h = StreamHandle("src", ["a", "b"], capacity=2)
    cha = h.subscribe("a")
    # b was replayed: abandoning its channel must never block the producer
    h.subscribe("b").abandon()
    for i in range(6):
        drained = []
        h.put(i, i * 10)
        while cha.depth():
            drained.append(cha.get())
    h.close()
    with pytest.raises(KeyError):
        h.subscribe("zzz")


# ---------------------------------------------------------------------------
# wire chunk framing
# ---------------------------------------------------------------------------


def test_frame_roundtrip_and_corruption_detection():
    frames = [{"s": 0, "c": [1, 2, 3]}, {"s": 1, "c": "x"}, {"eos": 2}]
    buf = b"".join(encode_frame(f) for f in frames)
    assert list(read_frames(io.BytesIO(buf))) == frames
    # flip a byte inside the first frame body: crc must catch it
    corrupt = bytearray(buf)
    corrupt[10] ^= 0xFF
    with pytest.raises(PayloadDecodeError):
        list(read_frames(io.BytesIO(bytes(corrupt))))
    # torn stream: truncated mid-frame is detected, not silently EOS'd
    with pytest.raises(PayloadDecodeError, match="torn"):
        list(read_frames(io.BytesIO(buf[: len(buf) - 3])))


# ---------------------------------------------------------------------------
# graph declarations
# ---------------------------------------------------------------------------


def test_stream_topology_validation():
    g = ContextGraph(name="bad")
    g.add("plain", lambda ctx: 1)
    g.add("m", lambda ctx, plain: plain, deps=["plain"], stream="map")
    with pytest.raises(ValueError, match="exactly one stream-stage"):
        g.validate()

    g2 = ContextGraph(name="two-sources")
    g2.add_stream("s1", lambda ctx: iter([1]))
    g2.add_stream("s2", lambda ctx: iter([2]))
    g2.add("m", lambda ctx, s1, s2: s1, deps=["s1", "s2"], stream="map")
    with pytest.raises(ValueError, match="exactly one stream-stage"):
        g2.validate()

    g3 = ContextGraph(name="cyclic-stream")
    g3.add_stream("s", lambda ctx, m=None: iter([1]), deps=["m"])
    g3.add("m", lambda ctx, s: s, deps=["s"], stream="map")
    with pytest.raises(CycleError):
        g3.schedule()

    with pytest.raises(ValueError, match="stream must be one of"):
        ContextGraph(name="k").add("x", lambda ctx: 1, stream="fold")


def test_batch_dep_on_own_pipeline_rejected():
    """A consumer whose batch dep waits on its own producer's EOS would
    deadlock once the stream exceeds channel capacity — reject up front."""
    g = ContextGraph(name="wait-cycle")
    g.add_stream("src", lambda ctx, start=0: iter(range(start, 30)))
    g.add("b", lambda ctx, src: sum(src), deps=["src"])  # batch: waits for EOS
    g.add(
        "r",
        lambda ctx, src, b: sum(src) + b,
        deps=["src", "b"],
        stream="reduce",
    )
    with pytest.raises(ValueError, match="deadlock"):
        g.validate()

    # the transitive variant: the batch dep reaches the pipeline indirectly
    g2 = ContextGraph(name="wait-cycle-2")
    g2.add_stream("src", lambda ctx, start=0: iter(range(start, 30)))
    g2.add("m", lambda ctx, src: src, deps=["src"], stream="map")
    g2.add("x", lambda ctx, m: len(m), deps=["m"])  # batch on the map's EOS
    g2.add(
        "r",
        lambda ctx, m, x: len(list(m)) + x,
        deps=["m", "x"],
        stream="reduce",
    )
    with pytest.raises(ValueError, match="deadlock"):
        g2.validate()


def test_map_stage_honors_node_retries():
    """A transient per-chunk failure in a map stage retries instead of
    killing the run (batch nodes and sources already had this)."""
    failures = {"n": 0}

    def flaky(ctx, src):
        if src == 2 and failures["n"] < 2:
            failures["n"] += 1
            raise RuntimeError("transient")
        return src * 10

    g = ContextGraph(name="map-retries")
    g.add_stream("src", lambda ctx, start=0: iter(range(start, 5)))
    g.add("m", flaky, deps=["src"], stream="map", retries=3)
    g.add("r", lambda ctx, m: sum(m), deps=["m"], stream="reduce")
    rep = LocalExecutor().run(g)
    assert rep.outputs["r"] == sum(i * 10 for i in range(5))
    assert failures["n"] == 2  # it really did fail (and recover) twice


# ---------------------------------------------------------------------------
# pipelined local execution
# ---------------------------------------------------------------------------


def test_local_pipeline_producer_map_reduce():
    g = ContextGraph(name="pipe")
    g.add_stream("src", lambda ctx, start=0: iter(range(start, 8)))
    g.add("sq", lambda ctx, src: src * src, deps=["src"], stream="map")
    g.add("total", lambda ctx, sq: sum(sq), deps=["sq"], stream="reduce")
    rep = LocalExecutor().run(g)
    assert rep.outputs["src"] == list(range(8))
    assert rep.outputs["sq"] == [i * i for i in range(8)]
    assert rep.outputs["total"] == sum(i * i for i in range(8))
    assert set(rep.executed) == {"src", "sq", "total"}


def test_consumers_start_on_first_chunk_not_last():
    """The defining pipelining property: the map must process chunk 0 while
    the producer is still emitting (a batch barrier would forbid it)."""
    events = []
    lock = threading.Lock()
    release = threading.Event()

    def producer(ctx, start=0):
        for i in range(start, 4):
            if i == 3:
                # park until the map PROVES it consumed an earlier chunk
                assert release.wait(5.0), "map never started: no pipelining"
            with lock:
                events.append(("emit", i))
            yield i

    def mapper(ctx, src):
        with lock:
            events.append(("map", src))
        release.set()
        return src + 100

    g = ContextGraph(name="overlap")
    g.add_stream("src", producer)
    g.add("m", mapper, deps=["src"], stream="map")
    g.add("r", lambda ctx, m: len(list(m)), deps=["m"], stream="reduce")
    rep = LocalExecutor().run(g)
    assert rep.outputs["r"] == 4
    emit3 = events.index(("emit", 3))
    assert ("map", 0) in events[:emit3]  # map ran BEFORE the producer finished


def test_backpressure_bounds_producer_runahead():
    def producer(ctx, start=0):
        for i in range(start, 40):
            yield i

    def slow_reduce(ctx, src):
        total = 0
        for v in src:
            time.sleep(0.002)
            total += v
        return total

    g = ContextGraph(name="bp")
    g.add_stream("src", producer)
    g.add("r", slow_reduce, deps=["src"], stream="reduce")
    ex = LocalExecutor(channel_capacity=3)
    rep = ex.run(g)
    assert rep.outputs["r"] == sum(range(40))
    # runahead bound is asserted structurally by the channel capacity


def test_map_with_extra_batch_dep_and_alias():
    g = ContextGraph(name="mixed")
    g.add("offset", lambda ctx: 1000)
    g.add_stream("src", lambda ctx, start=0: iter(range(start, 5)))
    g.add(
        "m",
        lambda ctx, chunk, offset: chunk + offset,
        deps=["src", "offset"],
        stream="map",
        aliases={"src": "chunk"},
    )
    g.add("r", lambda ctx, m: list(m), deps=["m"], stream="reduce")
    rep = LocalExecutor().run(g)
    assert rep.outputs["r"] == [1000, 1001, 1002, 1003, 1004]


def test_batch_consumer_of_stream_gets_materialized_list():
    g = ContextGraph(name="materialize")
    g.add_stream("src", lambda ctx, start=0: iter(range(start, 4)))
    g.add("batch", lambda ctx, src: sum(src), deps=["src"])  # NOT a stream node
    rep = LocalExecutor().run(g)
    assert rep.outputs["batch"] == 6  # ran after EOS, saw the full list


# ---------------------------------------------------------------------------
# chunk-granular durability
# ---------------------------------------------------------------------------


def _resume_graph(calls, fail_at=None, faults=None):
    def producer(ctx, start=0):
        calls["starts"].append(start)
        for i in range(start, 6):
            calls["emitted"].append(i)
            yield i

    def mapper(ctx, src):
        calls["mapped"].append(src)
        return src * 2

    if fail_at is not None:
        # mid-chunk kill point via the shared fault harness: dies BEFORE the
        # trigger chunk is mapped, after earlier chunks committed
        mapper = faults.fail_chunk(mapper, value=fail_at)

    g = ContextGraph(name="durable-stream")
    g.add_stream("src", producer)
    g.add("m", mapper, deps=["src"], stream="map")
    g.add("r", lambda ctx, m: sum(m), deps=["m"], stream="reduce")
    return g


def test_stream_journal_kinds_and_chain(tmp_path):
    calls = {"starts": [], "emitted": [], "mapped": []}
    path = str(tmp_path / "s.wal")
    with Journal(path, sync="batch") as j:
        rep = LocalExecutor(journal=j).run(_resume_graph(calls))
        assert rep.outputs["r"] == sum(i * 2 for i in range(6))
        kinds = j.kinds()
        assert kinds["CHUNK_COMMIT"] == 12  # 6 source + 6 map
        assert kinds["STREAM_EOS"] == 2
        assert kinds["NODE_COMMIT"] == 3  # src, m (stream summaries) + r
        # the digest chain over src's chunks must verify end to end
        chain = ""
        for rec in j.records():
            if rec.kind == "CHUNK_COMMIT" and rec.node_id == "src":
                chain = chain_digest(chain, rec.output_digest)
                assert rec.meta["chain"] == chain
        eos = [r for r in j.records()
               if r.kind == "STREAM_EOS" and r.node_id == "src"]
        assert eos[0].meta["chain"] == chain
        assert eos[0].meta["chunks"] == 6


def test_mid_stream_kill_replays_chunks_and_resumes_producer(tmp_path, faults):
    """THE acceptance property: kill a run mid-stream, re-run on the same
    journal — committed chunks come from the journal (zero producer
    re-emission) and the producer resumes from its last committed offset."""
    calls = {"starts": [], "emitted": [], "mapped": []}
    path = str(tmp_path / "kill.wal")
    with Journal(path, sync="batch") as j:
        with pytest.raises(InjectedFault, match="killed mid-stream"):
            LocalExecutor(journal=j).run(_resume_graph(calls, fail_at=3, faults=faults))
    assert calls["starts"] == [0]
    with Journal(path, sync="batch") as j:
        committed = [r.payload for r in j.records()
                     if r.kind == "CHUNK_COMMIT" and r.node_id == "m"]
    assert committed == [0, 2, 4]  # chunks 0..2 mapped & durable before the kill

    calls2 = {"starts": [], "emitted": [], "mapped": []}
    with Journal(path, sync="batch") as j:
        rep = LocalExecutor(journal=j).run(_resume_graph(calls2))
    assert rep.outputs["r"] == sum(i * 2 for i in range(6))
    # the producer was either fully replayed (it reached EOS before the
    # kill) or resumed from its last committed offset — it never restarted
    # from 0, and no committed chunk was re-emitted by the producer
    assert all(start > 0 for start in calls2["starts"])
    assert all(v >= 3 for v in calls2["emitted"])
    # committed map chunks came from the journal: only 3,4,5 mapped fresh
    assert calls2["mapped"] == [3, 4, 5]

    calls3 = {"starts": [], "emitted": [], "mapped": []}
    with Journal(path, sync="batch") as j:
        rep3 = LocalExecutor(journal=j).run(_resume_graph(calls3))
    assert rep3.executed == ()  # full replay: zero re-execution anywhere
    assert calls3["emitted"] == [] and calls3["mapped"] == []
    assert rep3.outputs == rep.outputs


def test_producer_without_start_param_still_resumes(tmp_path):
    """A producer that cannot seek gets the skip-side resume: committed
    chunks are dropped from its regenerated output, not re-committed."""
    emitted = []

    def naive_producer(ctx):  # no start param
        for i in range(5):
            emitted.append(i)
            yield i

    def build(fail):
        def mapper(ctx, src):
            if fail and src == 2:
                raise RuntimeError("die")
            return src

        g = ContextGraph(name="naive")
        g.add_stream("src", naive_producer)
        g.add("m", mapper, deps=["src"], stream="map")
        g.add("r", lambda ctx, m: list(m), deps=["m"], stream="reduce")
        return g

    path = str(tmp_path / "naive.wal")
    with Journal(path, sync="batch") as j:
        with pytest.raises(RuntimeError):
            LocalExecutor(journal=j).run(build(True))
    with Journal(path, sync="batch") as j:
        rep = LocalExecutor(journal=j).run(build(False))
    assert rep.outputs["r"] == [0, 1, 2, 3, 4]
    with Journal(path, sync="batch") as j:
        # across both runs, every (seq) committed exactly once for src
        seqs = [r.meta["seq"] for r in j.records()
                if r.kind == "CHUNK_COMMIT" and r.node_id == "src"]
        assert sorted(seqs) == sorted(set(seqs))


# ---------------------------------------------------------------------------
# cluster execution
# ---------------------------------------------------------------------------


def _stream_registry():
    reg = TaskRegistry()

    @reg.task("gen")
    def gen(ctx, start=0):
        for i in range(start, 6):
            yield i

    @reg.task("double")
    def double(ctx, chunk):
        return chunk * 2

    return reg


def _stream_graph():
    g = ContextGraph(name="cluster-stream")
    g.add_stream("src", "gen")
    g.add("m", "double", deps=["src"], stream="map", aliases={"src": "chunk"})
    g.add("r", lambda ctx, m: sum(m), deps=["m"], stream="reduce")
    return g


def test_cluster_stream_pipeline_and_replay(tmp_path):
    reg = _stream_registry()
    workers = [InProcWorker(f"w{i}", reg) for i in range(2)]
    path = str(tmp_path / "c.wal")
    with Journal(path, sync="batch") as j:
        with Gateway(workers) as gw:
            rep = ClusterExecutor(gw, journal=j, speculative=False).run(
                _stream_graph()
            )
    assert rep.outputs["r"] == sum(i * 2 for i in range(6))
    with Journal(path, sync="batch") as j:
        with Gateway(workers) as gw:
            rep2 = ClusterExecutor(gw, journal=j, speculative=False).run(
                _stream_graph()
            )
    assert rep2.executed == ()
    assert set(rep2.replayed) == {"src", "m", "r"}
    assert rep2.outputs == rep.outputs


def test_cluster_source_resumes_after_mid_stream_worker_failure():
    """A source whose transport dies mid-stream is re-dispatched with
    ``start`` set to the next uncommitted offset — committed chunks are
    never requested from the producer again."""
    reg = TaskRegistry()
    starts = []

    @reg.task("gen")
    def gen(ctx, start=0):
        starts.append(start)
        for i in range(start, 6):
            if i == 3 and len(starts) == 1:
                raise ConnectionError("transport died mid-stream")
            yield i

    workers = [InProcWorker(f"w{i}", reg) for i in range(2)]
    g = ContextGraph(name="resume-cluster")
    g.add_stream("src", "gen")
    g.add("r", lambda ctx, src: sum(src), deps=["src"], stream="reduce")
    with Gateway(workers) as gw:
        rep = ClusterExecutor(gw, speculative=False).run(g)
    assert rep.outputs["r"] == sum(range(6))
    assert starts[0] == 0
    assert starts[1:] and all(s == 3 for s in starts[1:])  # resumed, not restarted


def test_http_worker_streams_chunks_incrementally():
    reg = _stream_registry()
    with WorkerServer("ws0", reg) as ws:
        client = WorkerClient("ws0", ws.address, ws.heartbeat_server.address)
        with Gateway([client]) as gw:
            rep = ClusterExecutor(gw, speculative=False).run(_stream_graph())
    assert rep.outputs["src"] == list(range(6))
    assert rep.outputs["m"] == [i * 2 for i in range(6)]
    assert rep.outputs["r"] == sum(i * 2 for i in range(6))


def test_http_mid_stream_task_error_is_typed_and_resumable():
    reg = TaskRegistry()
    attempts = []

    @reg.task("gen")
    def gen(ctx, start=0):
        attempts.append(start)
        for i in range(start, 5):
            if i == 2 and len(attempts) == 1:
                raise ValueError("producer bug on first attempt")
            yield i

    with WorkerServer("ws0", reg) as ws:
        client = WorkerClient("ws0", ws.address, ws.heartbeat_server.address)
        g = ContextGraph(name="http-err")
        g.add_stream("src", "gen")
        g.add("r", lambda ctx, src: list(src), deps=["src"], stream="reduce")
        with Gateway([client]) as gw:
            rep = ClusterExecutor(gw, speculative=False).run(g)
    assert rep.outputs["r"] == [0, 1, 2, 3, 4]
    assert attempts == [0, 2]  # second dispatch resumed at the committed offset
