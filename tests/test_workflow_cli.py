"""Interrupt timeouts (default-answer / escalate policies, journaled for
deterministic replay) and the ``python -m repro workflows`` operator CLI.

Contract:
  - ``Node.interrupt`` timeout declarations are validated at graph build
    time; the SUSPEND record carries the *absolute* deadline so every later
    incarnation — any process, any machine — makes the same decision,
  - an expired ``on_timeout="default"`` interrupt self-answers via a
    journaled auto-RESUME (replay-deterministic); ``"escalate"`` marks the
    workflow escalated and raises; explicit human inputs always win,
  - the CLI lists pending suspensions across a store, shows one, and
    answers one with ``resume --input k=v``.
"""

import json
import os
import subprocess
import sys
import textwrap
import time

import pytest

from repro.core import ContextGraph, Journal, interrupt
from repro.workflow import WorkflowRegistry, WorkflowRunner
from repro.workflow.api import WorkflowInterruptTimeout

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _ask(ctx):
    return interrupt(ctx, "approve")


def _after(ctx, ask):
    return f"final:{ask}"


def _registry(timeout_s=0.01, default=..., on_timeout=""):
    reg = WorkflowRegistry()
    kw = {"interrupt_timeout_s": timeout_s, "interrupt_on_timeout": on_timeout}
    if default is not ...:
        kw["interrupt_default"] = default

    def build(args):
        g = ContextGraph(name="wf")
        g.add("ask", _ask, interrupt="approve", **kw)
        g.add("after", _after, deps=["ask"])
        return g

    reg.register("wf", build)
    return reg


# ---------------------------------------------------------------------------
# declaration-time validation
# ---------------------------------------------------------------------------


def test_timeout_without_interrupt_rejected():
    g = ContextGraph()
    with pytest.raises(ValueError, match="require an interrupt"):
        g.add("x", lambda ctx: 1, interrupt_timeout_s=5.0)


def test_policy_without_timeout_rejected():
    g = ContextGraph()
    with pytest.raises(ValueError, match="interrupt_timeout_s"):
        g.add("x", lambda ctx: 1, interrupt="gate", interrupt_on_timeout="escalate")


def test_default_policy_requires_explicit_default():
    g = ContextGraph()
    with pytest.raises(ValueError, match="default"):
        g.add(
            "x",
            lambda ctx: 1,
            interrupt="gate",
            interrupt_timeout_s=5.0,
            interrupt_on_timeout="default",
        )


def test_unknown_policy_rejected():
    g = ContextGraph()
    with pytest.raises(ValueError, match="interrupt_on_timeout"):
        g.add(
            "x",
            lambda ctx: 1,
            interrupt="gate",
            interrupt_timeout_s=5.0,
            interrupt_on_timeout="page-oncall",
        )


def test_policy_inference():
    g = ContextGraph()
    n1 = g.add("a", lambda ctx: 1, interrupt="g1", interrupt_timeout_s=1.0)
    assert n1.interrupt_on_timeout == "escalate"
    n2 = g.add(
        "b", lambda ctx: 1, interrupt="g2", interrupt_timeout_s=1.0, interrupt_default=0
    )
    assert n2.interrupt_on_timeout == "default"


# ---------------------------------------------------------------------------
# journaled deadline + policies at resume time
# ---------------------------------------------------------------------------


def test_suspend_record_carries_absolute_deadline(tmp_path):
    runner = WorkflowRunner(_registry(timeout_s=30.0, default="ok"), str(tmp_path))
    runner.run("wf", workflow_id="w1")
    with Journal(runner.store.journal_path("w1"), sync="never") as j:
        sus = [r for r in j.records() if r.kind == "SUSPEND"]
    assert sus, "no SUSPEND journaled"
    meta = sus[-1].meta
    assert meta["timeout_s"] == 30.0
    assert meta["on_timeout"] == "default" and meta["default"] == "ok"
    assert abs(meta["deadline"] - (time.time() + 30.0)) < 5.0  # absolute epoch


def test_expired_default_policy_self_answers_durably(tmp_path):
    runner = WorkflowRunner(_registry(default="auto-ok"), str(tmp_path))
    assert runner.run("wf", workflow_id="w1").suspended
    time.sleep(0.03)
    res = runner.resume("w1")
    assert res.status == "completed"
    assert res.outputs["after"] == "final:auto-ok"
    with Journal(runner.store.journal_path("w1"), sync="never") as j:
        auto = [r for r in j.records() if r.kind == "RESUME" and r.meta.get("auto")]
    assert auto and auto[0].meta["auto"] == "timeout"
    assert auto[0].meta["inputs"] == {"approve": "auto-ok"}
    # deterministic replay: a later incarnation re-reads the SAME answer
    res2 = runner.resume("w1")
    assert res2.status == "completed" and res2.outputs["after"] == "final:auto-ok"


def test_expired_escalate_policy_raises_and_marks_store(tmp_path):
    runner = WorkflowRunner(_registry(), str(tmp_path))  # no default ⇒ escalate
    assert runner.run("wf", workflow_id="w1").suspended
    time.sleep(0.03)
    with pytest.raises(WorkflowInterruptTimeout, match="escalation required"):
        runner.resume("w1")
    st = runner.status("w1")
    assert st["status"] == "escalated"
    assert st["pending_interrupt"]["expired"] is True
    # a human answer still lands after escalation
    res = runner.resume("w1", inputs={"approve": "human"})
    assert res.status == "completed" and res.outputs["after"] == "final:human"


def test_explicit_inputs_beat_expired_default(tmp_path):
    runner = WorkflowRunner(_registry(default="auto-ok"), str(tmp_path))
    runner.run("wf", workflow_id="w1")
    time.sleep(0.03)
    res = runner.resume("w1", inputs={"approve": "human"})
    assert res.outputs["after"] == "final:human"  # not the auto default


def test_unexpired_timeout_just_resuspends(tmp_path):
    runner = WorkflowRunner(_registry(timeout_s=60.0, default="x"), str(tmp_path))
    runner.run("wf", workflow_id="w1")
    res = runner.resume("w1")  # deadline far away: plain crash-resume
    assert res.suspended
    st = runner.status("w1")
    assert st["pending_interrupt"]["expired"] is False


def test_unserializable_default_degrades_to_escalate(tmp_path):
    reg = WorkflowRegistry()

    def build(args):
        g = ContextGraph(name="wf")
        g.add(
            "ask",
            _ask,
            interrupt="approve",
            interrupt_timeout_s=0.01,
            interrupt_default=lambda: None,  # not journal-serializable
        )
        return g

    reg.register("wf", build)
    runner = WorkflowRunner(reg, str(tmp_path))
    runner.run("wf", workflow_id="w1")
    with Journal(runner.store.journal_path("w1"), sync="never") as j:
        sus = [r for r in j.records() if r.kind == "SUSPEND"][-1]
    assert sus.meta["on_timeout"] == "escalate"
    assert "default" not in sus.meta


# ---------------------------------------------------------------------------
# python -m repro workflows CLI
# ---------------------------------------------------------------------------


@pytest.fixture
def cli_env(tmp_path):
    """A store with one suspended workflow + an importable registry module."""
    (tmp_path / "flows.py").write_text(
        textwrap.dedent(
            """
            from repro.core import interrupt
            from repro.core.graph import ContextGraph
            from repro.workflow import WorkflowRegistry

            REGISTRY = WorkflowRegistry()

            def ask(ctx):
                return interrupt(ctx, "approve")

            def after(ctx, ask):
                return f"final:{ask}"

            @REGISTRY.define("order")
            def order(args):
                g = ContextGraph(name="order")
                g.add("ask", ask, interrupt="approve", interrupt_timeout_s=3600.0)
                g.add("after", after, deps=["ask"])
                return g
            """
        )
    )
    store = str(tmp_path / "store")
    sys.path.insert(0, str(tmp_path))
    try:
        import flows

        WorkflowRunner(flows.REGISTRY, store).run("order", workflow_id="order-1")
    finally:
        sys.path.remove(str(tmp_path))
        sys.modules.pop("flows", None)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + str(tmp_path)
    return store, env


def _cli(args, env):
    return subprocess.run(
        [sys.executable, "-m", "repro", *args],
        capture_output=True,
        text=True,
        env=env,
        timeout=120,
    )


def test_cli_list_shows_pending_suspension(cli_env):
    store, env = cli_env
    proc = _cli(["workflows", "list", "--store", store], env)
    assert proc.returncode == 0, proc.stderr
    assert "order-1" in proc.stdout and "approve@ask" in proc.stdout

    proc = _cli(["workflows", "list", "--store", store, "--pending", "--json"], env)
    rows = json.loads(proc.stdout)
    assert rows[0]["id"] == "order-1"
    assert rows[0]["pending"]["interrupt"] == "approve"
    assert rows[0]["pending"]["expired"] is False


def test_cli_show_reports_deadline(cli_env):
    store, env = cli_env
    proc = _cli(["workflows", "show", "--store", store, "order-1"], env)
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["status"] == "suspended"
    assert doc["pending_interrupt"]["on_timeout"] == "escalate"


def test_cli_resume_answers_interrupt(cli_env):
    store, env = cli_env
    proc = _cli(
        [
            "workflows",
            "resume",
            "--store",
            store,
            "--registry",
            "flows:REGISTRY",
            "order-1",
            "--input",
            "approve=true",
        ],
        env,
    )
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout)
    assert out["status"] == "completed" and out["pending"] is None

    proc = _cli(["workflows", "list", "--store", store, "--pending"], env)
    assert "order-1" not in proc.stdout


def test_cli_input_values_parse_as_json_with_string_fallback(cli_env):
    from repro.__main__ import _parse_inputs

    assert _parse_inputs(["a=true", "b=3", "c=hello", 'd={"k": 1}']) == {
        "a": True,
        "b": 3,
        "c": "hello",
        "d": {"k": 1},
    }
    with pytest.raises(SystemExit):
        _parse_inputs(["missing-equals"])


def test_cli_unknown_id_exits_nonzero(cli_env):
    store, env = cli_env
    proc = _cli(["workflows", "show", "--store", store, "nope"], env)
    assert proc.returncode == 1
    assert "nope" in proc.stderr
