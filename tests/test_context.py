"""Context ξ-union semantics (§4.1) — unit + hypothesis property tests."""
import string

from _propcheck import given, settings, st

from repro.core import Context, ContextEntry, EMPTY_CONTEXT


def test_origin_context_and_data_fold():
    root = Context.origin({"env": "prod", "seed": 42})
    assert root.get("env") == "prod"
    c = root.with_data({"step": 1}, origin="R")
    # ξ(R) = ξ(∅) ∪ Ψ(R)
    assert c.get("seed") == 42 and c.get("step") == 1
    assert "∅" in c.origins() and "R" in c.origins()


def test_union_preserves_all_facts():
    root = Context.origin({"x": 0})
    a = root.with_data({"shard": 0}, origin="A")
    b = root.with_data({"shard": 1}, origin="B")
    u = a | b
    assert set(u.get_all("shard")) == {0, 1}
    assert u.provenance("shard") == ("A", "B")  # deterministic order


def test_get_resolves_latest_lamport():
    c = Context.origin({"k": "old"})
    c2 = c.with_data({"k": "new"}, origin="n1")
    assert c2.get("k") == "new"
    assert c2.get_all("k") == ("old", "new")


def test_digest_stability_and_sensitivity():
    a = Context.origin({"a": 1, "b": [1, 2]})
    b = Context.origin({"b": [1, 2], "a": 1})  # insertion order must not matter
    assert a.digest() == b.digest()
    c = Context.origin({"a": 1, "b": [2, 1]})
    assert a.digest() != c.digest()


def test_wire_roundtrip():
    c = Context.origin({"a": 1}).with_data({"b": {"x": [1.5, None, "s"]}}, origin="n")
    rt = Context.from_wire(c.to_wire())
    assert rt == c and rt.digest() == c.digest()


def test_empty_context():
    assert len(EMPTY_CONTEXT) == 0
    assert EMPTY_CONTEXT.get("missing", "d") == "d"
    assert (EMPTY_CONTEXT | EMPTY_CONTEXT) == EMPTY_CONTEXT


# ---------------------------------------------------------------------------
# property tests: ξ-union is a commutative, associative, idempotent monoid
# ---------------------------------------------------------------------------
_keys = st.text(string.ascii_lowercase, min_size=1, max_size=4)
_vals = st.one_of(st.integers(-5, 5), st.text(string.ascii_letters, max_size=4),
                  st.lists(st.integers(0, 3), max_size=3))


@st.composite
def contexts(draw):
    n = draw(st.integers(0, 5))
    entries = [ContextEntry.make(draw(_keys), draw(_vals),
                                 origin=draw(_keys), lamport=draw(st.integers(0, 3)))
               for _ in range(n)]
    return Context(entries)


@settings(max_examples=200, deadline=None)
@given(contexts(), contexts())
def test_union_commutative(a, b):
    assert (a | b) == (b | a)
    assert (a | b).digest() == (b | a).digest()


@settings(max_examples=200, deadline=None)
@given(contexts(), contexts(), contexts())
def test_union_associative(a, b, c):
    assert ((a | b) | c) == (a | (b | c))


@settings(max_examples=200, deadline=None)
@given(contexts())
def test_union_idempotent_with_identity(a):
    assert (a | a) == a
    assert (a | EMPTY_CONTEXT) == a


@settings(max_examples=100, deadline=None)
@given(contexts(), contexts())
def test_union_is_superset(a, b):
    u = a | b
    assert a.keys() | b.keys() == u.keys()
    for k in a.keys():
        uvals = list(u.get_all(k))
        for v in a.get_all(k):
            assert v in uvals
