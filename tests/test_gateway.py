"""Gateway (§3.3): allocation algorithms, silo queue, failure rerouting."""
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import pytest

from repro.core import (
    AllocationError,
    FlakyWorker,
    Gateway,
    HeartbeatServer,
    InProcWorker,
    TaskRegistry,
    WorkerClient,
    WorkerHandle,
    WorkerServer,
    context_affinity,
    least_loaded,
    power_of_two,
    round_robin,
)
from repro.wire import PayloadDecodeError


def _cluster(n=4, fail=None):
    reg = TaskRegistry()

    @reg.task("add")
    def add(ctx, a, b):
        return a + b

    @reg.task("slow")
    def slow(ctx, dt=0.05):
        time.sleep(dt)
        return dt

    @reg.task("whoami")
    def whoami(ctx):
        return ctx.get("gateway", "?")

    @reg.task("boom")
    def boom(ctx):
        raise ValueError("app error")

    return reg, [InProcWorker(f"w{i}", reg) for i in range(n)]


def test_basic_dispatch_and_result():
    reg, workers = _cluster()
    with Gateway(workers) as gw:
        fut = gw.submit("add", inputs={"a": 2, "b": 3})
        assert fut.result(timeout=5) == 5


def test_round_robin_spreads_load():
    reg, workers = _cluster(3)
    with Gateway(workers, allocation=("round_robin",)) as gw:
        futs = gw.map("add", [{"a": i, "b": 0} for i in range(9)])
        [f.result(timeout=5) for f in futs]
    counts = [w.state.completed for w in workers]
    assert sum(counts) == 9 and max(counts) <= 5  # roughly spread


def test_silo_priority_ordering():
    reg, workers = _cluster(1)
    order = []

    @reg.task("record")
    def record(ctx, tag):
        order.append(tag)
        return tag

    gw = Gateway(workers, silo=True, dispatch_threads=1)
    # enqueue BEFORE starting dispatch so priorities decide order
    gw.submit("record", inputs={"tag": "low"}, priority=9)
    gw.submit("record", inputs={"tag": "high"}, priority=0)
    f = gw.submit("record", inputs={"tag": "mid"}, priority=5)
    with gw:
        f.result(timeout=5)
        time.sleep(0.1)
    assert order[0] == "high" and set(order) == {"low", "mid", "high"}


def test_system_failure_reroutes_to_live_worker():
    reg, workers = _cluster(2)
    workers[0].alive = False  # system-level death: heartbeat gone
    with Gateway(workers, heartbeat_interval_s=0.05) as gw:
        fut = gw.submit("add", inputs={"a": 1, "b": 1})
        assert fut.result(timeout=5) == 2
    assert workers[1].state.completed >= 1


def test_application_failure_distinguished():
    """App raises -> status error -> retries -> surfaced; heartbeat stays OK."""
    reg, workers = _cluster(2)
    with Gateway(workers) as gw:
        fut = gw.submit("boom", max_attempts=2)
        with pytest.raises(RuntimeError):
            fut.result(timeout=5)
        assert all(h.live for h in gw.handles)  # system-level all healthy


def test_all_workers_down_allocation_error():
    reg, workers = _cluster(2)
    for w in workers:
        w.alive = False
    with Gateway(workers, heartbeat_interval_s=0.05) as gw:
        fut = gw.submit("add", inputs={"a": 1, "b": 1}, max_attempts=1)
        with pytest.raises((AllocationError, TimeoutError, ConnectionError)):
            fut.result(timeout=10)


def test_worker_down_callback_fires():
    reg, workers = _cluster(2)
    downs = []
    gw = Gateway(workers, heartbeat_interval_s=0.05)
    gw.on_worker_down = lambda h: downs.append(h.name)
    with gw:
        workers[0].alive = False
        deadline = time.time() + 5
        while not downs and time.time() < deadline:
            time.sleep(0.02)
    assert "w0" in downs


def test_heartbeat_eviction_requeues_inflight_requests():
    """A hung worker's in-flight requests move to survivors via the heartbeat
    monitor — the dispatch path alone would block on the dead transport."""
    reg, workers = _cluster(1)
    flaky = FlakyWorker("wx", reg, kill_after_starts=1, mode="hang",
                        hang_timeout_s=5.0)
    requeues = []
    with Gateway([flaky] + workers, heartbeat_interval_s=0.05) as gw:
        gw.on_requeue = lambda req, reason: requeues.append(reason)
        futs = gw.map("slow", [{"dt": 0.1}] * 4)
        assert [f.result(timeout=5) for f in futs] == [0.1] * 4
        flaky.release()
    assert gw.metrics["evicted"] >= 1
    assert any("evicted" in r for r in requeues)


def test_context_affinity_prefers_holder():
    reg, workers = _cluster(3)
    with Gateway(workers, allocation=("context_affinity", "least_loaded")) as gw:
        gw.submit("add", inputs={"a": 0, "b": 0}, affinity_key="shard7").result(timeout=5)
        holder = [h.name for h in gw.handles if "shard7" in h.held_contexts]
        assert len(holder) == 1
        for _ in range(5):
            gw.submit("add", inputs={"a": 0, "b": 0}, affinity_key="shard7").result(timeout=5)
        holders_after = [h.name for h in gw.handles if "shard7" in h.held_contexts]
        assert holders_after == holder  # affinity kept routing to the same worker


def test_allocation_algorithms_pure():
    handles = [WorkerHandle(worker=None, name=f"w{i}") for i in range(4)]
    handles[2].inflight = 5
    req = type("R", (), {"affinity_key": "", "task_name": "t"})()
    assert least_loaded(handles, req, {}).name != "w2"
    assert power_of_two(handles, req, {"rng": __import__("random").Random(0)}) is not None
    assert round_robin(handles, req, {}) is not None
    assert context_affinity(handles, req, {}) is None  # no key -> falls through
    handles[1].held_contexts.add("k")
    req2 = type("R", (), {"affinity_key": "k", "task_name": "t"})()
    assert context_affinity(handles, req2, {}).name == "w1"


def test_cluster_context_snapshot():
    reg, workers = _cluster(2)
    with Gateway(workers) as gw:
        gw.submit("add", inputs={"a": 1, "b": 2}).result(timeout=5)
        ctx = gw.cluster_context()
        assert ctx.get("worker/w0/live") in (True, False)
        assert "worker/w1/live" in ctx.keys()


def test_stats_snapshot_telemetry():
    """Gateway.stats(): per-worker probe latency, inflight/queue depths —
    the groundwork signals for stream-aware allocation."""
    reg, workers = _cluster(2)
    with Gateway(workers, heartbeat_interval_s=0.05) as gw:
        futs = gw.map("add", [{"a": i, "b": 1} for i in range(6)])
        [f.result(timeout=5) for f in futs]
        snap = gw.stats()
    assert set(snap["workers"]) == {"w0", "w1"}
    for w in snap["workers"].values():
        assert w["live"] is True and w["app_live"] is True
        assert isinstance(w["inflight"], int) and w["inflight"] >= 0
        assert w["probe_latency_s"] >= 0.0  # stamped even for in-proc workers
        assert w["hb_misses"] == 0
    assert sum(w["completed"] for w in snap["workers"].values()) >= 6
    assert snap["queue_depth"] == 0 and snap["silo_depth"] == 0
    assert snap["live_workers"] == 2
    assert snap["metrics"]["scheduled"] >= 6
    assert snap["mean_alloc_us"] >= 0.0


class _CorruptHandler(BaseHTTPRequestHandler):
    """An application server that answers /task with undecodable bytes."""

    def do_POST(self):  # noqa: N802
        body = b"\xde\xad\xbe\xef not a payload frame"
        self.send_response(200)
        self.send_header("Content-Type", "application/x-msgpack-zstd")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):
        pass


class _CorruptWorker:
    """A real HTTP worker (live heartbeat) whose responses are corrupt."""

    def __init__(self):
        self._httpd = ThreadingHTTPServer(("127.0.0.1", 0), _CorruptHandler)
        threading.Thread(target=self._httpd.serve_forever, daemon=True).start()
        self.heartbeat_server = HeartbeatServer().start()
        host, port = self._httpd.server_address
        self.client = WorkerClient("corrupt", f"http://{host}:{port}",
                                   self.heartbeat_server.address, timeout=5.0)

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self.heartbeat_server.stop()


def test_corrupt_http_payload_surfaces_typed_error():
    """End to end: an HTTP worker returning undecodable bytes surfaces
    PayloadDecodeError (the typed corruption signal), not a generic timeout."""
    corrupt = _CorruptWorker()
    try:
        with Gateway([corrupt.client], heartbeat_interval_s=0.1) as gw:
            fut = gw.submit("add", inputs={"a": 1, "b": 1}, max_attempts=2)
            with pytest.raises(PayloadDecodeError):
                fut.result(timeout=10)
            assert gw.metrics["corrupt"] >= 1
    finally:
        corrupt.stop()


def test_corrupt_worker_retried_on_healthy_worker():
    """The gateway quarantines the corrupt worker (app-level) and requeues
    the request on a healthy HTTP worker — the caller never sees the error."""
    reg = TaskRegistry()
    reg.register("add", lambda ctx, a, b: a + b)
    corrupt = _CorruptWorker()
    try:
        with WorkerServer("healthy", reg) as ws:
            healthy = WorkerClient("healthy", ws.address,
                                   ws.heartbeat_server.address)
            # long heartbeat interval: the app-level quarantine must not be
            # reset by a probe mid-test (probes self-heal app_live)
            with Gateway([corrupt.client, healthy],
                         allocation=("round_robin",),
                         heartbeat_interval_s=5.0) as gw:
                futs = gw.map("add", [{"a": i, "b": i} for i in range(6)])
                assert [f.result(timeout=15) for f in futs] == \
                    [2 * i for i in range(6)]
                # at least one request hit the corrupt worker and was retried
                assert gw.metrics["corrupt"] >= 1
                assert gw.metrics["requeued"] >= 1
                corrupt_handle = next(h for h in gw.handles
                                      if h.name == "corrupt")
                assert corrupt_handle.app_live is False  # quarantined
    finally:
        corrupt.stop()


def test_allocation_fast():
    """§5: gateway decisions must not become the scaled-up bottleneck."""
    reg, workers = _cluster(8)
    with Gateway(workers, allocation=("least_loaded",)) as gw:
        futs = gw.map("add", [{"a": i, "b": i} for i in range(200)])
        [f.result(timeout=10) for f in futs]
        assert gw.mean_alloc_us() < 1000  # < 1ms/decision
