"""Continuous-batching engine: correctness vs sequential generation,
slot refill, per-sequence positions, utilization accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serve import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(
        get_config("serpytor-demo-100m"), name="batcher-demo",
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=512)
    model = build(cfg)
    params, _ = model.init(jax.random.key(0))
    return cfg, model, params


def _sequential_generate(model, params, prompt, n, max_len):
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = model.prefill(params, {"tokens": toks}, pad_to=max_len)
    tok = jnp.argmax(logits, axis=-1)
    out = []
    for _ in range(n):
        out.append(int(tok[0]))
        logits, cache = model.decode_step(params, cache, {"token": tok})
        tok = jnp.argmax(logits, axis=-1)
    return out


def test_batched_equals_sequential(small_model):
    """Each request's generation must equal single-request greedy decode."""
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, rng.integers(4, 12))
               for _ in range(5)]
    want = {f"r{i}": _sequential_generate(model, params, p, 6, 64)
            for i, p in enumerate(prompts)}

    eng = ContinuousBatcher(model, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=f"r{i}", prompt=np.asarray(p, np.int32),
                           max_new_tokens=6))
    done = eng.run_until_drained()
    assert set(done) == set(want)
    for rid in want:
        assert done[rid].tokens == want[rid], \
            f"{rid}: {done[rid].tokens} != {want[rid]}"


def test_slot_reuse_more_requests_than_slots(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    eng = ContinuousBatcher(model, params, slots=2, max_len=32)
    for i in range(7):
        eng.submit(Request(rid=f"q{i}",
                           prompt=rng.integers(0, cfg.vocab_size, 4)
                           .astype(np.int32), max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 7
    assert all(len(g.tokens) == 3 for g in done.values())
    assert eng.utilization() > 0.4


def test_mixed_lengths_interleave(small_model):
    """A long generation must not block short ones (continuous batching)."""
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    eng = ContinuousBatcher(model, params, slots=2, max_len=64)
    eng.submit(Request(rid="long", prompt=rng.integers(0, 512, 4)
                       .astype(np.int32), max_new_tokens=20))
    for i in range(4):
        eng.submit(Request(rid=f"s{i}", prompt=rng.integers(0, 512, 4)
                           .astype(np.int32), max_new_tokens=2))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert len(done["long"].tokens) == 20
    # short requests completed in far fewer engine steps than the long one
    assert eng.steps <= 20 + 4 * 2 + 4  # admission bubbles only


def test_token_streaming_output_path(small_model):
    """submit_stream: tokens arrive on the channel INCREMENTALLY (the first
    one while the engine is still decoding) and match the final generation."""
    import threading

    cfg, model, params = small_model
    rng = np.random.default_rng(3)
    eng = ContinuousBatcher(model, params, slots=2, max_len=64)
    # capacity 2 < max_new_tokens forces real backpressure: the engine MUST
    # still be alive (parked on the channel) when the first token is read
    ch = eng.submit_stream(Request(rid="st", prompt=rng.integers(0, 512, 4)
                                   .astype(np.int32), max_new_tokens=8),
                           capacity=2)
    eng.submit(Request(rid="plain", prompt=rng.integers(0, 512, 4)
                       .astype(np.int32), max_new_tokens=8))

    done = {}
    t = threading.Thread(target=lambda: done.update(eng.run_until_drained()),
                         daemon=True)
    t.start()
    streamed = []
    first_arrival_done = None
    for seq, tok in ch:  # ends when the engine closes the channel (EOS)
        if first_arrival_done is None:
            first_arrival_done = t.is_alive()  # engine still running?
        assert seq == len(streamed)
        streamed.append(tok)
    t.join(timeout=30)
    assert first_arrival_done, "first token must stream out before drain ends"
    assert streamed == done["st"].tokens  # stream == batch result, token-exact
    assert len(streamed) == 8
    assert done["plain"].tokens  # a non-streamed neighbor is unaffected


def test_latency_accounting(small_model):
    cfg, model, params = small_model
    eng = ContinuousBatcher(model, params, slots=1, max_len=32)
    eng.submit(Request(rid="a", prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=2))
    done = eng.run_until_drained()
    g = done["a"]
    assert g.prompt_len == 4 and g.total_s > 0
    assert g.prefill_s >= 0 and g.decode_s >= 0
