"""repro.wire: codec round-trips, backend-stable digests, compression,
and the no-orjson import regression the seed shipped with."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import wire
from repro.core import Context, ContextEntry

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _codecs():
    out = [wire.get_codec("json"), wire.get_codec("msgpack")]
    try:
        out.append(wire.get_codec("orjson"))
    except ImportError:
        pass
    return out


CODECS = _codecs()
IDS = [c.name for c in CODECS]

SAMPLES = [
    None,
    True,
    -17,
    3.5,
    "héllo ∪ wörld",
    [1, 2, [3, {"k": "v"}]],
    {"b": 1, "a": [None, 2.25], "c": {"nested": True}},
    {"weird keys": {"1": "a", "0": "b"}},
]


# -- transport round-trips ---------------------------------------------------

@pytest.mark.parametrize("codec", CODECS, ids=IDS)
@pytest.mark.parametrize("value", SAMPLES, ids=range(len(SAMPLES)))
def test_roundtrip(codec, value):
    assert codec.decode(codec.encode(value)) == value


def test_msgpack_preserves_arrays():
    codec = wire.get_codec("msgpack")
    arr = np.arange(12, dtype=np.float32).reshape(3, 4)
    out = codec.decode(codec.encode({"x": arr, "c": 1 + 2j}))
    np.testing.assert_array_equal(out["x"], arr)
    assert out["x"].dtype == np.float32
    assert out["c"] == 1 + 2j


# -- the backend-stability guarantee ----------------------------------------

@pytest.mark.parametrize("value", SAMPLES + [
    {"arr": np.arange(6).reshape(2, 3)},
    {"s": {3, 1, 2}, "b": b"\x00\xff"},
], ids=range(len(SAMPLES) + 2))
def test_canonical_bytes_identical_across_codecs(value):
    blobs = {c.name: c.canonical_bytes(value) for c in CODECS}
    assert len(set(blobs.values())) == 1, blobs
    digests = {c.name: c.canonical_digest(value) for c in CODECS}
    assert len(set(digests.values())) == 1, digests


def test_canonical_is_insertion_order_independent():
    a = wire.canonical_digest({"x": 1, "y": 2})
    b = wire.canonical_digest({"y": 2, "x": 1})
    assert a == b


def test_from_canonical_inverts_canonical_bytes():
    v = {"a": [1, 2.5, None, "s"], "b": {"k": False}}
    assert wire.from_canonical(wire.canonical_bytes(v)) == v


def test_nonfinite_floats_normalize_to_null():
    assert wire.from_canonical(wire.canonical_bytes(float("nan"))) is None
    assert wire.from_canonical(wire.canonical_bytes(float("inf"))) is None


def test_unserializable_raises():
    with pytest.raises(TypeError):
        wire.canonical_bytes(object())


def test_non_str_mapping_keys_rejected():
    """str(key) coercion would collide {1: 'a'} with {'1': 'a'} on one
    digest — canonical encoding must refuse instead."""
    for codec in CODECS:
        with pytest.raises(TypeError, match="keys must be str"):
            codec.canonical_bytes({1: "a"})


@pytest.mark.parametrize("value", [1e-05, 1e16, [1e-300, -2.5e-08], 2**70],
                        ids=["exp-neg", "exp-pos", "tiny", "bigint"])
def test_canonical_float_and_bigint_formatting(value):
    """Values whose formatting differs between JSON writers (orjson emits
    1e-5, stdlib 1e-05; orjson rejects >64-bit ints) — every backend must
    emit the single stdlib canonical form."""
    blobs = {c.name: c.canonical_bytes(value) for c in CODECS}
    assert len(set(blobs.values())) == 1, blobs
    assert wire.from_canonical(wire.canonical_bytes(value)) == value


# -- codec selection ---------------------------------------------------------

def test_default_codec_selection_and_override():
    prev = wire.default_codec().name
    try:
        assert wire.set_default_codec("msgpack").name == "msgpack"
        assert wire.default_codec().name == "msgpack"
        # canonical form stays JSON even under a binary transport codec
        assert wire.canonical_bytes({"a": 1}) == b'{"a":1}'
        auto = wire.set_default_codec(None)
        assert auto.name in ("orjson", "json")
    finally:
        wire.set_default_codec(prev)


def test_unknown_codec_rejected():
    with pytest.raises(KeyError):
        wire.get_codec("bson")


def test_available_codecs_contains_builtins():
    names = wire.available_codecs()
    assert "json" in names and "msgpack" in names


# -- compression -------------------------------------------------------------

def test_compress_roundtrip_and_tagging():
    from repro.wire.compress import TAG_ZLIB, TAG_ZSTD

    data = b"serpytor " * 500
    frame = wire.compress(data)
    assert frame[0] in (TAG_ZLIB, TAG_ZSTD)
    assert wire.decompress(frame) == data
    assert len(frame) < len(data)


def test_decompress_rejects_garbage():
    with pytest.raises(ValueError, match="unknown compression tag"):
        wire.decompress(b"\x7fnot-a-frame")


@pytest.mark.skipif(wire.zstd_available(),
                    reason="install-hint path only exists without zstandard")
def test_legacy_zstd_frame_gets_actionable_error():
    """A seed-era untagged zstd frame (magic 0x28B52FFD) on a zlib-only host
    must point at the zstandard install, not claim an unknown tag."""
    with pytest.raises(ImportError, match="zstandard"):
        wire.decompress(b"\x28\xb5\x2f\xfd fake-zstd-body")


# -- payload codec -----------------------------------------------------------

def test_payload_roundtrip_pytree():
    tree = {"w": np.ones((4, 4), np.float32), "step": 7,
            "nested": [np.arange(3), {"b": 2.5}]}
    out = wire.decode_payload(wire.encode_payload(tree))
    np.testing.assert_array_equal(out["w"], tree["w"])
    np.testing.assert_array_equal(out["nested"][0], np.arange(3))
    assert out["step"] == 7 and out["nested"][1]["b"] == 2.5


def test_payload_digest_deterministic_and_sensitive():
    a = {"x": np.arange(5, dtype=np.int32)}
    b = {"x": np.arange(5, dtype=np.int32)}
    c = {"x": np.arange(5, dtype=np.int64)}
    assert wire.payload_digest(a) == wire.payload_digest(b)
    assert wire.payload_digest(a) != wire.payload_digest(c)


# -- context digest caching over wire ---------------------------------------

def test_entry_digest_memoized():
    e = ContextEntry.make("k", {"v": 1}, origin="o")
    d1 = e.digest
    assert e._digest == d1  # cached on first access
    assert e.digest == d1


def test_context_digest_stable_across_codecs():
    digests = set()
    prev = wire.default_codec().name
    try:
        for c in CODECS:
            wire.set_default_codec(c.name)
            ctx = Context.origin({"a": 1, "arr": [1, 2, 3]}).with_data(
                {"b": "x"}, origin="n1")
            digests.add(ctx.digest())
    finally:
        wire.set_default_codec(prev)
    assert len(digests) == 1, digests


def test_union_reuses_entry_digests():
    a = Context.origin({"a": 1})
    b = Context.origin({"b": 2})
    u = a | b
    entry_digests = {e.digest for e in u}
    for e in list(a) + list(b):
        assert e.digest in entry_digests  # same memoized entries, not copies


# -- regression: bare-environment import (the seed break) --------------------

_BLOCKER = """
import sys

class _Block:
    BLOCKED = {blocked!r}
    def find_spec(self, name, path=None, target=None):
        if name in self.BLOCKED:
            raise ImportError(f"{{name}} blocked for bare-environment test")
        return None

sys.meta_path.insert(0, _Block())
import repro
from repro import wire
assert wire.default_codec().name == "json", wire.default_codec().name
from repro.core import Context
ctx = Context.origin({{"env": "bare", "n": [1, 2]}})
assert len(ctx.digest()) == 16
rt = Context.from_wire(ctx.to_wire())
assert rt == ctx and rt.digest() == ctx.digest()
print("BARE-OK", ctx.digest())
"""


def test_import_and_digest_without_orjson_or_zstd():
    """`import repro` + context digests must work with orjson AND zstandard
    blocked — the zero-dependency promise the seed broke."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("REPRO_WIRE_CODEC", None)
    code = _BLOCKER.format(blocked=("orjson", "zstandard"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "BARE-OK" in proc.stdout


def test_env_var_forces_codec():
    env = dict(os.environ, PYTHONPATH=SRC, REPRO_WIRE_CODEC="msgpack")
    code = ("from repro import wire; "
            "assert wire.default_codec().name == 'msgpack'; print('ENV-OK')")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "ENV-OK" in proc.stdout


def test_digest_matches_bare_subprocess():
    """Digest computed in THIS process (whatever codec auto-selected) equals
    the digest computed in a subprocess with only stdlib json available —
    the cross-host stability claim of docs/journal-format.md."""
    ctx = Context.origin({"env": "bare", "n": [1, 2]})
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("REPRO_WIRE_CODEC", None)
    code = _BLOCKER.format(blocked=("orjson", "zstandard"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    bare_digest = proc.stdout.strip().split()[-1]
    assert bare_digest == ctx.digest()


def test_unwrap_digested_handles_namedtuples_and_identity():
    from collections import namedtuple
    from repro.wire import Digested, unwrap_digested

    Pair = namedtuple("Pair", ["a", "b"])
    wrapped = {"p": Pair(Digested.wrap([1, 2]), 3), "plain": (4, 5)}
    out = unwrap_digested(wrapped)
    assert out["p"] == Pair([1, 2], 3) and isinstance(out["p"], Pair)
    assert out["plain"] is wrapped["plain"]  # wrapper-free paths keep identity
    untouched = {"x": [1, {"y": 2}]}
    assert unwrap_digested(untouched) is untouched
